"""Unified model configuration covering all 10 assigned architectures.

One dataclass, no code forks: family differences (GQA vs MLA attention,
dense vs MoE FFN, attention vs SSD mixing, decoder-only vs encoder-decoder,
modality frontends) are expressed as config fields consumed by
models/transformer.py.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None           # default d_model // num_heads

    # -- attention flavour --------------------------------------------------
    attention: str = "gqa"                   # gqa | mla | none
    qk_norm: bool = False                    # qwen3-style per-head RMS on q,k
    qkv_bias: bool = False                   # qwen2-style bias on qkv proj
    causal: bool = True
    rope_theta: float = 10000.0
    rope_style: str = "standard"             # standard | mrope | none
    mrope_sections: tuple = (16, 24, 24)     # qwen2-vl t/h/w rotary split

    # -- MLA (multi-head latent attention; minicpm3/deepseek-v2 style) ------
    mla_q_lora_rank: int = 0
    mla_kv_lora_rank: int = 0
    mla_qk_nope_dim: int = 0
    mla_qk_rope_dim: int = 0
    mla_v_head_dim: int = 0

    # -- MoE -----------------------------------------------------------------
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                        # per-expert hidden (default d_ff)
    moe_shared_expert: bool = False          # llama4-style always-on expert
    moe_capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # -- SSM / Mamba2 (SSD) ---------------------------------------------------
    ssm_state_dim: int = 0
    ssm_num_heads: int = 0
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    ssm_expand: int = 2

    # -- hybrid (zamba2): shared attention block every N blocks ---------------
    hybrid_attn_every: int = 0

    # -- encoder-decoder (whisper) --------------------------------------------
    encoder_layers: int = 0
    encoder_seq_len: int = 1500              # whisper 30s of audio frames
    frontend: str = "none"                   # none | audio_stub | vision_stub

    norm_eps: float = 1e-6
    tie_embeddings: bool = True

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # Fully unroll layer scans (dry-run calibration only: XLA cost_analysis
    # counts rolled loop bodies once, so calibration compiles small
    # unrolled variants to recover true per-layer costs).
    scan_unroll: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.moe_num_experts and not self.moe_d_ff:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # -- derived -------------------------------------------------------------

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.attention == "none"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode state: SSM / hybrid only (DESIGN.md)."""
        return self.family in ("ssm", "hybrid")

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        if self.attention == "mla":
            r_q, r_kv = self.mla_q_lora_rank, self.mla_kv_lora_rank
            qk = self.mla_qk_nope_dim + self.mla_qk_rope_dim
            return (d * r_q + r_q * self.num_heads * qk
                    + d * (r_kv + self.mla_qk_rope_dim)
                    + r_kv * self.num_heads * (self.mla_qk_nope_dim
                                               + self.mla_v_head_dim)
                    + self.num_heads * self.mla_v_head_dim * d)
        n = (d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
             + self.num_heads * hd * d)
        if self.qkv_bias:
            n += (self.num_heads + 2 * self.num_kv_heads) * hd
        return n

    def _ssm_params(self) -> int:
        d = self.d_model
        dn = self.ssm_expand * d
        H = self.ssm_num_heads or max(1, dn // self.ssm_head_dim)
        N = self.ssm_state_dim
        return (d * (2 * dn + 2 * N + H) + dn * d
                + self.ssm_conv_width * (dn + 2 * N))

    def _ffn_params(self) -> int:
        d = self.d_model
        if self.moe_num_experts:
            n = d * self.moe_num_experts  # router
            n += self.moe_num_experts * 3 * d * self.moe_d_ff
            if self.moe_shared_expert:
                n += 3 * d * self.d_ff
            return n
        return 3 * d * self.d_ff

    def num_params(self) -> int:
        """Analytic parameter count (embedding + blocks + norms)."""
        d, f, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd = self.head_dim
        n = V * d                                      # embedding
        if not self.tie_embeddings:
            n += V * d

        if self.family == "ssm":
            n += L * (self._ssm_params() + d)
            return n
        if self.family == "hybrid":
            every = max(self.hybrid_attn_every, 1)
            groups = L // every
            mamba_layers = L - groups
            n += mamba_layers * (self._ssm_params() + d)
            # ONE shared attention+MLP block (applied `groups` times)
            n += self._attn_params() + self._ffn_params() + 2 * d
            return n

        per_layer = self._attn_params() + self._ffn_params() + 2 * d
        n += L * per_layer
        if self.is_encdec:
            enc = self.encoder_layers * (4 * d * self.num_heads * hd
                                         + 3 * d * f + 2 * d)
            xattn = self.num_layers * (4 * d * self.num_heads * hd + d)
            n += enc + xattn
        return n

    def active_params(self) -> int:
        """Activated parameters per token (MoE top-k instead of all experts)."""
        if not self.moe_num_experts:
            return self.num_params()
        total = self.num_params()
        inactive = (self.moe_num_experts - self.moe_top_k)
        per_expert = 3 * self.d_model * self.moe_d_ff
        return total - self.num_layers * inactive * per_expert
