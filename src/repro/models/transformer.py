"""Model assembly: decoder-only / encoder-decoder / SSM / hybrid stacks.

All architectures share one parameter layout convention:

    params = {
      "embed":   (V, D)
      "head":    (D, V)            -- absent when tie_embeddings
      "final_norm": {...}
      "layers":  pytree with leading layer axis (scanned)
      "enc_*":   encoder stack (whisper)
      "shared_attn": single shared block (zamba2)
    }

Layer stacks are `lax.scan`ned over the leading axis so the lowered HLO is
one layer body regardless of depth (compile-time at 95 layers stays flat);
`remat` wraps the scan body with jax.checkpoint for activation
rematerialization during training.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import (constrain_batch_acts,
                                 constrain_seq_model_acts,
                                 model_axis_extent)
from repro.models import layers as nn
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig

Params = Dict[str, Any]


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


def _ct(cfg):
    return jnp.dtype(cfg.compute_dtype)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def _scan(cfg, f, init, xs):
    """lax.scan honoring cfg.scan_unroll (dry-run calibration unrolls so
    XLA cost_analysis counts every layer; production keeps rolled loops)."""
    unroll = True if cfg.scan_unroll else 1
    return jax.lax.scan(f, init, xs, unroll=unroll)

def _init_block(rng, cfg: ModelConfig, kind: str) -> Params:
    """One transformer block's parameters.  kind: attn|mla|moe|ssm|encdec."""
    ks = jax.random.split(rng, 6)
    p: Params = {"ln1": nn.init_rmsnorm(cfg.d_model, _dt(cfg))}
    if kind == "ssm":
        p["mixer"] = ssm_mod.init_mamba2(ks[0], cfg)
        return p
    if cfg.attention == "mla":
        p["attn"] = nn.init_mla(ks[0], cfg)
    else:
        p["attn"] = nn.init_attention(ks[0], cfg)
    p["ln2"] = nn.init_rmsnorm(cfg.d_model, _dt(cfg))
    if kind == "moe":
        p["moe"] = nn.init_moe(ks[1], cfg)
    else:
        p["mlp"] = nn.init_mlp(ks[1], cfg)
    if kind == "encdec":
        p["ln_x"] = nn.init_rmsnorm(cfg.d_model, _dt(cfg))
        p["xattn"] = nn.init_cross_attention(ks[2], cfg)
    return p


def _block_kind(cfg: ModelConfig) -> str:
    if cfg.family == "ssm":
        return "ssm"
    if cfg.moe_num_experts:
        return "moe"
    if cfg.is_encdec:
        return "encdec"
    return "attn"


def _hybrid_counts(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(num_groups, mamba_per_group, tail_mamba) for the zamba2 layout:
    within each group of `every` blocks the last is the shared attn block."""
    every = cfg.hybrid_attn_every
    groups = cfg.num_layers // every
    tail = cfg.num_layers - groups * every
    return groups, every - 1, tail


def init_model(rng, cfg: ModelConfig) -> Params:
    ks = jax.random.split(rng, 8)
    V, D = cfg.vocab_size, cfg.d_model
    params: Params = {
        "embed": (jax.random.normal(ks[0], (V, D), jnp.float32) * D ** -0.5
                  ).astype(_dt(cfg)),
        "final_norm": nn.init_rmsnorm(D, _dt(cfg)),
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(ks[1], (D, V), jnp.float32)
                          * D ** -0.5).astype(_dt(cfg))

    if cfg.family == "hybrid":
        groups, per_group, tail = _hybrid_counts(cfg)
        def init_mamba_layer(r):
            return {"ln1": nn.init_rmsnorm(D, _dt(cfg)),
                    "mixer": ssm_mod.init_mamba2(r, cfg)}
        params["layers"] = jax.vmap(init_mamba_layer)(
            jax.random.split(ks[2], groups * per_group))
        if tail:
            params["tail_layers"] = jax.vmap(init_mamba_layer)(
                jax.random.split(ks[3], tail))
        shared = _init_block(ks[4], cfg, "attn")
        params["shared_attn"] = shared
        return params

    kind = _block_kind(cfg)
    params["layers"] = jax.vmap(lambda r: _init_block(r, cfg, kind))(
        jax.random.split(ks[2], cfg.num_layers))

    if cfg.is_encdec:
        def init_enc(r):
            k1, k2 = jax.random.split(r)
            return {"ln1": nn.init_rmsnorm(D, _dt(cfg)),
                    "attn": nn.init_attention(k1, cfg),
                    "ln2": nn.init_rmsnorm(D, _dt(cfg)),
                    "mlp": nn.init_mlp(k2, cfg)}
        params["enc_layers"] = jax.vmap(init_enc)(
            jax.random.split(ks[5], cfg.encoder_layers))
        params["enc_norm"] = nn.init_rmsnorm(D, _dt(cfg))
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Block bodies
# ---------------------------------------------------------------------------

def _attn_block(p, cfg, x, positions, enc_kv=None, causal=True):
    # Heads that don't divide the TP extent would replicate the score
    # tensor across 'model'; fall back to sequence parallelism instead.
    if cfg.num_heads % max(model_axis_extent(), 1) != 0:
        x = constrain_seq_model_acts(x)
    else:
        x = constrain_batch_acts(x)
    h = nn.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.attention == "mla":
        h = nn.mla_forward(p["attn"], cfg, h, positions, causal=causal)
    else:
        h = nn.attention_forward(p["attn"], cfg, h, positions, causal=causal)
    x = x + h
    if enc_kv is not None:
        h = nn.rmsnorm(p["ln_x"], x, cfg.norm_eps)
        x = x + nn.cross_attention(p["xattn"], cfg, h, enc_kv)
    h = nn.rmsnorm(p["ln2"], x, cfg.norm_eps)
    aux = jnp.asarray(0.0, jnp.float32)
    if "moe" in p:
        h, aux = nn.moe_forward(p["moe"], cfg, h)
    else:
        h = nn.mlp_forward(p["mlp"], cfg, h)
    return x + h, aux


def _ssm_block(p, cfg, x):
    x = constrain_batch_acts(x)
    h = nn.rmsnorm(p["ln1"], x, cfg.norm_eps)
    return x + ssm_mod.mamba2_forward(p["mixer"], cfg, h)


# ---------------------------------------------------------------------------
# Training / full-sequence forward
# ---------------------------------------------------------------------------

def _embed(params, cfg, tokens):
    return constrain_batch_acts(params["embed"].astype(_ct(cfg))[tokens])


def _unembed(params, cfg, x):
    x = constrain_batch_acts(x)
    x = nn.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    w = params.get("head", None)
    if w is None:
        w = params["embed"].astype(_ct(cfg)).T
    else:
        w = w.astype(_ct(cfg))
    return jnp.einsum("bsd,dv->bsv", x, w)


def _encode(params, cfg, frames):
    """Whisper encoder over precomputed frame embeddings (conv stub)."""
    pos = nn.sinusoidal_positions(frames.shape[1], cfg.d_model)
    x = frames.astype(_ct(cfg)) + pos[None].astype(_ct(cfg))

    def body(x, lp):
        if cfg.num_heads % max(model_axis_extent(), 1) != 0:
            x = constrain_seq_model_acts(x)
        else:
            x = constrain_batch_acts(x)
        h = nn.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        x = x + nn.attention_forward(lp["attn"], cfg, h,
                                     jnp.zeros(x.shape[:2], jnp.int32),
                                     causal=False)
        h = nn.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        return x + nn.mlp_forward(lp["mlp"], cfg, h), None

    x, _ = _scan(cfg, body, x, params["enc_layers"])
    return nn.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens, *, encoder_input=None,
            pixel_embeds=None, remat: bool = False):
    """Full-sequence forward.  Returns (logits, aux_loss)."""
    B, S = tokens.shape
    x = _embed(params, cfg, tokens)
    if pixel_embeds is not None:
        x = jnp.concatenate([pixel_embeds.astype(x.dtype), x], axis=1)
        S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    if cfg.is_encdec:
        assert encoder_input is not None, "whisper needs encoder frames"
        enc_out = _encode(params, cfg, encoder_input)
        pos_dec = nn.sinusoidal_positions(S, cfg.d_model)
        x = x + pos_dec[None].astype(x.dtype)

        def body(carry, lp):
            x, aux = carry
            kv = nn.encoder_kv(lp["xattn"], cfg, enc_out)
            x, a = _attn_block(lp, cfg, x, positions, enc_kv=kv)
            return (x, aux + a), None
        body = jax.checkpoint(body) if remat else body
        (x, aux), _ = _scan(cfg, body, (x, jnp.asarray(0.0)), params["layers"])
        return _unembed(params, cfg, x), aux

    if cfg.family == "ssm":
        def body(x, lp):
            return _ssm_block(lp, cfg, x), None
        body = jax.checkpoint(body) if remat else body
        x, _ = _scan(cfg, body, x, params["layers"])
        return _unembed(params, cfg, x), jnp.asarray(0.0)

    if cfg.family == "hybrid":
        groups, per_group, tail = _hybrid_counts(cfg)
        shared = params["shared_attn"]
        stacked = jax.tree.map(
            lambda a: a.reshape((groups, per_group) + a.shape[1:]),
            params["layers"])

        def group_body(x, gp):
            def inner(x, lp):
                return _ssm_block(lp, cfg, x), None
            x, _ = _scan(cfg, inner, x, gp)
            x, _ = _attn_block(shared, cfg, x, positions)
            return x, None
        group_body = jax.checkpoint(group_body) if remat else group_body
        x, _ = _scan(cfg, group_body, x, stacked)
        if tail:
            def inner(x, lp):
                return _ssm_block(lp, cfg, x), None
            x, _ = _scan(cfg, inner, x, params["tail_layers"])
        return _unembed(params, cfg, x), jnp.asarray(0.0)

    # decoder-only attention stacks (dense / moe / vlm)
    def body(carry, lp):
        x, aux = carry
        x, a = _attn_block(lp, cfg, x, positions)
        return (x, aux + a), None
    body = jax.checkpoint(body) if remat else body
    (x, aux), _ = _scan(cfg, body, (x, jnp.asarray(0.0)), params["layers"])
    return _unembed(params, cfg, x), aux


def loss_fn(params, cfg: ModelConfig, batch, *, remat: bool = False):
    """Next-token cross entropy (f32 logsumexp) + router aux loss."""
    logits, aux = forward(params, cfg, batch["tokens"],
                          encoder_input=batch.get("frames"),
                          remat=remat)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    # SPMD-friendly label pick: one-hot contraction fuses into a masked
    # local reduce + small all-reduce over the vocab-sharded axis (a gather
    # here would force an all-gather of the full logits).
    V = logits.shape[-1]
    onehot = jax.nn.one_hot(labels, V, dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    ce = jnp.mean(lse - gold)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with per-layer caches
# ---------------------------------------------------------------------------

def init_cache(params, cfg: ModelConfig, batch: int, max_seq: int):
    """Pre-allocated decode caches, stacked over layers (scan-compatible)."""
    G, hd = cfg.num_kv_heads, cfg.head_dim
    ct = _ct(cfg)

    def attn_cache():
        return {"k": jnp.zeros((batch, max_seq, G, hd), ct),
                "v": jnp.zeros((batch, max_seq, G, hd), ct)}

    if cfg.family == "ssm":
        def one(_):
            return ssm_mod.mamba2_init_cache(cfg, batch)
        return jax.vmap(one)(jnp.arange(cfg.num_layers))
    if cfg.family == "hybrid":
        groups, per_group, tail = _hybrid_counts(cfg)
        def one(_):
            return ssm_mod.mamba2_init_cache(cfg, batch)
        caches = {
            "mamba": jax.vmap(one)(jnp.arange(groups * per_group)),
            "shared": jax.vmap(lambda _: attn_cache())(jnp.arange(groups)),
        }
        if tail:
            caches["tail"] = jax.vmap(one)(jnp.arange(tail))
        return caches
    if cfg.attention == "mla":
        def one(_):
            return {"c": jnp.zeros((batch, max_seq, cfg.mla_kv_lora_rank), ct),
                    "k_rope": jnp.zeros((batch, max_seq, cfg.mla_qk_rope_dim), ct)}
        return jax.vmap(one)(jnp.arange(cfg.num_layers))
    caches = jax.vmap(lambda _: attn_cache())(jnp.arange(cfg.num_layers))
    if cfg.is_encdec:
        return {"self": caches, "cross": None}   # cross filled at prefill
    return caches


def _decode_attn_block(lp, cfg, x, cache, pos, enc_kv=None):
    x = constrain_batch_acts(x)
    h = nn.rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if cfg.attention == "mla":
        h, cache = nn.mla_decode(lp["attn"], cfg, h, cache, pos)
    else:
        h, cache = nn.attention_decode(lp["attn"], cfg, h, cache, pos)
    x = x + h
    if enc_kv is not None:
        h = nn.rmsnorm(lp["ln_x"], x, cfg.norm_eps)
        x = x + nn.cross_attention(lp["xattn"], cfg, h, enc_kv)
    h = nn.rmsnorm(lp["ln2"], x, cfg.norm_eps)
    if "moe" in lp:
        h, _ = nn.moe_forward(lp["moe"], cfg, h)
    else:
        h = nn.mlp_forward(lp["mlp"], cfg, h)
    return x + h, cache


def decode_step(params, cfg: ModelConfig, tokens, caches, pos, *,
                encoder_out=None):
    """One new token for every sequence in the batch.

    tokens: (B, 1) int32; pos: () int32 -- current write position (cache
    holds `pos` valid entries).  Returns (logits (B, 1, V), caches).
    """
    x = _embed(params, cfg, tokens)

    if cfg.family == "ssm":
        def body(x, inp):
            lp, c = inp
            h = nn.rmsnorm(lp["ln1"], x, cfg.norm_eps)
            h, c = ssm_mod.mamba2_decode(lp["mixer"], cfg, h, c)
            return x + h, c
        x, caches = _scan(cfg, body, x, (params["layers"], caches))
        return _unembed(params, cfg, x), caches

    if cfg.family == "hybrid":
        groups, per_group, tail = _hybrid_counts(cfg)
        shared = params["shared_attn"]
        stacked = jax.tree.map(
            lambda a: a.reshape((groups, per_group) + a.shape[1:]),
            params["layers"])
        mcaches = jax.tree.map(
            lambda a: a.reshape((groups, per_group) + a.shape[1:]),
            caches["mamba"])

        def group_body(x, inp):
            gp, gc, sc = inp
            def inner(x, i2):
                lp, c = i2
                h = nn.rmsnorm(lp["ln1"], x, cfg.norm_eps)
                h, c = ssm_mod.mamba2_decode(lp["mixer"], cfg, h, c)
                return x + h, c
            x, gc = _scan(cfg, inner, x, (gp, gc))
            x, sc = _decode_attn_block(shared, cfg, x, sc, pos)
            return x, (gc, sc)
        x, (mc, sc) = _scan(cfg, group_body, x, (stacked, mcaches,
                                                   caches["shared"]))
        new = {"mamba": jax.tree.map(
                   lambda a: a.reshape((groups * per_group,) + a.shape[2:]), mc),
               "shared": sc}
        if tail:
            def inner(x, i2):
                lp, c = i2
                h = nn.rmsnorm(lp["ln1"], x, cfg.norm_eps)
                h, c = ssm_mod.mamba2_decode(lp["mixer"], cfg, h, c)
                return x + h, c
            x, tc = _scan(cfg, inner, x, (params["tail_layers"], caches["tail"]))
            new["tail"] = tc
        return _unembed(params, cfg, x), new

    if cfg.is_encdec:
        # position embedding for the *current* decode position
        S_max = jax.tree.leaves(caches["self"])[0].shape[2]
        pos_table = nn.sinusoidal_positions(S_max, cfg.d_model)
        pos_emb = jax.lax.dynamic_slice(
            pos_table, (jnp.asarray(pos, jnp.int32), jnp.zeros((), jnp.int32)),
            (1, cfg.d_model))
        x = x + pos_emb[None].astype(x.dtype)

        def body(x, inp):
            lp, c, xkv = inp
            x, c = _decode_attn_block(lp, cfg, x, c, pos, enc_kv=xkv)
            return x, c
        x, self_c = _scan(cfg, body, x, (params["layers"], caches["self"],
                                           caches["cross"]))
        return _unembed(params, cfg, x), {"self": self_c,
                                          "cross": caches["cross"]}

    def body(x, inp):
        lp, c = inp
        x, c = _decode_attn_block(lp, cfg, x, c, pos)
        return x, c
    x, caches = _scan(cfg, body, x, (params["layers"], caches))
    return _unembed(params, cfg, x), caches


def prefill(params, cfg: ModelConfig, tokens, max_seq: int, *,
            encoder_input=None):
    """Process the prompt, build decode caches.  Returns (logits, caches)."""
    B, S = tokens.shape
    x = _embed(params, cfg, tokens)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    if cfg.family == "ssm":
        def body(x, lp):
            h = nn.rmsnorm(lp["ln1"], x, cfg.norm_eps)
            h, c = ssm_mod.mamba2_forward(lp["mixer"], cfg, h,
                                          return_state=True)
            return x + h, c
        x, caches = _scan(cfg, body, x, params["layers"])
        return _unembed(params, cfg, x), caches

    if cfg.family == "hybrid":
        groups, per_group, tail = _hybrid_counts(cfg)
        shared = params["shared_attn"]
        stacked = jax.tree.map(
            lambda a: a.reshape((groups, per_group) + a.shape[1:]),
            params["layers"])

        def pad_kv(c):
            padded = {}
            for key in ("k", "v"):
                buf = jnp.zeros((B, max_seq) + c[key].shape[2:], c[key].dtype)
                padded[key] = jax.lax.dynamic_update_slice(
                    buf, c[key], (0, 0, 0, 0))
            return padded

        def group_body(x, gp):
            def inner(x, lp):
                h = nn.rmsnorm(lp["ln1"], x, cfg.norm_eps)
                h, c = ssm_mod.mamba2_forward(lp["mixer"], cfg, h,
                                              return_state=True)
                return x + h, c
            x, gc = _scan(cfg, inner, x, gp)
            h = nn.rmsnorm(shared["ln1"], x, cfg.norm_eps)
            h, kv = nn.attention_forward(shared["attn"], cfg, h, positions,
                                         causal=True, return_cache=True)
            x = x + h
            h = nn.rmsnorm(shared["ln2"], x, cfg.norm_eps)
            x = x + nn.mlp_forward(shared["mlp"], cfg, h)
            return x, (gc, pad_kv(kv))
        x, (mc, sc) = _scan(cfg, group_body, x, stacked)
        caches = {"mamba": jax.tree.map(
                      lambda a: a.reshape((groups * per_group,) + a.shape[2:]), mc),
                  "shared": sc}
        if tail:
            def inner(x, lp):
                h = nn.rmsnorm(lp["ln1"], x, cfg.norm_eps)
                h, c = ssm_mod.mamba2_forward(lp["mixer"], cfg, h,
                                              return_state=True)
                return x + h, c
            x, tc = _scan(cfg, inner, x, params["tail_layers"])
            caches["tail"] = tc
        return _unembed(params, cfg, x), caches

    enc_out = None
    if cfg.is_encdec:
        assert encoder_input is not None
        enc_out = _encode(params, cfg, encoder_input)
        pos_dec = nn.sinusoidal_positions(S, cfg.d_model)
        x = x + pos_dec[None].astype(x.dtype)

    def pad_cache(c):
        out = {}
        for key, buf_v in c.items():
            buf = jnp.zeros((B, max_seq) + buf_v.shape[2:], buf_v.dtype)
            idx = (0,) * buf.ndim
            out[key] = jax.lax.dynamic_update_slice(buf, buf_v, idx)
        return out

    def body(x, lp):
        # Same propagation pin as _attn_block (P2/P5 in EXPERIMENTS.md).
        if cfg.num_heads % max(model_axis_extent(), 1) != 0:
            x = constrain_seq_model_acts(x)
        else:
            x = constrain_batch_acts(x)
        h = nn.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        if cfg.attention == "mla":
            h, c = nn.mla_forward(lp["attn"], cfg, h, positions,
                                  return_cache=True)
        else:
            h, c = nn.attention_forward(lp["attn"], cfg, h, positions,
                                        return_cache=True)
        x = x + h
        xkv = None
        if cfg.is_encdec:
            hh = nn.rmsnorm(lp["ln_x"], x, cfg.norm_eps)
            xkv = nn.encoder_kv(lp["xattn"], cfg, enc_out)
            x = x + nn.cross_attention(lp["xattn"], cfg, hh, xkv)
        h = nn.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if "moe" in lp:
            h, _ = nn.moe_forward(lp["moe"], cfg, h)
        else:
            h = nn.mlp_forward(lp["mlp"], cfg, h)
        out = (pad_cache(c), xkv) if cfg.is_encdec else pad_cache(c)
        return x + h, out

    x, caches = _scan(cfg, body, x, params["layers"])
    logits = _unembed(params, cfg, x)
    if cfg.is_encdec:
        self_c, cross_c = caches
        return logits, {"self": self_c, "cross": cross_c}
    return logits, caches
