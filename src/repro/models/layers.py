"""Model building blocks shared by all 10 architectures.

Plain-pytree parameters (dicts of jnp arrays) + pure apply functions; no
framework dependency.  Parameter tensors keep semantic axes separate
(e.g. wq: (d_model, heads, head_dim)) so dist/sharding.py can map logical
axes -> mesh axes by key-path pattern.

Numerics: matmuls in cfg.compute_dtype (bf16 on TPU), softmax/norm/router
in float32.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


def _ct(cfg):
    return jnp.dtype(cfg.compute_dtype)


def _init(rng, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 1 else 1
    if scale is None:
        scale = fan_in ** -0.5
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def rmsnorm_headwise(scale, x, eps: float):
    """Per-head q/k norm (qwen3): x (..., heads, head_dim)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE + none)
# ---------------------------------------------------------------------------

def _rope_freqs(head_dim: int, theta: float, dtype=jnp.float32):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=dtype) / half))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) int32."""
    half = x.shape[-1] // 2
    freqs = _rope_freqs(x.shape[-1], theta)
    ang = positions[..., None].astype(jnp.float32) * freqs        # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: Tuple[int, int, int]):
    """Qwen2-VL M-RoPE: rotary dims split into (t, h, w) sections, each
    rotated by its own position stream.  positions3: (3, B, S)."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = _rope_freqs(x.shape[-1], theta)                       # (half,)
    # Build a per-dim position by selecting the section's position stream.
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                        total_repeat_length=half)                 # (half,)
    pos = positions3[sec_id, :, :]                                # (half,B,S)
    ang = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs    # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int, dtype=jnp.float32):
    """Whisper-style fixed sinusoidal position embedding (S, D)."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = jnp.arange(seq_len)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# GQA attention (with optional qk-norm, qkv bias, rope variants, KV cache)
# ---------------------------------------------------------------------------

def init_attention(rng, cfg) -> Params:
    d, H, G, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 6)
    p = {
        "wq": _init(ks[0], (d, H, hd), _dt(cfg)),
        "wk": _init(ks[1], (d, G, hd), _dt(cfg)),
        "wv": _init(ks[2], (d, G, hd), _dt(cfg)),
        "wo": _init(ks[3], (H, hd, d), _dt(cfg), scale=(H * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), _dt(cfg))
        p["bk"] = jnp.zeros((G, hd), _dt(cfg))
        p["bv"] = jnp.zeros((G, hd), _dt(cfg))
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), _dt(cfg))
        p["k_norm"] = jnp.ones((hd,), _dt(cfg))
    return p


def _project_qkv(p, cfg, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(_ct(cfg)))
    k = jnp.einsum("bsd,dgk->bsgk", x, p["wk"].astype(_ct(cfg)))
    v = jnp.einsum("bsd,dgk->bsgk", x, p["wv"].astype(_ct(cfg)))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(_ct(cfg))
        k = k + p["bk"].astype(_ct(cfg))
        v = v + p["bv"].astype(_ct(cfg))
    if cfg.qk_norm:
        q = rmsnorm_headwise(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_headwise(p["k_norm"], k, cfg.norm_eps)
    if cfg.rope_style == "standard":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_style == "mrope":
        pos3 = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def _sdpa(q, k, v, mask, cfg):
    """q: (B,S,H,hd); k,v: (B,T,G,hd); grouped heads; f32 softmax."""
    B, S, H, hd = q.shape
    G = k.shape[2]
    rep = H // G
    qg = q.reshape(B, S, G, rep, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bsgrk,btgk->bgrst", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, jnp.asarray(-1e30, jnp.float32))
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrst,btgk->bsgrk", probs, v)
    return out.reshape(B, S, H, hd)


# Sequences at or above this length use the online-softmax KV-block scan
# (never materializes the S x T score matrix -- peak is S x CHUNK).
FLASH_THRESHOLD = 8192
FLASH_KV_CHUNK = 1024


def _sdpa_chunked(q, k, v, cfg, *, causal: bool):
    """Memory-efficient attention: lax.scan over KV chunks with running
    (max, denominator, accumulator) -- the FlashAttention recurrence in
    pure JAX.  Peak score tensor is (B, G, rep, S, CHUNK) instead of
    (..., S, T).  Each chunk body is rematerialized in the backward pass.
    """
    B, S, H, hd = q.shape
    G = k.shape[2]
    rep = H // G
    Tlen = k.shape[1]
    C = min(FLASH_KV_CHUNK, Tlen)
    assert Tlen % C == 0, (Tlen, C)
    nchunks = Tlen // C
    qg = q.reshape(B, S, G, rep, hd)
    scale = hd ** -0.5
    kc = jnp.moveaxis(k.reshape(B, nchunks, C, G, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nchunks, C, G, hd), 1, 0)
    qpos = jnp.arange(S)

    @jax.checkpoint
    def body(carry, inp):
        acc, m, denom, t0 = carry
        kt, vt = inp
        s = jnp.einsum("bsgrk,btgk->bgrst", qg, kt).astype(jnp.float32) * scale
        if causal:
            kpos = t0 + jnp.arange(C)
            msk = qpos[:, None] >= kpos[None, :]
            s = jnp.where(msk[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        denom = denom * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bgrst,btgk->bsgrk", p.astype(q.dtype), vt)
        acc = acc * jnp.moveaxis(alpha, (1, 2, 3), (2, 3, 1))[..., None] + pv
        return (acc, m_new, denom, t0 + C), None

    acc0 = jnp.zeros((B, S, G, rep, hd), jnp.float32)
    m0 = jnp.full((B, G, rep, S), -jnp.inf, jnp.float32)
    d0 = jnp.zeros((B, G, rep, S), jnp.float32)
    (acc, m, denom, _), _ = jax.lax.scan(
        body, (acc0, m0, d0, jnp.asarray(0, jnp.int32)), (kc, vc))
    denom = jnp.moveaxis(denom, (1, 2, 3), (2, 3, 1))
    out = acc / jnp.maximum(denom, 1e-30)[..., None]
    return out.reshape(B, S, H, hd).astype(q.dtype)


def attention_forward(p, cfg, x, positions, *, causal=True, return_cache=False):
    """Full-sequence attention (train / prefill)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    if S >= FLASH_THRESHOLD and k.shape[1] % FLASH_KV_CHUNK == 0:
        out = _sdpa_chunked(q, k, v, cfg, causal=causal)
    else:
        mask = None
        if causal:
            it = jnp.arange(S)
            mask = (it[None, :, None] >= it[None, None, :])[:, None, None, :, :]
        out = _sdpa(q, k, v, mask, cfg)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(_ct(cfg)))
    if return_cache:
        return y, {"k": k, "v": v}
    return y


def attention_decode(p, cfg, x, cache, pos):
    """One-token decode against a pre-allocated KV cache.

    x: (B, 1, D); cache: {"k","v"}: (B, S_max, G, hd); pos: () int32.
    """
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    z = jnp.zeros((), jnp.asarray(pos).dtype)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (z, pos, z, z))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (z, pos, z, z))
    S_max = k.shape[1]
    mask = (jnp.arange(S_max)[None, :] <= pos)[None, None, None, :, :]
    out = _sdpa(q, k, v, mask, cfg)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(_ct(cfg)))
    return y, {"k": k, "v": v}


def init_cross_attention(rng, cfg) -> Params:
    return init_attention(rng, cfg)


def cross_attention(p, cfg, x, kv_cache):
    """Decoder cross-attention against precomputed encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(_ct(cfg)))
    out = _sdpa(q, kv_cache["k"], kv_cache["v"], None, cfg)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(_ct(cfg)))


def encoder_kv(p, cfg, enc_out):
    k = jnp.einsum("bsd,dgk->bsgk", enc_out, p["wk"].astype(_ct(cfg)))
    v = jnp.einsum("bsd,dgk->bsgk", enc_out, p["wv"].astype(_ct(cfg)))
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA: multi-head latent attention (minicpm3 / deepseek-v2 style)
# ---------------------------------------------------------------------------

def init_mla(rng, cfg) -> Params:
    d, H = cfg.d_model, cfg.num_heads
    rq, rkv = cfg.mla_q_lora_rank, cfg.mla_kv_lora_rank
    dn, dr, dv = cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim, cfg.mla_v_head_dim
    ks = jax.random.split(rng, 8)
    return {
        "wq_a": _init(ks[0], (d, rq), _dt(cfg)),
        "q_a_norm": jnp.ones((rq,), _dt(cfg)),
        "wq_b": _init(ks[1], (rq, H, dn + dr), _dt(cfg)),
        "wkv_a": _init(ks[2], (d, rkv + dr), _dt(cfg)),
        "kv_a_norm": jnp.ones((rkv,), _dt(cfg)),
        "wk_b": _init(ks[3], (rkv, H, dn), _dt(cfg)),
        "wv_b": _init(ks[4], (rkv, H, dv), _dt(cfg)),
        "wo": _init(ks[5], (H, dv, d), _dt(cfg), scale=(H * dv) ** -0.5),
    }


def _mla_latents(p, cfg, x, positions):
    """Compressed KV latent c (B,S,rkv) + shared rotary key (B,S,1,dr)."""
    dr = cfg.mla_qk_rope_dim
    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(_ct(cfg)))
    c, k_rope = kv_a[..., :cfg.mla_kv_lora_rank], kv_a[..., cfg.mla_kv_lora_rank:]
    c = rmsnorm({"scale": p["kv_a_norm"]}, c, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return c, k_rope


def _mla_queries(p, cfg, x, positions):
    dn, dr = cfg.mla_qk_nope_dim, cfg.mla_qk_rope_dim
    q_a = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(_ct(cfg)))
    q_a = rmsnorm({"scale": p["q_a_norm"]}, q_a, cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_a, p["wq_b"].astype(_ct(cfg)))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_chunked(q_lat, q_rope, c, kr, scale, *, causal: bool):
    """Online-softmax MLA attention over latent chunks (FlashAttention
    recurrence in latent space).  q_lat: (B,S,H,r); q_rope: (B,S,H,dr);
    c: (B,T,r); kr: (B,T,dr).  Returns ctx_lat (B,S,H,r)."""
    B, S, H, r = q_lat.shape
    Tlen = c.shape[1]
    C = min(FLASH_KV_CHUNK, Tlen)
    assert Tlen % C == 0, (Tlen, C)
    nchunks = Tlen // C
    cc = jnp.moveaxis(c.reshape(B, nchunks, C, r), 1, 0)
    krc = jnp.moveaxis(kr.reshape(B, nchunks, C, kr.shape[-1]), 1, 0)
    qpos = jnp.arange(S)

    @jax.checkpoint
    def body(carry, inp):
        acc, m, denom, t0 = carry
        ct, krt = inp
        s = (jnp.einsum("bshr,btr->bhst", q_lat, ct)
             + jnp.einsum("bshk,btk->bhst", q_rope, krt)
             ).astype(jnp.float32) * scale
        if causal:
            kpos = t0 + jnp.arange(C)
            msk = qpos[:, None] >= kpos[None, :]
            s = jnp.where(msk[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))          # (B,H,S)
        alpha = jnp.exp(m - m_new)
        pr = jnp.exp(s - m_new[..., None])
        denom = denom * alpha + jnp.sum(pr, axis=-1)
        pv = jnp.einsum("bhst,btr->bshr", pr.astype(q_lat.dtype), ct)
        acc = acc * jnp.moveaxis(alpha, (1, 2), (2, 1))[..., None] + pv
        return (acc, m_new, denom, t0 + C), None

    acc0 = jnp.zeros((B, S, H, r), jnp.float32)
    m0 = jnp.full((B, H, S), -jnp.inf, jnp.float32)
    d0 = jnp.zeros((B, H, S), jnp.float32)
    (acc, m, denom, _), _ = jax.lax.scan(
        body, (acc0, m0, d0, jnp.asarray(0, jnp.int32)), (cc, krc))
    denom = jnp.moveaxis(denom, (1, 2), (2, 1))
    return (acc / jnp.maximum(denom, 1e-30)[..., None]).astype(q_lat.dtype)


def mla_forward(p, cfg, x, positions, *, causal=True, return_cache=False):
    """Latent-space attention: scores/context computed against the cached
    latent c, with the nope-key projection absorbed into the query
    (the standard MLA decode identity, applied at train time too so the
    exact same einsums are exercised everywhere).  Long sequences use the
    online-softmax chunked path (never materializes the S x T scores)."""
    B, S, _ = x.shape
    dn = cfg.mla_qk_nope_dim
    c, k_rope = _mla_latents(p, cfg, x, positions)
    q_nope, q_rope = _mla_queries(p, cfg, x, positions)
    # Absorb W_kb: q~ = W_kb^T q_nope  -> (B,S,H,rkv)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"].astype(_ct(cfg)))
    scale = (dn + cfg.mla_qk_rope_dim) ** -0.5
    if S >= FLASH_THRESHOLD and S % FLASH_KV_CHUNK == 0:
        ctx_lat = _mla_chunked(q_lat, q_rope, c, k_rope[:, :, 0, :],
                               scale, causal=causal)
    else:
        scores = (jnp.einsum("bshr,btr->bhst", q_lat, c)
                  + jnp.einsum("bshk,btgk->bhst", q_rope,
                               jnp.broadcast_to(k_rope, k_rope.shape))
                  ).astype(jnp.float32) * scale
        if causal:
            it = jnp.arange(S)
            scores = jnp.where(
                it[None, None, :, None] >= it[None, None, None, :],
                scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        ctx_lat = jnp.einsum("bhst,btr->bshr", probs, c)
    out = jnp.einsum("bshr,rhv->bshv", ctx_lat, p["wv_b"].astype(_ct(cfg)))
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(_ct(cfg)))
    if return_cache:
        return y, {"c": c, "k_rope": k_rope[:, :, 0, :]}
    return y


def mla_decode(p, cfg, x, cache, pos):
    """One-token MLA decode: the cache holds only the latent + rotary key --
    this is the memory win MLA exists for (rkv + dr per token, not 2*H*hd)."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    c_new, k_rope_new = _mla_latents(p, cfg, x, positions)
    q_nope, q_rope = _mla_queries(p, cfg, x, positions)
    z0 = jnp.zeros((), jnp.asarray(pos).dtype)
    c = jax.lax.dynamic_update_slice(cache["c"], c_new.astype(cache["c"].dtype),
                                     (z0, pos, z0))
    kr = jax.lax.dynamic_update_slice(cache["k_rope"],
                                      k_rope_new[:, :, 0, :].astype(cache["k_rope"].dtype),
                                      (z0, pos, z0))
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"].astype(_ct(cfg)))
    scale = (cfg.mla_qk_nope_dim + cfg.mla_qk_rope_dim) ** -0.5
    scores = (jnp.einsum("bshr,btr->bhst", q_lat, c)
              + jnp.einsum("bshk,btk->bhst", q_rope, kr)).astype(jnp.float32) * scale
    S_max = c.shape[1]
    mask = (jnp.arange(S_max) <= pos)[None, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx_lat = jnp.einsum("bhst,btr->bshr", probs, c)
    out = jnp.einsum("bshr,rhv->bshv", ctx_lat, p["wv_b"].astype(_ct(cfg)))
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(_ct(cfg)))
    return y, {"c": c, "k_rope": kr}


# ---------------------------------------------------------------------------
# Dense SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(rng, cfg, d_ff=None) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": _init(ks[0], (d, f), _dt(cfg)),
        "w_up": _init(ks[1], (d, f), _dt(cfg)),
        "w_down": _init(ks[2], (f, d), _dt(cfg), scale=f ** -0.5),
    }


def mlp_forward(p, cfg, x):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(_ct(cfg)))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(_ct(cfg)))
    h = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(_ct(cfg)))


# ---------------------------------------------------------------------------
# MoE with top-k routing, capacity + sort-based dispatch (EP-shardable)
# ---------------------------------------------------------------------------

def init_moe(rng, cfg) -> Params:
    d, E, f = cfg.d_model, cfg.moe_num_experts, cfg.moe_d_ff
    ks = jax.random.split(rng, 5)
    p = {
        "router": _init(ks[0], (d, E), jnp.float32),
        "w_gate": _init(ks[1], (E, d, f), _dt(cfg)),
        "w_up": _init(ks[2], (E, d, f), _dt(cfg)),
        "w_down": _init(ks[3], (E, f, d), _dt(cfg), scale=f ** -0.5),
    }
    if cfg.moe_shared_expert:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.d_ff)
    return p


def _moe_group_dispatch(xg, eidg, gvg, cap, E):
    """Per-group sort-based dispatch (vmapped over DP groups).

    xg: (Tg, D); eidg/gvg: (Tg*k,).  Returns (buf (E, cap, D), combine
    metadata).  All indexing stays inside the group so the vmapped scatter
    has an explicit batch dim GSPMD can partition over 'data' (a global
    scatter here caused involuntary full replication -- see DESIGN.md).
    """
    Tk = eidg.shape[0]
    order = jnp.argsort(eidg, stable=True)
    eid_s = eidg[order]
    gv_s = gvg[order]
    tid_s = (order // (Tk // xg.shape[0]))
    first = jnp.searchsorted(eid_s, eid_s, side="left")
    slot = jnp.arange(Tk) - first
    keep = slot < cap
    slot_c = jnp.where(keep, slot, cap)
    buf = jnp.zeros((E, cap + 1, xg.shape[1]), xg.dtype)
    buf = buf.at[eid_s, slot_c].set(xg[tid_s], mode="drop")
    return buf[:, :cap], (eid_s, slot_c, tid_s, gv_s, keep)


def _moe_group_combine(out, meta, Tg, D, dtype):
    eid_s, slot_c, tid_s, gv_s, keep = meta
    cap = out.shape[1]
    y_s = jnp.where(keep[:, None],
                    out[eid_s, jnp.minimum(slot_c, cap - 1)],
                    jnp.zeros((), out.dtype))
    y_s = y_s * gv_s[:, None].astype(out.dtype)
    y = jnp.zeros((Tg, D), dtype)
    return y.at[tid_s].add(y_s.astype(dtype))


def moe_forward(p, cfg, x):
    """Returns (y, aux_loss).  Grouped sort-based capacity dispatch:

      tokens -> top-k experts -> per-DP-group stable sort by expert id ->
      per-expert contiguous slots (capacity C, overflow dropped) -> batched
      expert matmuls (G, E, C, d) -> combine weighted by router gates.

    The groups axis G equals the data-parallel shard count (1 on a single
    device), so dispatch/combine scatters are *batched* over the sharded
    dim and every index stays shard-local; the expert axis E shards over
    'model' (EP).
    """
    from repro.dist.sharding import dp_axis_extent

    B, S, D = x.shape
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    T = B * S
    G = dp_axis_extent()
    if T % G != 0:
        G = 1
    Tg = T // G
    xf = x.reshape(G, Tg, D)

    logits = jnp.einsum("gtd,de->gte", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)               # (G,Tg,k)
    gate_vals = gate_vals / jnp.clip(jnp.sum(gate_vals, -1, keepdims=True),
                                     1e-9)

    # Load-balance auxiliary loss (Switch-style).
    me = jnp.mean(probs, axis=(0, 1))                              # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], E), axis=(0, 1))
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    cap = int(max(1, round(Tg * k / E * cfg.moe_capacity_factor)))

    eid = expert_idx.reshape(G, Tg * k)
    gv = gate_vals.reshape(G, Tg * k)

    buf, meta = jax.vmap(
        lambda xg, eg, gg: _moe_group_dispatch(xg, eg, gg, cap, E)
    )(xf, eid, gv)                                                  # (G,E,cap,D)

    ct = _ct(cfg)
    g = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(ct))
    u = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(ct))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(ct))

    y = jax.vmap(
        lambda og, mg: _moe_group_combine(og, mg, Tg, D, x.dtype)
    )(out, meta)                                                    # (G,Tg,D)
    y_flat = y.reshape(T, D)

    if cfg.moe_shared_expert:
        y_flat = y_flat + mlp_forward(
            p["shared"], cfg, x.reshape(1, T, D))[0].astype(x.dtype)

    return y_flat.reshape(B, S, D), aux
