"""Mamba2 (SSD / state-space duality) block, chunked, with O(1) decode state.

Implements the SSD algorithm of arXiv:2405.21060: scalar-identity state
transition per head, chunked into intra-chunk (quadratic within chunk,
attention-like) and inter-chunk (recurrent state passing) parts.

Train/prefill:  y = SSD(x*dt, exp(dt*A), B, C) computed chunk-parallel.
Decode:         S <- a * S + dt * (B (x) x);  y = C . S  -- O(1) per token,
                which is why the long_500k shape runs only on SSM/hybrid
                architectures (DESIGN.md skip rule).

Shapes: heads H, head dim P (H*P = expand*d_model), state N (single group).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


def _ct(cfg):
    return jnp.dtype(cfg.compute_dtype)


def _dims(cfg):
    d = cfg.d_model
    dn = cfg.ssm_expand * d
    H = cfg.ssm_num_heads
    P = dn // H
    N = cfg.ssm_state_dim
    return d, dn, H, P, N


def init_mamba2(rng, cfg) -> Params:
    d, dn, H, P, N = _dims(cfg)
    conv_dim = dn + 2 * N
    ks = jax.random.split(rng, 6)
    scale = d ** -0.5
    return {
        # in_proj -> [z (dn), x (dn), B (N), C (N), dt (H)]
        "w_in": (jax.random.normal(ks[0], (d, 2 * dn + 2 * N + H), jnp.float32)
                 * scale).astype(_dt(cfg)),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_dim),
                                     jnp.float32) * 0.5).astype(_dt(cfg)),
        "conv_b": jnp.zeros((conv_dim,), _dt(cfg)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": jnp.ones((dn,), _dt(cfg)),
        "w_out": (jax.random.normal(ks[2], (dn, d), jnp.float32)
                  * dn ** -0.5).astype(_dt(cfg)),
    }


def _split_in(cfg, proj):
    d, dn, H, P, N = _dims(cfg)
    z = proj[..., :dn]
    xbc = proj[..., dn: 2 * dn + 2 * N]
    dt = proj[..., 2 * dn + 2 * N:]
    return z, xbc, dt


def _causal_conv(xbc, w, b, width):
    """Depthwise causal conv over time: xbc (B, L, C)."""
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return jax.nn.silu(out + b[None, None, :])


def _gated_norm(y, z, scale, eps):
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32))


def mamba2_forward(p, cfg, x, *, return_state: bool = False):
    """Chunked SSD scan.  x: (B, L, D) -> (B, L, D)."""
    d, dn, H, P, N = _dims(cfg)
    B_, L, _ = x.shape
    Q = min(cfg.ssm_chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    proj = jnp.einsum("bld,de->ble", x, p["w_in"].astype(_ct(cfg)))
    z, xbc_pre, dt_raw = _split_in(cfg, proj)
    xbc = _causal_conv(xbc_pre, p["conv_w"].astype(_ct(cfg)),
                       p["conv_b"].astype(_ct(cfg)), cfg.ssm_conv_width)
    xs = xbc[..., :dn].reshape(B_, L, H, P)
    Bm = xbc[..., dn: dn + N]                                  # (B,L,N)
    Cm = xbc[..., dn + N:]                                     # (B,L,N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,L,H)
    A = -jnp.exp(p["A_log"])                                   # (H,) negative
    # log decay per step: la = dt * A  (<= 0)
    la = dt * A[None, None, :]                                 # (B,L,H)

    # chunk views
    lac = la.reshape(B_, nc, Q, H)
    cum = jnp.cumsum(lac, axis=2)                              # (B,nc,Q,H)
    total = cum[:, :, -1, :]                                   # (B,nc,H)
    xdt = (xs.astype(jnp.float32) * dt[..., None]).reshape(B_, nc, Q, H, P)
    Bc = Bm.astype(jnp.float32).reshape(B_, nc, Q, N)
    Cc = Cm.astype(jnp.float32).reshape(B_, nc, Q, N)

    # ---- intra-chunk (attention-like, strictly causal incl. diagonal) ----
    # M[t,s] = exp(cum_t - cum_s) for s <= t.  Mask BEFORE the exp: the
    # discarded (s > t) entries have gap > 0 and exp(gap) overflows, which
    # poisons the backward pass (inf * 0 -> NaN in the where-grad).
    gap = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (B,nc,Q,Q,H)
    it = jnp.arange(Q)
    tri = (it[:, None] >= it[None, :])[None, None, :, :, None]
    gap = jnp.where(tri, gap, -jnp.inf)
    Mmat = jnp.exp(gap)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)             # (B,nc,Q,Q)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp",
                         scores, Mmat, xdt)

    # ---- inter-chunk: local end-states then sequential chunk scan --------
    # local state: S_c = sum_s exp(cum_Q - cum_s) * B_s (x) xdt_s
    wgt = jnp.exp(total[:, :, None, :] - cum)                  # (B,nc,Q,H)
    S_loc = jnp.einsum("bcqh,bcqn,bcqhp->bchnp", wgt, Bc, xdt)  # (B,nc,H,N,P)

    decay = jnp.exp(total)                                     # (B,nc,H)

    def scan_fn(S_prev, inp):
        S_l, dec = inp
        S_new = S_l + dec[:, :, None, None] * S_prev
        return S_new, S_prev

    S0 = jnp.zeros((B_, H, N, P), jnp.float32)
    S_last, S_prevs = jax.lax.scan(
        scan_fn,
        S0,
        (jnp.moveaxis(S_loc, 1, 0), jnp.moveaxis(decay, 1, 0)))
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)                      # (B,nc,H,N,P)

    # y_inter[t] = exp(cum_t) * C_t . S_prev(chunk)
    y_inter = jnp.einsum("bcqh,bcqn,bchnp->bcqhp",
                         jnp.exp(cum), Cc, S_prevs)

    y = (y_intra + y_inter).reshape(B_, L, H, P)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B_, L, dn)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps).astype(_ct(cfg))
    out = jnp.einsum("ble,ed->bld", y, p["w_out"].astype(_ct(cfg)))
    if return_state:
        w = cfg.ssm_conv_width
        cache = {"conv": xbc_pre[:, L - (w - 1):, :].astype(jnp.float32),
                 "ssm": S_last}
        return out, cache
    return out


def mamba2_init_cache(cfg, batch: int, dtype=jnp.float32):
    d, dn, H, P, N = _dims(cfg)
    conv_dim = dn + 2 * N
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
    }


def mamba2_decode(p, cfg, x, cache):
    """One-token recurrent step.  x: (B, 1, D)."""
    d, dn, H, P, N = _dims(cfg)
    B_ = x.shape[0]
    proj = jnp.einsum("bld,de->ble", x, p["w_in"].astype(_ct(cfg)))
    z, xbc_new, dt_raw = _split_in(cfg, proj)

    # causal conv over the rolling window
    window = jnp.concatenate([cache["conv"], xbc_new.astype(cache["conv"].dtype)],
                             axis=1)                           # (B, W, C)
    w = p["conv_w"].astype(_ct(cfg))
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(_ct(cfg)), w) \
        + p["conv_b"].astype(_ct(cfg))
    xbc = jax.nn.silu(conv_out)[:, None, :]                    # (B,1,C)
    new_conv = window[:, 1:, :]

    xs = xbc[..., :dn].reshape(B_, H, P)
    Bm = xbc[:, 0, dn: dn + N]
    Cm = xbc[:, 0, dn + N:]

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A[None, :])                               # (B,H)
    xdt = xs.astype(jnp.float32) * dt[..., None]               # (B,H,P)

    S = cache["ssm"] * a[:, :, None, None] \
        + jnp.einsum("bn,bhp->bhnp", Bm.astype(jnp.float32), xdt)
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), S)
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B_, 1, dn)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps).astype(_ct(cfg))
    out = jnp.einsum("ble,ed->bld", y, p["w_out"].astype(_ct(cfg)))
    return out, {"conv": new_conv, "ssm": S}
