"""Version compatibility shims for the jax API surface this repo targets.

The codebase is written against the current jax API (`jax.shard_map`,
`jax.sharding.AxisType`); older runtimes (0.4.x) ship the same
functionality under `jax.experimental.shard_map` with `check_rep`/`auto`
spellings.  Everything funnels through here so call sites stay on the
modern spelling.
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool | None = None):
    """`jax.shard_map` with the modern kwargs on any jax version.

    axis_names: the subset of mesh axes mapped Manually (the rest stay
    Auto); None means all axes are Manual.
    check_vma:  replication checking (older jax calls this check_rep).
    """
    check = True if check_vma is None else check_vma
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = frozenset(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check, auto=auto)


def make_mesh(axis_shapes, axis_names, *, auto_axes: bool = True):
    """`jax.make_mesh` requesting Auto axis types when the runtime
    supports explicit axis types (newer jax); plain mesh otherwise."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(AxisType.Auto,) * len(axis_names))
    except (ImportError, TypeError, AttributeError):
        if hasattr(jax, "make_mesh"):
            return jax.make_mesh(axis_shapes, axis_names)
        from jax.sharding import Mesh
        import numpy as _np
        return Mesh(_np.array(jax.devices()[: _np.prod(axis_shapes)])
                    .reshape(axis_shapes), axis_names)
