"""Pre-jax-import host-device setup (jax-free on purpose).

Forcing XLA host CPU devices lets the batched plan executor shard
problem batches across cores (`repro.core.plan._batch_sharding`).  The
flag only takes effect if it is set BEFORE the first ``import jax``
anywhere in the process, so this module must not import jax and callers
(benchmark driver, examples) must invoke it before their jax imports.
"""

from __future__ import annotations

import os


import sys


def force_host_devices(n: int | None = None) -> int:
    """Set ``--xla_force_host_platform_device_count=n`` in XLA_FLAGS.

    n defaults to ``os.cpu_count()``; n <= 1 leaves the environment
    untouched.  If XLA_FLAGS already configures the flag, the existing
    setting wins (we never rewrite user flags) -- but an explicitly
    requested count that differs gets a stderr warning instead of a
    silent no-op.  Returns the count now in effect via this call
    (0 when nothing was changed).
    """
    explicit = n is not None
    if n is None:
        n = os.cpu_count() or 1
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        if explicit:
            print(f"[hostdev] XLA_FLAGS already configures host devices; "
                  f"requested count {n} ignored ({flags!r})",
                  file=sys.stderr)
        return 0
    if n <= 1:
        return 0
    os.environ["XLA_FLAGS"] = \
        (flags + f" --xla_force_host_platform_device_count={n}").strip()
    return n
