"""Host-sharded data pipeline with background prefetch.

Each host process owns `host_batch = global_batch / num_hosts`; the
device-level sharding of the resulting array is applied by the trainer via
NamedSharding (batch axis over ("pod","data")).  A small thread pool keeps
`prefetch` batches ahead of the training step; batches are a pure function
of (seed, step, shard) so resume-at-step-k is exact.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np

from repro.data.synthetic import SyntheticTokens


def synthetic_batch_specs(cfg, shape, dtype=np.int32):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    import jax.numpy as jnp
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.is_encdec:
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    return specs


class DataPipeline:
    def __init__(self, source: SyntheticTokens, *, global_batch: int,
                 num_shards: int = 1, shard_id: int = 0,
                 prefetch: int = 2, start_step: int = 0,
                 extra_fn=None):
        assert global_batch % num_shards == 0
        self.source = source
        self.host_batch = global_batch // num_shards
        self.shard_id = shard_id
        self.prefetch = prefetch
        self.step = start_step
        self.extra_fn = extra_fn
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _produce(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch(step, self.shard_id, self.host_batch)
            if self.extra_fn is not None:
                batch.update(self.extra_fn(step, self.shard_id,
                                           self.host_batch))
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def start(self):
        if self._thread is None:
            self._thread = threading.Thread(target=self._produce, daemon=True)
            self._thread.start()
        return self

    def __iter__(self) -> Iterator[dict]:
        self.start()
        while True:
            step, batch = self._q.get()
            yield batch

    def stop(self):
        self._stop.set()
