from repro.data.pipeline import DataPipeline, synthetic_batch_specs
from repro.data.synthetic import SyntheticTokens

__all__ = ["DataPipeline", "SyntheticTokens", "synthetic_batch_specs"]
