"""Deterministic synthetic token source.

Structured enough that a model can actually learn (Zipfian unigram
distribution + short-range Markov coupling) and bit-reproducible for a
given (seed, step, host_shard): the stream is a pure function of its
coordinates, which is what makes elastic restarts and straggler re-issue
trivially consistent (no iterator state to checkpoint -- only the step).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2
    markov_strength: float = 0.5

    def batch(self, step: int, shard: int, batch_size: int) -> dict:
        """(batch_size, seq_len) tokens + next-token labels."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        B, S, V = batch_size, self.seq_len, self.vocab_size
        # Zipf-ish unigram draw, clipped into vocab.
        base = rng.zipf(self.zipf_a, size=(B, S + 1)) % V
        # Markov coupling: with prob markov_strength, token t+1 is a
        # deterministic function of token t (learnable signal).
        nxt = (base[:, :-1] * 2654435761 + 12345) % V
        mask = rng.random((B, S)) < self.markov_strength
        toks = base[:, 1:].copy()
        toks[mask] = nxt[mask]
        tokens = np.concatenate([base[:, :1], toks[:, :-1]], axis=1)
        labels = toks
        return {"tokens": tokens.astype(np.int32),
                "labels": labels.astype(np.int32)}
