"""Batch-first solve plans: static tree shape + bucketed compile cache.

Every solve -- single or batched -- goes through a :class:`SolvePlan`.
A plan captures everything *static* about a solve up front:

  * the padded problem size ``N = leaf * 2^L`` and tree depth ``L``,
  * the per-level rank-one coupling indices (where each merge's split
    off-diagonal lives in ``e``),
  * the selected-row track slots (2 boundary rows, +1 tracked original
    row when boundary output is requested),
  * the batch bucket: request batches are rounded **up to the next power
    of two**, so arbitrary traffic (B = 1, 3, 5, 97, ...) lands on a
    handful of compiled executables instead of one trace per batch size.

and owns the process-wide cache of compiled executables, keyed on

    (padded N, leaf, batch bucket, dtype, chunk, niter, use_zhat,
     return_boundary, tol_factor, stream_threshold, deflate_budget,
     resident_threshold, fused, shards, compress_halo, precision,
     refine_tol)

Two requests that differ only in original size n (same padded bucket) or
only in batch size (same power-of-two bucket) share one executable: the
tracked-row index is a *traced* per-problem input and short batches are
padded with trivial dummy problems, both sliced away on exit.  This is
what lets the solver run as a service under real traffic -- steady-state
request handling is cache lookups + one device launch, never a retrace.

``stream_threshold=None``, ``deflate_budget=None`` and
``resident_threshold=None`` are resolved to backend-aware concrete values
at plan-construction time so the cache key is always fully concrete.

Memory model: persistent state for a bucket of B problems is B * O(N)
(lam + selected rows + inputs), never B * O(N^2) -- the paper's O(n)
boundary-row state is exactly what makes the batched front door viable.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core import br_dc as _br
from repro.core import guard as _guard
from repro.core import merge as _merge
from repro.core import secular as _sec
from repro.core.instrument import SolveCounter
from repro.runtime import faults as _faults

# Incremented once per executor *trace* (Python-level side effect inside
# the jitted body runs only when XLA actually retraces).  Tests assert
# that a second same-bucket request performs zero new traces.
EXECUTOR_TRACES = SolveCounter("executor_traces")

# Same contract for the partial-spectrum (range) executor.
RANGE_EXECUTOR_TRACES = SolveCounter("range_executor_traces")


class PlanKey(NamedTuple):
    """Bucketed compile-cache key; every field is static/hashable."""
    padded_n: int
    leaf: int
    batch_bucket: int
    dtype: str
    chunk: int
    niter: int
    use_zhat: bool
    return_boundary: bool
    tol_factor: float
    stream_threshold: int
    deflate_budget: int
    resident_threshold: int
    fused: bool
    # Distributed conquer: number of contiguous problem shards on the 1-D
    # solver mesh (1 == classic single-device path) and whether the
    # subtree->cooperative all-gather int8-compresses the boundary rows.
    # Mesh shape is executable identity: same N on a different shard
    # count is a different XLA program, so it must split the cache.
    shards: int = 1
    compress_halo: bool = False
    # Mixed-precision pipeline: "native" runs the tree in `dtype`;
    # "mixed" runs the whole tree in f32 and then Sturm-certifies /
    # polishes the eigenvalues against the original f64 (d, e) to
    # refine_tol * eps_f64 * ||T||.  `dtype` stays the OUTPUT dtype
    # (float64 for mixed), so the f32 tree executable is shared with
    # plain-f32 traffic of the same knobs; refine_tol is normalized to
    # 0.0 on uncertified native routes so it never splits their cache.
    precision: str = "native"
    refine_tol: float = 0.0
    # Certified solves (the robustness layer's product knob): the request
    # finalizer runs one extra batched Sturm sweep (certify_spectrum)
    # over the outputs and escalates misses down the degradation ladder.
    # The flag joins the key so the serving scheduler groups certified
    # traffic into its own flushes (one amortized sweep per flush) -- but
    # the TREE executable is untouched: `certify` is not a static arg of
    # `_executor`, so certified and uncertified routes of equal knobs
    # share one compiled solve.
    certify: bool = False


def batch_bucket(batch: int) -> int:
    """Round a request batch up to the next power of two (min 1)."""
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    return 1 << (batch - 1).bit_length()


def _refine_default_tol() -> float:
    from repro.core import bisect as _bis  # deferred: bisect imports plan
    return _bis.DEFAULT_REFINE_TOL


def _refine_traces() -> SolveCounter:
    from repro.core import bisect as _bis  # deferred: bisect imports plan
    return _bis.REFINE_EXECUTOR_TRACES


# Auto-routing floor: padded problems at least this large pick the
# sharded path when several devices are visible.  Below it the all-gather
# plus replicated merge-head overhead outweighs the sharded subtree/
# secular work (the distributed crossover heuristic in the README).
DIST_AUTO_MIN_N = 16384


def _resolve_shards(mesh, padded_n: int, leaf: int) -> int:
    """Resolve the ``mesh`` routing knob to a concrete shard count.

    ``mesh`` may be None / 1 (single device), "auto" (shard huge
    problems over the largest usable power-of-two device count), an int
    shard count, or a Mesh object (its total size is used).  Explicit
    requests validate hard -- a clear error beats a silent single-device
    fallback; "auto" degrades to 1 instead.
    """
    max_shards = padded_n // leaf        # one leaf per shard at minimum
    if mesh is None or mesh == 1:
        return 1
    if isinstance(mesh, str):
        if mesh != "auto":
            raise ValueError(f"mesh must be 'auto', an int shard count, "
                             f"or a Mesh; got {mesh!r}")
        if padded_n < DIST_AUTO_MIN_N:
            return 1
        shards = jax.device_count()
        shards = 1 << (shards.bit_length() - 1)   # largest pow2 <= devices
        while shards > max_shards:
            shards //= 2
        return max(1, shards)
    shards = int(mesh.size) if hasattr(mesh, "size") else int(mesh)
    if shards < 1:
        raise ValueError(f"mesh shard count must be >= 1, got {shards}")
    if shards == 1:
        return 1
    if shards & (shards - 1):
        raise ValueError(
            f"mesh shard count must be a power of two (the D&C tree "
            f"pairs nodes), got {shards}")
    if shards > jax.device_count():
        raise ValueError(
            f"mesh={shards} but only {jax.device_count()} devices are "
            f"visible; force host devices before first jax init "
            f"(XLA_FLAGS=--xla_force_host_platform_device_count={shards}, "
            f"or run.py --mesh {shards})")
    if shards > max_shards:
        raise ValueError(
            f"mesh={shards} needs at least {shards} leaves but "
            f"padded n={padded_n} with leaf={leaf} has {max_shards}; "
            f"use fewer shards or a smaller leaf")
    return shards


def resolve_solve_route(n: int, *, leaf: int = 32, chunk: int = 256,
                        niter: int | None = None,
                        use_zhat: bool = True,
                        return_boundary: bool = False,
                        tol_factor: float = 8.0,
                        stream_threshold: int | None = None,
                        deflate_budget: int | None = None,
                        resident_threshold: int | None = None,
                        fused: bool = True, dtype=None,
                        mesh="auto",
                        compress_halo: bool = False,
                        precision: str = "native",
                        refine_tol: float | None = None,
                        certify: bool = False) -> PlanKey:
    """Resolve a full-spectrum request to its bucketed route key -- pure.

    The returned :class:`PlanKey` has every request-determined field
    concrete (None knobs resolved to backend defaults, n absorbed into
    its padded size) but the batch axis *unresolved*: ``batch_bucket`` is
    0 and ``chunk`` is the requested upper bound, both fixed by
    :func:`plan_for_route` once the launch batch is known.  Two requests
    with equal route keys are guaranteed to share one compiled executable
    when coalesced into the same flush -- the grouping invariant the
    serving scheduler (``repro.serve``) is built on.  Never touches the
    plan cache.

    ``mesh`` routes distributed conquer: the default "auto" shards
    problems with padded N >= ``DIST_AUTO_MIN_N`` over the largest
    power-of-two device count available (a no-op on one device); an int /
    Mesh demands exactly that shard count and raises when the devices or
    tree leaves are not there.  ``compress_halo`` opts the sharded
    path's boundary-row all-gather into int8 compression; it is
    normalized to False on the single-device route so it never splits
    that cache.

    ``precision="mixed"`` routes the mixed-precision pipeline: the D&C
    tree runs in f32 (the ``dtype`` field stays the OUTPUT dtype,
    float64) and the eigenvalues are Sturm-certified / cluster-polished
    to ``refine_tol * eps_f64 * ||T||`` against the original (d, e).
    ``niter=None`` resolves to the precision's default iteration budget
    (f32 trees hit their accuracy floor earlier -- see
    ``secular.DEFAULT_NITER_F32``); an explicit niter always wins.
    """
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    if precision not in ("native", "mixed"):
        raise ValueError(
            f"precision must be 'native' or 'mixed', got {precision!r}")
    if precision == "mixed":
        if not jax.config.jax_enable_x64:
            raise ValueError(
                "precision='mixed' certifies against float64 Sturm "
                "counts; enable jax_enable_x64 first (JAX_ENABLE_X64=1 "
                "-- see the README mixed-precision runbook)")
        if dtype is not None and jnp.dtype(dtype) != jnp.dtype(jnp.float64):
            raise ValueError(
                f"precision='mixed' returns float64 eigenvalues; dtype "
                f"must be float64 or None, got {jnp.dtype(dtype).name} "
                f"(for a pure-f32 solve use dtype=float32 with "
                f"precision='native')")
        dtype = jnp.float64
        refine_tol = float(refine_tol if refine_tol is not None
                           else _refine_default_tol())
        if refine_tol <= 0.0:
            raise ValueError(
                f"refine_tol must be positive (eps_f64 * ||T|| units), "
                f"got {refine_tol}")
    else:
        if refine_tol is not None and not certify:
            raise ValueError(
                "refine_tol only applies to precision='mixed' or "
                "certify=True routes")
        # Certified native routes carry the certification tolerance in the
        # refine_tol field (same eps * ||T|| units the mixed pipeline
        # uses); uncertified native routes normalize it to 0.0 so it never
        # splits their cache.
        refine_tol = (float(refine_tol if refine_tol is not None
                            else _refine_default_tol()) if certify else 0.0)
        if certify and refine_tol <= 0.0:
            raise ValueError(
                f"refine_tol must be positive (eps * ||T|| units), "
                f"got {refine_tol}")
    if niter is None:
        niter = (_sec.DEFAULT_NITER_F32 if precision == "mixed"
                 else _sec.DEFAULT_NITER)
    if dtype is None:
        dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    if stream_threshold is None:
        stream_threshold = _merge.default_stream_threshold()
    if deflate_budget is None:
        deflate_budget = _merge.DEFAULT_DEFLATE_BUDGET
    if resident_threshold is None:
        resident_threshold = _merge.default_resident_threshold()
    N, _ = _br._tree_shape(n, leaf)
    shards = _resolve_shards(mesh, N, leaf)
    return PlanKey(padded_n=N, leaf=leaf, batch_bucket=0,
                   dtype=jnp.dtype(dtype).name, chunk=int(chunk),
                   niter=int(niter), use_zhat=use_zhat,
                   return_boundary=return_boundary,
                   tol_factor=float(tol_factor),
                   stream_threshold=int(stream_threshold),
                   deflate_budget=int(deflate_budget),
                   resident_threshold=int(resident_threshold), fused=fused,
                   shards=shards,
                   compress_halo=bool(compress_halo) and shards > 1,
                   precision=precision, refine_tol=refine_tol,
                   certify=bool(certify))


# Elements per streamed secular tile the CPU path aims for (~2 MiB f64):
# big enough to amortize loop steps, small enough to stay cache-resident.
_CPU_TILE_BUDGET = 256 * 1024


def _resolve_chunk(chunk: int, bucket: int, padded_n: int) -> int:
    """Batch-aware effective streaming chunk (CPU only).

    The requested ``chunk`` is an upper bound.  Under a wide batch the
    vmapped streamed tiles are (bucket * nodes, chunk, K): a chunk sized
    for one problem blows the cache by the batch factor and the secular
    iteration turns memory-bound (measured ~4x slower per problem at
    bucket=64, K=256 with chunk=256 vs 16 on 2-core CPU).  The effective
    chunk targets a fixed tile budget at the top merge (K = padded N,
    width = bucket), keeping per-eval tiles cache-resident; results are
    equivalent to rounding (chunking is a pure scheduling knob).
    Accelerator backends keep the requested chunk -- their kernels tile
    explicitly.
    """
    if bucket <= 1 or jax.default_backend() != "cpu":
        return chunk
    return max(8, min(chunk, _CPU_TILE_BUDGET // (bucket * padded_n)))


_MESH_LOCK = threading.Lock()
_MESH_CACHE: dict[int, Mesh] = {}


def _batch_sharding(bucket: int):
    """NamedSharding over the batch axis when multiple devices exist.

    A batched solve is embarrassingly parallel across problems, so the
    bucket is split across all default-backend devices (forced host CPU
    devices count too: set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=<cores>`` to give
    the executor one device per core).  The Python loop of single solves
    can never use this -- each of its launches is one problem wide.
    Buckets are powers of two, so the mesh uses the largest power-of-two
    device count available (a 6-core host shards over 4 devices rather
    than not at all).  Returns None when sharding does not apply
    (single device, or bucket smaller than two shards).
    """
    devs = jax.devices()
    if len(devs) <= 1:
        return None
    n = 1 << (len(devs).bit_length() - 1)   # largest pow2 <= len(devs)
    n = min(n, bucket)                      # bucket is pow2 -> divisible
    if n <= 1:
        return None
    with _MESH_LOCK:
        mesh = _MESH_CACHE.get(n)
        if mesh is None:
            mesh = Mesh(np.array(devs[:n]), ("batch",))
            _MESH_CACHE[n] = mesh
    return NamedSharding(mesh, PartitionSpec("batch"))


_SOLVER_MESH_CACHE: dict[int, Mesh] = {}


def _dist_axis() -> str:
    from repro.dist.sharding import SOLVER_AXIS
    return SOLVER_AXIS


def _solver_mesh(shards: int) -> Mesh:
    """Cached 1-D solver mesh (one Mesh object per shard count, so the
    mesh is a stable static jit argument and never causes a retrace)."""
    with _MESH_LOCK:
        mesh = _SOLVER_MESH_CACHE.get(shards)
        if mesh is None:
            from repro.launch.mesh import make_solver_mesh
            mesh = make_solver_mesh(shards)
            _SOLVER_MESH_CACHE[shards] = mesh
    return mesh


@functools.partial(jax.jit, static_argnames=(
    "leaf", "chunk", "niter", "use_zhat", "return_boundary", "tol_factor",
    "stream_threshold", "deflate_budget", "resident_threshold", "fused"))
def _executor(d_pad, e_pad, track, *, leaf, chunk, niter, use_zhat,
              return_boundary, tol_factor, stream_threshold,
              deflate_budget, resident_threshold, fused):
    """The one compiled entry point for every solve.

    A module-level jit (not per-plan) so the executable cache is shared by
    all SolvePlan instances: same bucket shapes + same static flags ==
    same executable, even across plan objects and original sizes n.
    """
    EXECUTOR_TRACES.increment()
    return _br._br_dc_padded_batch(
        d_pad, e_pad, track, leaf=leaf, chunk=chunk, niter=niter,
        use_zhat=use_zhat, return_boundary=return_boundary,
        tol_factor=tol_factor, stream_threshold=stream_threshold,
        deflate_budget=deflate_budget,
        resident_threshold=resident_threshold, fused=fused)


@functools.partial(jax.jit, static_argnames=(
    "mesh", "shards", "compress_halo", "leaf", "chunk", "niter", "use_zhat",
    "return_boundary", "tol_factor", "stream_threshold", "deflate_budget",
    "resident_threshold", "fused"))
def _executor_sharded(d_pad, e_pad, track, *, mesh, shards, compress_halo,
                      leaf, chunk, niter, use_zhat, return_boundary,
                      tol_factor, stream_threshold, deflate_budget,
                      resident_threshold, fused):
    """Distributed-conquer entry point: one shard_map launch over the 1-D
    solver mesh.  Module-level jit like `_executor`, with the mesh as a
    static argument (cached Mesh objects in `_solver_mesh` keep it a
    stable cache key), so same-mesh traffic never retraces.
    """
    from repro.compat import shard_map as _shard_map
    from repro.dist.sharding import SOLVER_AXIS
    EXECUTOR_TRACES.increment()
    body = functools.partial(
        _br._br_dc_sharded_batch, shards=shards, axis_name=SOLVER_AXIS,
        leaf=leaf, chunk=chunk, niter=niter, use_zhat=use_zhat,
        return_boundary=return_boundary, tol_factor=tol_factor,
        stream_threshold=stream_threshold, deflate_budget=deflate_budget,
        resident_threshold=resident_threshold, fused=fused,
        compress_halo=compress_halo)
    sliced = PartitionSpec(None, SOLVER_AXIS)
    # Outputs are genuinely replicated: everything past the transition
    # all-gather is computed identically on every device (the replication
    # checker cannot prove that through ppermute/axis_index, hence off).
    mapped = _shard_map(body, mesh=mesh,
                        in_specs=(sliced, sliced, PartitionSpec()),
                        out_specs=PartitionSpec(), check_vma=False)
    return mapped(d_pad, e_pad, track)


@dataclasses.dataclass(frozen=True)
class SolvePlan:
    """Static solve schedule for one (padded N, batch bucket) class."""
    key: PlanKey
    levels: int
    # Per-level tuples of the original indices k whose off-diagonal
    # e[k-1] couples each merge at that level (diagnostics/scheduling).
    coupling_index: tuple
    # Selected-row slots: ("blo", "bhi") (+ "track" with boundary output).
    track_slots: tuple

    @property
    def padded_n(self) -> int:
        return self.key.padded_n

    @property
    def batch_bucket_size(self) -> int:
        return self.key.batch_bucket

    @property
    def devices(self) -> int:
        """Shard count of the 1-D solver mesh this plan launches on
        (1 == the classic single-device executor)."""
        return self.key.shards

    @property
    def state_bytes(self) -> int:
        """Persistent-state byte model for one full-bucket launch.

        B * O(N): inputs (d_pad, e_pad), child spectra (lam) and the r
        selected rows -- the paper's linear-space bound, scaled by the
        batch bucket.  Transients (streamed tiles / dense small-K blocks)
        are excluded; see ``workspace_model`` for those.
        """
        r = 3 if self.key.return_boundary else 2
        itemsize = jnp.dtype(self.key.dtype).itemsize
        return (3 + r) * self.key.padded_n * self.key.batch_bucket * itemsize

    def execute(self, d, e, orig_n=None) -> "_br.BRBatchResult":
        """Run the plan's cached executor on a (B, n) problem batch.

        B may be anything <= the plan's batch bucket (short batches are
        padded with dummy problems and sliced away); n may be anything
        that pads to this plan's N.  Exactly one device launch.

        ``orig_n`` is the mixed-size hook the serving coalescer uses: a
        (B,) array of *original* problem sizes when the batch rows were
        host-padded (with decoupled sentinel blocks) to the common width
        ``n`` before stacking.  It routes each problem's own boundary row
        ``orig_n[b] - 1`` into the tracked selected-row slot (a traced
        input -- no retrace), so mixed-n flushes still return correct
        per-problem (blo, bhi); eigenvalue demux (slicing row b to
        ``orig_n[b]``) is the caller's job since rows here keep the
        common width.
        """
        key = self.key
        dtype = jnp.dtype(key.dtype)
        d = jnp.asarray(d, dtype)
        e = jnp.asarray(e, dtype)
        d, e = _br._as_batch(d, e, None)   # enforce (B, n)/(B, n-1)
        B, n = d.shape
        Bb = key.batch_bucket
        if B > Bb:
            raise ValueError(
                f"batch {B} exceeds plan bucket {Bb}; make a bigger plan")
        if _br._tree_shape(n, key.leaf)[0] != key.padded_n:
            raise ValueError(
                f"n={n} pads to {_br._tree_shape(n, key.leaf)[0]}, but this "
                f"plan was built for padded N={key.padded_n}")

        if orig_n is not None:
            orig_n = jnp.asarray(orig_n, jnp.int32)
            if orig_n.shape != (B,):
                raise ValueError(
                    f"orig_n must have shape ({B},), got {orig_n.shape}")

        if B < Bb:
            # Dummy problems: zero diagonals decouple exactly and cost one
            # deflated pass-through per merge; sliced off below.
            d = jnp.concatenate([d, jnp.zeros((Bb - B, n), dtype)], axis=0)
            e = jnp.concatenate(
                [e, jnp.zeros((Bb - B, max(n - 1, 0)), dtype)], axis=0)

        d_pad, e_pad, N, L = _br._pad_problem(d, e, key.leaf)
        # The tracked third row slot is only needed when padding appends
        # sentinel rows below row n-1; unpadded problems (n == N) already
        # carry that row as the bhi slot, so they run with r == 2.  With
        # per-problem original sizes the track slot always runs (some
        # problems may be host-padded even when n == N) and each problem
        # follows its own row orig_n[b] - 1.
        if key.return_boundary and orig_n is not None:
            track = jnp.concatenate(
                [orig_n - 1, jnp.full((Bb - B,), n - 1, jnp.int32)])
        elif key.return_boundary and n != N:
            track = jnp.full((Bb,), n - 1, jnp.int32)
        else:
            track = None

        if key.precision == "mixed":
            # The whole D&C tree runs in f32; the f64 (d_pad, e_pad) stay
            # behind for the Sturm certification / cluster polish below.
            d_run = d_pad.astype(jnp.float32)
            e_run = e_pad.astype(jnp.float32)
        else:
            d_run, e_run = d_pad, e_pad

        # Chaos-harness hook: a scheduled launch fault raises here --
        # after input staging, before any executor runs -- exactly where
        # a real device/compile fault would surface to the caller.  The
        # hook is one global-flag read when no schedule is configured.
        _faults.inject("plan.launch")

        if key.shards > 1:
            # Distributed conquer: the *problem* axis is sharded over the
            # 1-D solver mesh (batch sharding does not compose with it --
            # every device works on every problem's slice).
            mesh = _solver_mesh(key.shards)
            sliced = NamedSharding(
                mesh, PartitionSpec(None, _dist_axis()))
            # Chaos-harness hook: corrupts one staged off-diagonal entry
            # (default: the last, a shard-boundary coupling) -- the "halo
            # exchange delivered a damaged value" scenario.
            e_run = _faults.corrupt_entry("dist.halo", e_run)
            d_run = jax.device_put(d_run, sliced)
            e_run = jax.device_put(e_run, sliced)
            if track is not None:
                track = jax.device_put(
                    track, NamedSharding(mesh, PartitionSpec()))
            lam, rows, kprimes = _executor_sharded(
                d_run, e_run, track, mesh=mesh, shards=key.shards,
                compress_halo=key.compress_halo, leaf=key.leaf,
                chunk=key.chunk, niter=key.niter, use_zhat=key.use_zhat,
                return_boundary=key.return_boundary,
                tol_factor=key.tol_factor,
                stream_threshold=key.stream_threshold,
                deflate_budget=key.deflate_budget,
                resident_threshold=key.resident_threshold, fused=key.fused)
        else:
            sharding = _batch_sharding(Bb)
            if sharding is not None:
                d_run = jax.device_put(d_run, sharding)
                e_run = jax.device_put(e_run, sharding)
                if track is not None:
                    track = jax.device_put(track, sharding)

            lam, rows, kprimes = _executor(
                d_run, e_run, track, leaf=key.leaf, chunk=key.chunk,
                niter=key.niter, use_zhat=key.use_zhat,
                return_boundary=key.return_boundary,
                tol_factor=key.tol_factor,
                stream_threshold=key.stream_threshold,
                deflate_budget=key.deflate_budget,
                resident_threshold=key.resident_threshold, fused=key.fused)
        _br.SOLVE_COUNTER.increment()
        # Chaos-harness hook: NaN-poisons configured eigenvalue rows ("the
        # device returned garbage") so tests can drive the degradation
        # ladder.  Sits BEFORE the mixed-precision refinement stage: a
        # poisoned mixed solve exercises recovery-by-refinement, a
        # poisoned native solve exercises the finalizer's ladder.
        lam = _faults.poison_rows("plan.output", lam)

        if _br.SOLVE_COUNTER.deflation_enabled:
            # Deflation-ratio gauge (opt-in via measure(deflation=True)):
            # kprime per level is already an executor output, so observing
            # it costs one tiny host transfer, never a recomputation.
            # Restrict to merge nodes that touch real data -- nodes lying
            # entirely in the padded sentinel region [n, N) deflate almost
            # completely and would bias the reported ratio downwards.
            for level, kp in enumerate(kprimes):
                K_level = 2 * key.leaf * (1 << level)
                nm_real = min(kp.shape[1], -(-n // K_level))
                _br.SOLVE_COUNTER.record_deflation(
                    level, float(jnp.sum(kp[:B, :nm_real])),
                    B * nm_real * K_level)

        lam = lam[:B]
        rows_b = rows[:B] if key.return_boundary else None
        if key.precision == "mixed":
            # Certify the f32 tree's eigenvalues with f64 Sturm counts
            # against the ORIGINAL (d, e) and polish only the misses.
            # Runs on the full padded width: sentinel lanes are exactly
            # decoupled and certify vacuously (nvalid masks them), so the
            # padded counts equal the original problem's counts.  The
            # polish moves each lane by at most refine_tol * eps * ||T||,
            # which can reorder ties -- one argsort restores ascending
            # order and (for boundary output) permutes the selected rows
            # by the identical permutation.
            from repro.core import bisect as _bis  # deferred: imports plan
            nvalid = (orig_n if orig_n is not None
                      else jnp.full((B,), n, jnp.int32))
            lam_ref, rinfo = _bis.refine_clusters(
                d_pad[:B], e_pad[:B, : N - 1], lam.astype(dtype),
                nvalid=nvalid, tol_factor=key.refine_tol, sort=False)
            order = jnp.argsort(lam_ref, axis=1)
            lam = jnp.take_along_axis(lam_ref, order, axis=1)
            if rows_b is not None:
                rows_b = jnp.take_along_axis(
                    rows_b.astype(dtype), order[:, None, :], axis=2)
            if _br.SOLVE_COUNTER.refinement_enabled:
                _br.SOLVE_COUNTER.record_refinement(
                    rinfo["targets"], rinfo["polished"],
                    rinfo["iterations"], rinfo["rounds"])

        lam = lam[:, :n]  # sentinels sort above the Gershgorin bound
        if key.return_boundary:
            blo = rows_b[:, 0, :n]
            bhi = rows_b[:, 2 if track is not None else 1, :n]
        else:
            blo = bhi = None
        return _br.BRBatchResult(lam, blo, bhi,
                                 tuple(k[:B] for k in kprimes))


class RangePlanKey(NamedTuple):
    """Bucketed cache key for partial-spectrum (sliced) solves.

    ``k_bucket`` rounds the requested slice width up to the next power of
    two and the target *indices* are a traced executor input, so every
    (il, iu) window of the same bucketed width -- top-k, bottom-k, or an
    interior band -- shares one executable.  ``select`` is deliberately
    NOT a key field: select-by-value requests are resolved host-side to
    an index window (two Sturm counts) and then reuse the select-by-index
    executables instead of splitting the cache.
    """
    n: int
    k_bucket: int
    batch_bucket: int
    dtype: str
    maxiter: int
    polish: int


@functools.partial(jax.jit, static_argnames=("maxiter", "polish"))
def _range_executor(d, e, targets, *, maxiter, polish):
    """The one compiled entry point for every sliced solve.

    Module-level jit (not per-plan) so executables are shared across
    RangePlan instances exactly like the full-spectrum ``_executor``.
    """
    from repro.core import bisect as _bis  # deferred: bisect imports plan
    RANGE_EXECUTOR_TRACES.increment()
    return _bis._slice_targets(d, e, targets, maxiter=maxiter,
                               polish=polish)


@dataclasses.dataclass(frozen=True)
class RangePlan:
    """Static schedule for one (n, k bucket, batch bucket) sliced-solve
    class; ``execute`` is the only entry point that launches work."""
    key: RangePlanKey

    @property
    def k_bucket_size(self) -> int:
        return self.key.k_bucket

    @property
    def state_bytes(self) -> int:
        """Persistent-state byte model for one full-bucket launch:
        B * (2n inputs + 4k bracket state (lo, hi, lam, count)) -- the
        O(B * (n + k)) memory the sliced front end advertises."""
        key = self.key
        itemsize = jnp.dtype(key.dtype).itemsize
        return key.batch_bucket * (2 * key.n + 4 * key.k_bucket) * itemsize

    def execute(self, d, e, il, k: int | None = None):
        """Eigenvalues [il, il + k) of each problem in a (B, n) batch.

        B may be anything <= the plan's batch bucket; the slice may start
        anywhere and k may be anything <= the plan's k bucket (targets
        are traced inputs).  Short batches pad with trivial dummy
        problems and short slices pad by clamping the target indices to
        n-1 (duplicate roots, sliced away).  Exactly one device launch.
        Returns (B, k).

        ``il`` may also be a (B,) integer array -- the serving
        coalescer's mixed-window hook: each problem slices its own
        [il[b], il[b] + k) window inside one launch (targets are traced,
        so this shares the same executable).  Per-problem windows
        narrower than ``k`` clamp their tail targets to n-1; the caller
        slices each row to its own width.
        """
        key = self.key
        dtype = jnp.dtype(key.dtype)
        d = jnp.asarray(d, dtype)
        e = jnp.asarray(e, dtype)
        d, e = _br._as_batch(d, e, None)
        B, n = d.shape
        if n != key.n:
            raise ValueError(f"n={n} but this plan was built for n={key.n}")
        Bb = key.batch_bucket
        if B > Bb:
            raise ValueError(
                f"batch {B} exceeds plan bucket {Bb}; make a bigger plan")
        k = key.k_bucket if k is None else int(k)
        if not (1 <= k <= key.k_bucket):
            raise ValueError(
                f"slice width {k} exceeds plan k bucket {key.k_bucket}")
        il = np.asarray(il, np.int64)
        if il.ndim == 0:
            ilv = int(il)
            if not (0 <= ilv and ilv + k <= n):
                raise ValueError(
                    f"slice [{ilv}, {ilv + k}) out of range for n={n}")
            il = np.full((B,), ilv, np.int64)
        else:
            if il.shape != (B,):
                raise ValueError(
                    f"per-problem il must have shape ({B},), got {il.shape}")
            if il.min() < 0 or il.max() >= n:
                raise ValueError(
                    f"per-problem il must lie in [0, {n}); got "
                    f"[{il.min()}, {il.max()}]")

        if B < Bb:
            d = jnp.concatenate([d, jnp.zeros((Bb - B, n), dtype)], axis=0)
            e = jnp.concatenate(
                [e, jnp.zeros((Bb - B, max(n - 1, 0)), dtype)], axis=0)
        il_full = jnp.zeros((Bb,), jnp.int32).at[:B].set(
            jnp.asarray(il, jnp.int32))
        targets = jnp.minimum(
            il_full[:, None] + jnp.arange(key.k_bucket, dtype=jnp.int32)[None, :],
            n - 1)

        lam = _range_executor(d, e, targets, maxiter=key.maxiter,
                              polish=key.polish)
        _br.SOLVE_COUNTER.increment()
        return lam[:B, :k]


_PLAN_CACHE: dict[PlanKey, SolvePlan] = {}
_RANGE_CACHE: dict[tuple, RangePlan] = {}
_PLAN_LOCK = threading.Lock()
_STATS = {"hits": 0, "misses": 0, "range_hits": 0, "range_misses": 0}


def make_plan(n: int, batch: int = 1, *, leaf: int = 32, chunk: int = 256,
              niter: int | None = None, use_zhat: bool = True,
              return_boundary: bool = False, tol_factor: float = 8.0,
              stream_threshold: int | None = None,
              deflate_budget: int | None = None,
              resident_threshold: int | None = None, fused: bool = True,
              dtype=None, mesh="auto",
              compress_halo: bool = False,
              precision: str = "native",
              refine_tol: float | None = None) -> SolvePlan:
    """Build (or fetch) the SolvePlan for an (n, batch) request class.

    Bucketing: ``batch`` rounds up to the next power of two and ``n`` is
    absorbed into its padded ``leaf * 2^L`` size, so the cache stays a
    handful of entries under arbitrary traffic.  The returned plan is
    shared and immutable; ``plan.execute(d, e)`` is the only entry point
    that launches work.  Route resolution and plan construction are the
    same two steps the serving scheduler performs -- this is literally
    ``plan_for_route(resolve_solve_route(...), batch)``.
    """
    route = resolve_solve_route(
        n, leaf=leaf, chunk=chunk, niter=niter, use_zhat=use_zhat,
        return_boundary=return_boundary, tol_factor=tol_factor,
        stream_threshold=stream_threshold, deflate_budget=deflate_budget,
        resident_threshold=resident_threshold, fused=fused, dtype=dtype,
        mesh=mesh, compress_halo=compress_halo, precision=precision,
        refine_tol=refine_tol)
    return plan_for_route(route, batch)


def plan_for_route(route: PlanKey, batch: int = 1) -> SolvePlan:
    """Fix a route key's batch axis and build (or fetch) its SolvePlan.

    ``route`` comes from :func:`resolve_solve_route` (batch_bucket == 0,
    chunk == requested upper bound); ``batch`` is the actual launch batch,
    rounded up to its power-of-two bucket here.  This is the plan-cache
    entry point shared by the sync API and the serving scheduler's flush
    path, so coalesced and one-shot traffic hit the same cache entries.
    """
    bucket = batch_bucket(batch)
    key = route._replace(batch_bucket=bucket,
                         chunk=_resolve_chunk(route.chunk, bucket,
                                              route.padded_n))
    N, leaf = key.padded_n, key.leaf
    L = (N // leaf).bit_length() - 1
    with _PLAN_LOCK:
        plan = _PLAN_CACHE.get(key)
        if plan is not None:
            _STATS["hits"] += 1
            return plan
        _STATS["misses"] += 1
        coupling = []
        for level in range(L):
            M = leaf * (1 << level)
            nm = N // (2 * M)
            coupling.append(tuple((2 * i + 1) * M for i in range(nm)))
        slots = ("blo", "bhi") + (("track",) if key.return_boundary else ())
        plan = SolvePlan(key=key, levels=L, coupling_index=tuple(coupling),
                         track_slots=slots)
        _PLAN_CACHE[key] = plan
        return plan


def resolve_range_route(n: int, k: int, *, maxiter: int | None = None,
                        polish: int | None = None,
                        dtype=None) -> RangePlanKey:
    """Resolve a sliced-solve request to its bucketed route key -- pure.

    Mirrors :func:`resolve_solve_route`: the returned key is fully
    concrete except for the batch axis (``batch_bucket`` == 0, fixed by
    :func:`range_plan_for_route`).  Never touches the plan cache.
    """
    from repro.core import bisect as _bis  # deferred: bisect imports plan
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    if not (1 <= k <= n):
        raise ValueError(f"k must be in [1, n]; got k={k}, n={n}")
    if dtype is None:
        dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    if maxiter is None:
        maxiter = _bis.DEFAULT_MAX_BISECT
    if polish is None:
        polish = _bis.DEFAULT_POLISH
    return RangePlanKey(n=n, k_bucket=min(batch_bucket(k), n),
                        batch_bucket=0, dtype=jnp.dtype(dtype).name,
                        maxiter=int(maxiter), polish=int(polish))


def make_range_plan(n: int, k: int, batch: int = 1, *,
                    maxiter: int | None = None, polish: int | None = None,
                    dtype=None) -> RangePlan:
    """Build (or fetch) the RangePlan for an (n, k, batch) sliced request.

    Bucketing: ``k`` and ``batch`` round up to the next power of two and
    the slice's start index is a traced executor input, so steady top-k /
    bottom-k / band traffic of any window position lands on a handful of
    compiled executables (``plan_cache_stats()`` exposes the range-cache
    hits/misses/traces next to the full-spectrum ones).
    """
    return range_plan_for_route(
        resolve_range_route(n, k, maxiter=maxiter, polish=polish,
                            dtype=dtype), batch)


def range_plan_for_route(route: RangePlanKey, batch: int = 1) -> RangePlan:
    """Fix a range route key's batch axis and build (or fetch) its plan."""
    key = route._replace(batch_bucket=batch_bucket(batch))
    with _PLAN_LOCK:
        plan = _RANGE_CACHE.get(key)
        if plan is not None:
            _STATS["range_hits"] += 1
            return plan
        _STATS["range_misses"] += 1
        plan = RangePlan(key=key)
        _RANGE_CACHE[key] = plan
        return plan


def plan_cache_stats() -> dict:
    """Plan-cache observability: size/hits/misses, executor trace counts,
    and the per-kind persistent-state byte budgets (sum of each cached
    plan's ``state_bytes`` model -- what a simultaneous full-bucket launch
    of every cached executable would hold resident)."""
    with _PLAN_LOCK:
        mesh_buckets: dict[int, int] = {}
        for k in _PLAN_CACHE:
            mesh_buckets[k.shards] = mesh_buckets.get(k.shards, 0) + 1
        return {"size": len(_PLAN_CACHE), "hits": _STATS["hits"],
                "misses": _STATS["misses"],
                "mesh_buckets": mesh_buckets,
                "executor_traces": EXECUTOR_TRACES.count,
                "state_bytes": sum(p.state_bytes
                                   for p in _PLAN_CACHE.values()),
                "range_size": len(_RANGE_CACHE),
                "range_hits": _STATS["range_hits"],
                "range_misses": _STATS["range_misses"],
                "range_executor_traces": RANGE_EXECUTOR_TRACES.count,
                "range_state_bytes": sum(p.state_bytes
                                         for p in _RANGE_CACHE.values()),
                "refine_executor_traces": _refine_traces().count,
                **_guard.robustness_counters()}


def clear_plan_cache() -> None:
    """Drop cached plans and zero every cache statistic.

    Also resets the EXECUTOR_TRACES / RANGE_EXECUTOR_TRACES counters so a
    fresh measurement window after a clear starts from zero -- without
    this, no-retrace assertions (and the serving scheduler's steady-state
    monitoring) would race on counts left over from earlier traffic.
    Compiled executables stay in jax's jit cache: clearing is a
    bookkeeping reset, not a recompile.

    Also clears the robustness layer's process-wide state -- the fault
    injection schedule and its hit counters, the degradation gauge, and
    the degradation/deadline counters -- so chaos tests can never leak a
    fault schedule or escalation counts into neighboring tests (the same
    isolation contract the trace counters got in PR 5).
    """
    with _PLAN_LOCK:
        _PLAN_CACHE.clear()
        _RANGE_CACHE.clear()
        for k in _STATS:
            _STATS[k] = 0
        EXECUTOR_TRACES.reset()
        RANGE_EXECUTOR_TRACES.reset()
        _refine_traces().reset()
    _faults.reset_faults()
    _guard.reset_robustness_counters()
    _br.SOLVE_COUNTER.clear_degradation()


# Workload-spec kind aliases accepted by ``prewarm``; "solve" is the
# stacked ("batch") form.  Kinds matter: each resolves through the same
# routing rules its real traffic will use ("full" carries the single-API
# L == 0 boundary-rows rule, "slq" always has boundary rows), so the
# compiled executable is exactly the one the first request needs.
_PREWARM_KIND_ALIASES = {"solve": "batch", "batch": "batch", "full": "full",
                         "slq": "slq"}


def prewarm(workload_spec) -> dict:
    """Compile executables for an expected workload before traffic hits.

    ``workload_spec`` is an iterable of dict entries::

        {"kind": "solve", "n": 1024, "batch": 64, **make_plan knobs}
        {"kind": "full",  "n": 16}                  # single-problem API
        {"kind": "slq",   "n": 64, "batch": 8, "leaf": 8}
        {"kind": "range", "n": 4096, "k": 32, "batch": 8, **knobs}

    Each entry is routed exactly like a real request of that kind
    (``repro.core.request.route_request`` -- one source of truth for key
    resolution), its plan is built, and one throwaway full-bucket execute
    on trivial problems compiles the XLA executable -- after ``prewarm``
    a cold service serves its first same-shaped request with zero traces
    (assert via ``plan_cache_stats()``).  Boundary-row plans execute with
    the per-problem ``orig_n`` track input, matching the serving flush
    form.  The throwaway solves do tick SOLVE_COUNTER.

    dtype / ``precision="mixed"`` knobs flow through untouched, so
    f32 and mixed traffic prewarms its OWN executables (a mixed spec
    compiles the f32 tree executor *and* the f64 certify executor --
    its throwaway solve runs the full certify stage on trivial
    problems, which certify on the first round).
    Returns ``{"plans": P, "seconds": s, "traces": t}``.
    """
    from repro.core.request import SolveRequest, route_request
    t0 = time.perf_counter()
    t_start = EXECUTOR_TRACES.count + RANGE_EXECUTOR_TRACES.count
    plans = 0
    for spec in workload_spec:
        spec = dict(spec)
        kind = spec.pop("kind", "solve")
        n = int(spec.pop("n"))
        batch = int(spec.pop("batch", 1))
        if kind in _PREWARM_KIND_ALIASES:
            req_kind = _PREWARM_KIND_ALIASES[kind]
            dtype = spec.get("dtype")
            if dtype is None:
                dtype = (jnp.float64 if jax.config.jax_enable_x64
                         else jnp.float32)
            d = np.zeros((n,) if req_kind == "full" else (batch, n),
                         jnp.dtype(dtype))
            e = np.zeros(d.shape[:-1] + (max(n - 1, 0),), d.dtype)
            routed = route_request(SolveRequest(
                d=d, e=e, kind=req_kind,
                return_boundary=bool(spec.pop("return_boundary", False)),
                certify=bool(spec.pop("certify", False)),
                knobs=spec))
            if routed.route is not None:   # n == 1 short circuits: no plan
                plan = plan_for_route(routed.route, batch)
                d2 = np.zeros((batch, n), d.dtype)
                e2 = np.zeros((batch, max(n - 1, 0)), d.dtype)
                # Serve flushes pass per-problem orig_n (a distinct traced
                # signature when boundary rows are on); "full" mirrors the
                # single-problem sync execution instead.
                orig_n = (np.full((batch,), n, np.int32)
                          if plan.key.return_boundary and req_kind != "full"
                          else None)
                jax.block_until_ready(plan.execute(d2, e2, orig_n=orig_n)
                                      .eigenvalues)
        elif kind == "range":
            k = int(spec.pop("k"))
            plan = make_range_plan(n, k, batch, **spec)
            dtype = jnp.dtype(plan.key.dtype)
            d = jnp.zeros((batch, n), dtype)
            e = jnp.zeros((batch, max(n - 1, 0)), dtype)
            jax.block_until_ready(plan.execute(d, e, 0, k))
        else:
            raise ValueError(
                f"unknown prewarm kind {kind!r}; use one of "
                f"{tuple(_PREWARM_KIND_ALIASES) + ('range',)}")
        plans += 1
    return {"plans": plans, "seconds": time.perf_counter() - t0,
            "traces": EXECUTOR_TRACES.count + RANGE_EXECUTOR_TRACES.count
            - t_start}
