"""Batch-first solve plans: static tree shape + bucketed compile cache.

Every solve -- single or batched -- goes through a :class:`SolvePlan`.
A plan captures everything *static* about a solve up front:

  * the padded problem size ``N = leaf * 2^L`` and tree depth ``L``,
  * the per-level rank-one coupling indices (where each merge's split
    off-diagonal lives in ``e``),
  * the selected-row track slots (2 boundary rows, +1 tracked original
    row when boundary output is requested),
  * the batch bucket: request batches are rounded **up to the next power
    of two**, so arbitrary traffic (B = 1, 3, 5, 97, ...) lands on a
    handful of compiled executables instead of one trace per batch size.

and owns the process-wide cache of compiled executables, keyed on

    (padded N, leaf, batch bucket, dtype, chunk, niter, use_zhat,
     return_boundary, tol_factor, stream_threshold, deflate_budget,
     resident_threshold, fused)

Two requests that differ only in original size n (same padded bucket) or
only in batch size (same power-of-two bucket) share one executable: the
tracked-row index is a *traced* per-problem input and short batches are
padded with trivial dummy problems, both sliced away on exit.  This is
what lets the solver run as a service under real traffic -- steady-state
request handling is cache lookups + one device launch, never a retrace.

``stream_threshold=None``, ``deflate_budget=None`` and
``resident_threshold=None`` are resolved to backend-aware concrete values
at plan-construction time so the cache key is always fully concrete.

Memory model: persistent state for a bucket of B problems is B * O(N)
(lam + selected rows + inputs), never B * O(N^2) -- the paper's O(n)
boundary-row state is exactly what makes the batched front door viable.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core import br_dc as _br
from repro.core import merge as _merge
from repro.core import secular as _sec
from repro.core.instrument import SolveCounter

# Incremented once per executor *trace* (Python-level side effect inside
# the jitted body runs only when XLA actually retraces).  Tests assert
# that a second same-bucket request performs zero new traces.
EXECUTOR_TRACES = SolveCounter("executor_traces")


class PlanKey(NamedTuple):
    """Bucketed compile-cache key; every field is static/hashable."""
    padded_n: int
    leaf: int
    batch_bucket: int
    dtype: str
    chunk: int
    niter: int
    use_zhat: bool
    return_boundary: bool
    tol_factor: float
    stream_threshold: int
    deflate_budget: int
    resident_threshold: int
    fused: bool


def batch_bucket(batch: int) -> int:
    """Round a request batch up to the next power of two (min 1)."""
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    return 1 << (batch - 1).bit_length()


# Elements per streamed secular tile the CPU path aims for (~2 MiB f64):
# big enough to amortize loop steps, small enough to stay cache-resident.
_CPU_TILE_BUDGET = 256 * 1024


def _resolve_chunk(chunk: int, bucket: int, padded_n: int) -> int:
    """Batch-aware effective streaming chunk (CPU only).

    The requested ``chunk`` is an upper bound.  Under a wide batch the
    vmapped streamed tiles are (bucket * nodes, chunk, K): a chunk sized
    for one problem blows the cache by the batch factor and the secular
    iteration turns memory-bound (measured ~4x slower per problem at
    bucket=64, K=256 with chunk=256 vs 16 on 2-core CPU).  The effective
    chunk targets a fixed tile budget at the top merge (K = padded N,
    width = bucket), keeping per-eval tiles cache-resident; results are
    equivalent to rounding (chunking is a pure scheduling knob).
    Accelerator backends keep the requested chunk -- their kernels tile
    explicitly.
    """
    if bucket <= 1 or jax.default_backend() != "cpu":
        return chunk
    return max(8, min(chunk, _CPU_TILE_BUDGET // (bucket * padded_n)))


_MESH_LOCK = threading.Lock()
_MESH_CACHE: dict[int, Mesh] = {}


def _batch_sharding(bucket: int):
    """NamedSharding over the batch axis when multiple devices exist.

    A batched solve is embarrassingly parallel across problems, so the
    bucket is split across all default-backend devices (forced host CPU
    devices count too: set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=<cores>`` to give
    the executor one device per core).  The Python loop of single solves
    can never use this -- each of its launches is one problem wide.
    Buckets are powers of two, so the mesh uses the largest power-of-two
    device count available (a 6-core host shards over 4 devices rather
    than not at all).  Returns None when sharding does not apply
    (single device, or bucket smaller than two shards).
    """
    devs = jax.devices()
    if len(devs) <= 1:
        return None
    n = 1 << (len(devs).bit_length() - 1)   # largest pow2 <= len(devs)
    n = min(n, bucket)                      # bucket is pow2 -> divisible
    if n <= 1:
        return None
    with _MESH_LOCK:
        mesh = _MESH_CACHE.get(n)
        if mesh is None:
            mesh = Mesh(np.array(devs[:n]), ("batch",))
            _MESH_CACHE[n] = mesh
    return NamedSharding(mesh, PartitionSpec("batch"))


@functools.partial(jax.jit, static_argnames=(
    "leaf", "chunk", "niter", "use_zhat", "return_boundary", "tol_factor",
    "stream_threshold", "deflate_budget", "resident_threshold", "fused"))
def _executor(d_pad, e_pad, track, *, leaf, chunk, niter, use_zhat,
              return_boundary, tol_factor, stream_threshold,
              deflate_budget, resident_threshold, fused):
    """The one compiled entry point for every solve.

    A module-level jit (not per-plan) so the executable cache is shared by
    all SolvePlan instances: same bucket shapes + same static flags ==
    same executable, even across plan objects and original sizes n.
    """
    EXECUTOR_TRACES.increment()
    return _br._br_dc_padded_batch(
        d_pad, e_pad, track, leaf=leaf, chunk=chunk, niter=niter,
        use_zhat=use_zhat, return_boundary=return_boundary,
        tol_factor=tol_factor, stream_threshold=stream_threshold,
        deflate_budget=deflate_budget,
        resident_threshold=resident_threshold, fused=fused)


@dataclasses.dataclass(frozen=True)
class SolvePlan:
    """Static solve schedule for one (padded N, batch bucket) class."""
    key: PlanKey
    levels: int
    # Per-level tuples of the original indices k whose off-diagonal
    # e[k-1] couples each merge at that level (diagnostics/scheduling).
    coupling_index: tuple
    # Selected-row slots: ("blo", "bhi") (+ "track" with boundary output).
    track_slots: tuple

    @property
    def padded_n(self) -> int:
        return self.key.padded_n

    @property
    def batch_bucket_size(self) -> int:
        return self.key.batch_bucket

    def execute(self, d, e) -> "_br.BRBatchResult":
        """Run the plan's cached executor on a (B, n) problem batch.

        B may be anything <= the plan's batch bucket (short batches are
        padded with dummy problems and sliced away); n may be anything
        that pads to this plan's N.  Exactly one device launch.
        """
        key = self.key
        dtype = jnp.dtype(key.dtype)
        d = jnp.asarray(d, dtype)
        e = jnp.asarray(e, dtype)
        d, e = _br._as_batch(d, e, None)   # enforce (B, n)/(B, n-1)
        B, n = d.shape
        Bb = key.batch_bucket
        if B > Bb:
            raise ValueError(
                f"batch {B} exceeds plan bucket {Bb}; make a bigger plan")
        if _br._tree_shape(n, key.leaf)[0] != key.padded_n:
            raise ValueError(
                f"n={n} pads to {_br._tree_shape(n, key.leaf)[0]}, but this "
                f"plan was built for padded N={key.padded_n}")

        if B < Bb:
            # Dummy problems: zero diagonals decouple exactly and cost one
            # deflated pass-through per merge; sliced off below.
            d = jnp.concatenate([d, jnp.zeros((Bb - B, n), dtype)], axis=0)
            e = jnp.concatenate(
                [e, jnp.zeros((Bb - B, max(n - 1, 0)), dtype)], axis=0)

        d_pad, e_pad, N, L = _br._pad_problem(d, e, key.leaf)
        # The tracked third row slot is only needed when padding appends
        # sentinel rows below row n-1; unpadded problems (n == N) already
        # carry that row as the bhi slot, so they run with r == 2.
        track = (jnp.full((Bb,), n - 1, jnp.int32)
                 if key.return_boundary and n != N else None)

        sharding = _batch_sharding(Bb)
        if sharding is not None:
            d_pad = jax.device_put(d_pad, sharding)
            e_pad = jax.device_put(e_pad, sharding)
            if track is not None:
                track = jax.device_put(track, sharding)

        lam, rows, kprimes = _executor(
            d_pad, e_pad, track, leaf=key.leaf, chunk=key.chunk,
            niter=key.niter, use_zhat=key.use_zhat,
            return_boundary=key.return_boundary, tol_factor=key.tol_factor,
            stream_threshold=key.stream_threshold,
            deflate_budget=key.deflate_budget,
            resident_threshold=key.resident_threshold, fused=key.fused)
        _br.SOLVE_COUNTER.increment()

        if _br.SOLVE_COUNTER.deflation_enabled:
            # Deflation-ratio gauge (opt-in via measure(deflation=True)):
            # kprime per level is already an executor output, so observing
            # it costs one tiny host transfer, never a recomputation.
            # Restrict to merge nodes that touch real data -- nodes lying
            # entirely in the padded sentinel region [n, N) deflate almost
            # completely and would bias the reported ratio downwards.
            for level, kp in enumerate(kprimes):
                K_level = 2 * key.leaf * (1 << level)
                nm_real = min(kp.shape[1], -(-n // K_level))
                _br.SOLVE_COUNTER.record_deflation(
                    level, float(jnp.sum(kp[:B, :nm_real])),
                    B * nm_real * K_level)

        lam = lam[:B, :n]  # sentinels sort above the Gershgorin bound
        if key.return_boundary:
            blo = rows[:B, 0, :n]
            bhi = rows[:B, 2 if track is not None else 1, :n]
        else:
            blo = bhi = None
        return _br.BRBatchResult(lam, blo, bhi,
                                 tuple(k[:B] for k in kprimes))


_PLAN_CACHE: dict[PlanKey, SolvePlan] = {}
_PLAN_LOCK = threading.Lock()
_STATS = {"hits": 0, "misses": 0}


def make_plan(n: int, batch: int = 1, *, leaf: int = 32, chunk: int = 256,
              niter: int = _sec.DEFAULT_NITER, use_zhat: bool = True,
              return_boundary: bool = False, tol_factor: float = 8.0,
              stream_threshold: int | None = None,
              deflate_budget: int | None = None,
              resident_threshold: int | None = None, fused: bool = True,
              dtype=None) -> SolvePlan:
    """Build (or fetch) the SolvePlan for an (n, batch) request class.

    Bucketing: ``batch`` rounds up to the next power of two and ``n`` is
    absorbed into its padded ``leaf * 2^L`` size, so the cache stays a
    handful of entries under arbitrary traffic.  The returned plan is
    shared and immutable; ``plan.execute(d, e)`` is the only entry point
    that launches work.
    """
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    if dtype is None:
        dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    if stream_threshold is None:
        stream_threshold = _merge.default_stream_threshold()
    if deflate_budget is None:
        deflate_budget = _merge.DEFAULT_DEFLATE_BUDGET
    if resident_threshold is None:
        resident_threshold = _merge.default_resident_threshold()
    bucket = batch_bucket(batch)
    N, L = _br._tree_shape(n, leaf)
    chunk = _resolve_chunk(chunk, bucket, N)
    key = PlanKey(padded_n=N, leaf=leaf, batch_bucket=bucket,
                  dtype=jnp.dtype(dtype).name, chunk=chunk, niter=niter,
                  use_zhat=use_zhat, return_boundary=return_boundary,
                  tol_factor=float(tol_factor),
                  stream_threshold=int(stream_threshold),
                  deflate_budget=int(deflate_budget),
                  resident_threshold=int(resident_threshold), fused=fused)
    with _PLAN_LOCK:
        plan = _PLAN_CACHE.get(key)
        if plan is not None:
            _STATS["hits"] += 1
            return plan
        _STATS["misses"] += 1
        coupling = []
        for level in range(L):
            M = leaf * (1 << level)
            nm = N // (2 * M)
            coupling.append(tuple((2 * i + 1) * M for i in range(nm)))
        slots = ("blo", "bhi") + (("track",) if return_boundary else ())
        plan = SolvePlan(key=key, levels=L, coupling_index=tuple(coupling),
                         track_slots=slots)
        _PLAN_CACHE[key] = plan
        return plan


def plan_cache_stats() -> dict:
    """Plan-cache observability: size/hits/misses + executor trace count."""
    with _PLAN_LOCK:
        return {"size": len(_PLAN_CACHE), "hits": _STATS["hits"],
                "misses": _STATS["misses"],
                "executor_traces": EXECUTOR_TRACES.count}


def clear_plan_cache() -> None:
    """Drop cached plans (compiled executables stay in jax's jit cache)."""
    with _PLAN_LOCK:
        _PLAN_CACHE.clear()
        _STATS["hits"] = _STATS["misses"] = 0
