"""Partial-spectrum slicing: Sturm-count bisection + safeguarded Newton.

The BR algorithm makes *all-eigenvalue* solves linear-space, but real
spectral workloads (SLQ edge estimates, extremal-mode monitoring,
condition numbers) want k << n eigenvalues.  This module brackets exactly
the requested eigenvalues with Gershgorin bounds + vectorized
Sturm-sequence counts and refines every bracket **in parallel** inside
one ``lax.while_loop`` -- the spectrum-slicing front end of Keyes et
al.'s partial-spectrum D&C, realized on the library's batch-first
substrate:

  * ``sturm_count``      -- #{eigenvalues <= shift} via the LAPACK DSTEBZ
                            pivot recurrence (negcount of LDL^T), vectorized
                            over arbitrary shift batches.  The hot batched
                            form dispatches through ``kernels/ops`` (Pallas
                            kernel with a problems x shift-blocks grid on
                            TPU, fused XLA scan elsewhere).
  * ``_slice_targets``   -- all requested roots bisect their brackets
                            simultaneously (one count sweep refines every
                            interval at once), then a short safeguarded
                            Newton polish sharpens each root using the
                            derivative of the same pivot recurrence --
                            the secular solver's bracket-guarded iteration
                            pattern applied to the characteristic
                            polynomial (candidate outside the bracket ->
                            bisection step; counts keep the bracket exact).
  * ``eigvalsh_tridiagonal_range`` -- the public select-by-index /
                            select-by-value API.  Compiled executables are
                            cached by ``repro.core.plan.make_range_plan``
                            (k rounds up to a power-of-two bucket and the
                            target indices are a *traced* input, so
                            repeated top-k traffic of any (il, iu) window
                            hits one executable).

Memory: O(B * (n + k)) total -- no merge tree, no selected rows; work is
O(B * k * n) per bisection sweep.  For k << n this undercuts the full
conquer by the measured multiples in BENCH_partial.json.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Bisection halvings cap.  The while_loop exits as soon as every bracket
# is below its tolerance (~53 + log2(spread/scale) halvings at float64);
# the cap only bounds the trip count for adversarial inputs.
DEFAULT_MAX_BISECT = 96

# Safeguarded Newton polish steps after bisection.  Newton on the
# characteristic polynomial is quadratically convergent from inside an
# isolated bracket, so 2 steps pin the root to ~eps * ||T|| even when the
# bisection tolerance stopped a few ulps short; each step also refines
# the bracket via its own Sturm count, so the polish can never leave it.
DEFAULT_POLISH = 2


def _pivot_floor(e2, dtype):
    """DSTEBZ-style pivot floor: guards the count recurrence's division.

    e2: (..., n-1) squared off-diagonals (may have zero length).  Returns
    a (..., 1)-shaped floor ``safmin * max(1, max e2)`` so that a pivot
    landing exactly on an eigenvalue is replaced by ``-pivmin`` (counted
    as negative, matching LAPACK's "eigenvalues <= shift" convention).
    """
    safmin = jnp.finfo(dtype).tiny
    emax = (jnp.max(e2, axis=-1, keepdims=True) if e2.shape[-1]
            else jnp.zeros(e2.shape[:-1] + (1,), dtype))
    return safmin * jnp.maximum(1.0, emax)


def sturm_count_xla(d, e2, shifts, pivmin):
    """Batched Sturm counts: #{eigenvalues of problem b <= shifts[b, s]}.

    d: (B, n); e2: (B, n-1) squared off-diagonals; shifts: (B, S);
    pivmin: (B, 1).  One fused scan over the matrix rows carries all
    B x S pivot lanes at once -- the XLA realization of the Pallas
    kernel's problems x shift-blocks grid.  Returns (B, S) int32.
    """
    q = d[:, :1] - shifts                             # (B, S)
    q = jnp.where(jnp.abs(q) < pivmin, -pivmin, q)
    cnt = (q <= 0.0).astype(jnp.int32)
    if d.shape[1] == 1:
        return cnt

    def step(carry, inp):
        q, cnt = carry
        di, e2i = inp                                 # (B,), (B,)
        q = (di[:, None] - shifts) - e2i[:, None] / q
        q = jnp.where(jnp.abs(q) < pivmin, -pivmin, q)
        return (q, cnt + (q <= 0.0).astype(jnp.int32)), None

    (q, cnt), _ = jax.lax.scan(
        step, (q, cnt), (d[:, 1:].T, e2.T))
    return cnt


def _count_and_newton(d, e2, x, pivmin):
    """One pivot sweep returning (count, logdet') at each shift.

    Same recurrence as :func:`sturm_count_xla` plus its derivative:
    with q_i the pivots of T - xI, r_i = q_i'/q_i accumulates

        s = sum_i q_i'/q_i = d/dx log|det(T - xI)| = -sum_k 1/(lam_k - x)

    so the Newton step for the nearest eigenvalue is ``x - 1/s`` (near an
    isolated root the k-th term dominates and x - 1/s -> lam_k).  The
    derivative rides the count sweep for free -- one extra multiply-add
    per row, no extra memory.
    """
    q = d[:, :1] - x
    q = jnp.where(jnp.abs(q) < pivmin, -pivmin, q)
    cnt = (q <= 0.0).astype(jnp.int32)
    r = -1.0 / q                                      # q_1' = -1
    s = r
    if d.shape[1] == 1:
        return cnt, s

    def step(carry, inp):
        q, cnt, r, s = carry
        di, e2i = inp
        u = e2i[:, None] / q                          # e2 / q_{i-1}
        qn = (di[:, None] - x) - u
        qn = jnp.where(jnp.abs(qn) < pivmin, -pivmin, qn)
        dq = -1.0 + u * r                             # q_i' via r_{i-1}
        rn = dq / qn
        return (qn, cnt + (qn <= 0.0).astype(jnp.int32), rn, s + rn), None

    (q, cnt, r, s), _ = jax.lax.scan(
        step, (q, cnt, r, s), (d[:, 1:].T, e2.T))
    return cnt, s


def _slice_targets(d, e, targets, *, maxiter: int = DEFAULT_MAX_BISECT,
                   polish: int = DEFAULT_POLISH):
    """Eigenvalues lam[targets[b]] of each problem b (traced core).

    d: (B, n); e: (B, n-1); targets: (B, k) int32 ascending indices in
    [0, n).  All B x k brackets are initialized from the per-problem
    Gershgorin bounds and refined together: every while_loop trip runs
    ONE batched Sturm sweep at the k midpoints and halves each bracket on
    its own count, exiting when the *widest* bracket converges.  A short
    safeguarded Newton polish (bracket-guarded like the secular
    iteration; out-of-bracket candidates fall back to the midpoint)
    follows.  Returns (B, k) eigenvalues, ascending along k for
    ascending targets.
    """
    from repro.kernels import ops as _ops  # deferred: kernels import core

    B, n = d.shape
    dtype = d.dtype
    eps = jnp.finfo(dtype).eps
    e2 = e * e
    pivmin = _pivot_floor(e2, dtype)                  # (B, 1)

    # Gershgorin enclosure per problem, pre-widened by one pivot floor so
    # the invariant count(lo) <= j < count(hi) holds at the endpoints.
    if n > 1:
        radius = jnp.zeros_like(d)
        radius = radius.at[:, :-1].add(jnp.abs(e)).at[:, 1:].add(jnp.abs(e))
    else:
        radius = jnp.zeros_like(d)
    glo = jnp.min(d - radius, axis=1, keepdims=True) - pivmin  # (B, 1)
    ghi = jnp.max(d + radius, axis=1, keepdims=True) + pivmin
    scale = jnp.maximum(jnp.abs(glo), jnp.abs(ghi))            # ~ ||T||
    tol = 2.0 * eps * jnp.maximum(scale, jnp.finfo(dtype).tiny) + 2.0 * pivmin

    k = targets.shape[1]
    lo = jnp.broadcast_to(glo, (B, k))
    hi = jnp.broadcast_to(ghi, (B, k))

    def count(x):
        return _ops.sturm_count_batched(d, e2, x, pivmin)

    def cond(state):
        it, lo, hi = state
        return (it < maxiter) & jnp.any(hi - lo > tol)

    def body(state):
        it, lo, hi = state
        mid = 0.5 * (lo + hi)
        above = count(mid) > targets       # count(mid) >= j+1: lam_j <= mid
        # Freeze converged brackets: their result must not depend on how
        # long the *widest* bracket in the launch keeps iterating, so a
        # root's eigenvalue is bit-identical across batch shapes, k
        # buckets and window positions that happen to share its bracket.
        live = (hi - lo) > tol
        hi = jnp.where(above & live, mid, hi)
        lo = jnp.where(~above & live, mid, lo)
        return it + 1, lo, hi

    _, lo, hi = jax.lax.while_loop(
        cond, body, (jnp.asarray(0, jnp.int32), lo, hi))
    x = 0.5 * (lo + hi)

    for _ in range(polish):
        cnt, s = _count_and_newton(d, e2, x, pivmin)
        above = cnt > targets
        hi = jnp.where(above, x, hi)
        lo = jnp.where(above, lo, x)
        cand = x - 1.0 / s
        inb = jnp.isfinite(cand) & (cand > lo) & (cand < hi)
        x = jnp.where(inb, cand, 0.5 * (lo + hi))
    return x.astype(dtype)


@jax.jit
def _sturm_count_flat(d, e2, shifts):
    return sturm_count_xla(d[None, :], e2[None, :], shifts[None, :],
                           _pivot_floor(e2[None, :], d.dtype))[0]


def sturm_count(d, e, shifts):
    """#{eigenvalues of the tridiagonal (d, e) <= shift}, any shift shape.

    Single-problem convenience wrapper over the batched count (LAPACK
    DSTEBZ negcount convention: a pivot within the floor of zero counts
    as negative).  d: (n,); e: (n-1,); shifts: any shape.  Returns int32
    of ``shifts.shape``.
    """
    d = jnp.asarray(d)
    e = jnp.asarray(e)
    shifts = jnp.asarray(shifts, d.dtype)
    cnt = _sturm_count_flat(d, e * e, shifts.reshape(-1))
    return cnt.reshape(shifts.shape)


def _validate_index_range(n: int, il, iu):
    il, iu = int(il), int(iu)
    if not (0 <= il <= iu < n):
        raise ValueError(
            f"index range must satisfy 0 <= il <= iu < n; got il={il}, "
            f"iu={iu}, n={n} (indices are 0-based and inclusive)")
    return il, iu


def eigvalsh_tridiagonal_range(d, e, *, select: str = "i",
                               il=None, iu=None, vl=None, vu=None,
                               maxiter: int = DEFAULT_MAX_BISECT,
                               polish: int = DEFAULT_POLISH,
                               dtype=None):
    """Selected eigenvalues of the symmetric tridiagonal (d, e).

    The partial-spectrum front door: brackets exactly the requested
    eigenvalues with Sturm-count bisection (all intervals refined in
    parallel) and polishes each with a bracket-safeguarded Newton
    iteration -- O(k * n) work and O(n + k) memory, no merge tree, which
    beats the full conquer by multiples for k << n (BENCH_partial.json).

    Args:
      d: (n,) diagonal, or (B, n) for a problem batch.
      e: (n-1,) off-diagonal, or (B, n-1).
      select: "i" -- eigenvalues with 0-based ascending indices in the
        inclusive range [il, iu] (scipy's ``select='i'`` convention);
        "v" -- eigenvalues in the half-open interval (vl, vu]
        (single-problem only: the per-problem hit count would be ragged
        across a batch).
      maxiter: bisection halvings cap (the loop exits early on
        convergence).
      polish: safeguarded Newton polish steps after bisection.

    Returns:
      (k,) ascending eigenvalues (or (B, k) for batched inputs) where
      k = iu - il + 1 for select="i" and the count of eigenvalues in
      (vl, vu] for select="v" (possibly 0).  Accuracy contract: each
      returned eigenvalue matches the corresponding entry of the full
      solve to <= 8 * eps * ||T||.
    """
    # The request core (repro.core.request) owns selection resolution
    # (select="v" becomes an index window via two Sturm counts there) and
    # the plan-cache launch; this wrapper exists for the keyword-argument
    # surface.  Service and sync range requests therefore share one code
    # path by construction.
    from repro.core.request import SolveRequest, execute_request
    knobs = {"maxiter": maxiter, "polish": polish}
    if dtype is not None:
        knobs["dtype"] = dtype
    req = SolveRequest(d=d, e=e, kind="range", select=select, il=il, iu=iu,
                       vl=vl, vu=vu, knobs=knobs)
    return execute_request(req).eigenvalues
