"""Partial-spectrum slicing: Sturm-count bisection + safeguarded Newton.

The BR algorithm makes *all-eigenvalue* solves linear-space, but real
spectral workloads (SLQ edge estimates, extremal-mode monitoring,
condition numbers) want k << n eigenvalues.  This module brackets exactly
the requested eigenvalues with Gershgorin bounds + vectorized
Sturm-sequence counts and refines every bracket **in parallel** inside
one ``lax.while_loop`` -- the spectrum-slicing front end of Keyes et
al.'s partial-spectrum D&C, realized on the library's batch-first
substrate:

  * ``sturm_count``      -- #{eigenvalues <= shift} via the LAPACK DSTEBZ
                            pivot recurrence (negcount of LDL^T), vectorized
                            over arbitrary shift batches.  The hot batched
                            form dispatches through ``kernels/ops`` (Pallas
                            kernel with a problems x shift-blocks grid on
                            TPU, fused XLA scan elsewhere).
  * ``_slice_targets``   -- all requested roots bisect their brackets
                            simultaneously (one count sweep refines every
                            interval at once), then a short safeguarded
                            Newton polish sharpens each root using the
                            derivative of the same pivot recurrence --
                            the secular solver's bracket-guarded iteration
                            pattern applied to the characteristic
                            polynomial (candidate outside the bracket ->
                            bisection step; counts keep the bracket exact).
  * ``eigvalsh_tridiagonal_range`` -- the public select-by-index /
                            select-by-value API.  Compiled executables are
                            cached by ``repro.core.plan.make_range_plan``
                            (k rounds up to a power-of-two bucket and the
                            target indices are a *traced* input, so
                            repeated top-k traffic of any (il, iu) window
                            hits one executable).
  * ``refine_clusters``  -- the mixed-precision pipeline's f64 stage:
                            certify approximate (f32-tree) eigenvalues
                            with ONE sorted f64 count sweep, then polish
                            only the non-certified clusters with a
                            bracket-guarded secant/Newton/bisection loop
                            against the original (d, e) -- the same
                            freeze-per-bracket pattern as
                            ``_slice_targets``, with the live set
                            compacted between launches so refinement cost
                            is proportional to the miss set, not n.

Memory: O(B * (n + k)) total -- no merge tree, no selected rows; work is
O(B * k * n) per bisection sweep.  For k << n this undercuts the full
conquer by the measured multiples in BENCH_partial.json.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import guard as _guard
from repro.core.instrument import SolveCounter

# Bisection halvings cap.  The while_loop exits as soon as every bracket
# is below its tolerance (~53 + log2(spread/scale) halvings at float64);
# the cap only bounds the trip count for adversarial inputs.
DEFAULT_MAX_BISECT = 96

# Safeguarded Newton polish steps after bisection.  Newton on the
# characteristic polynomial is quadratically convergent from inside an
# isolated bracket, so 2 steps pin the root to ~eps * ||T|| even when the
# bisection tolerance stopped a few ulps short; each step also refines
# the bracket via its own Sturm count, so the polish can never leave it.
DEFAULT_POLISH = 2

# Unroll factor of the pivot-recurrence scans.  The recurrence is a
# sequential dependency chain per shift lane, so unrolling changes loop
# structure only, never per-lane arithmetic order (counts and derivative
# sums are bit-identical at any unroll); it cuts the CPU sweep cost
# ~35-40% at wide lane counts by amortizing the scan's per-step dispatch.
_SCAN_UNROLL = 8

# Certification tolerance of the mixed-precision pipeline, in units of
# eps_f64 * max(1, ||T||_inf).  16 keeps the mixed path's contribution at
# a quarter of the 64-eps cross-library conformance budget: the f64 D&C
# itself and LAPACK's drivers each deviate up to ~50 eps * ||T|| from one
# another at n = 4096, so certifying tighter buys nothing observable while
# costing refinement iterations on every near-degenerate cluster.
DEFAULT_REFINE_TOL = 16.0

# Certify -> refine rounds cap.  The refine loop's delta-freeze criterion
# is a heuristic (a tiny secant step near an unresolved pair can freeze a
# lane early), so soundness comes from *re-certifying* after each refine
# pass -- certification is sound by construction (count-verified
# two-sided brackets).  Measured round trajectories collapse after one
# pass (miss counts [4096, 0] at n = 4096 random); 4 bounds adversarial
# spectra.
DEFAULT_REFINE_ROUNDS = 4

# while_loop trips per refine launch before the host loop compacts the
# live set: long enough to amortize a launch, short enough that lanes
# converging at the secant's superlinear rate stop paying for stragglers.
_REFINE_TRIPS = 4

# Refine launches per certify round: 24 launches * 4 trips = 96 bracket
# halvings even in the pure-bisection worst case -- the same budget as
# DEFAULT_MAX_BISECT, reached only if both secant and Newton candidates
# fail every trip.
_REFINE_MAX_LAUNCHES = 24

# One trace per (batch, lane-bucket) shape of the certify/refine
# executors -- same contract as plan.EXECUTOR_TRACES; surfaced through
# plan.plan_cache_stats() and reset by plan.clear_plan_cache().
REFINE_EXECUTOR_TRACES = SolveCounter("refine_executor_traces")


def _pivot_floor(e2, dtype):
    """DSTEBZ-style pivot floor: guards the count recurrence's division.

    e2: (..., n-1) squared off-diagonals (may have zero length).  Returns
    a (..., 1)-shaped floor ``safmin * max(1, max e2)`` so that a pivot
    landing exactly on an eigenvalue is replaced by ``-pivmin`` (counted
    as negative, matching LAPACK's "eigenvalues <= shift" convention).
    """
    safmin = jnp.finfo(dtype).tiny
    emax = (jnp.max(e2, axis=-1, keepdims=True) if e2.shape[-1]
            else jnp.zeros(e2.shape[:-1] + (1,), dtype))
    return safmin * jnp.maximum(1.0, emax)


def sturm_count_xla(d, e2, shifts, pivmin):
    """Batched Sturm counts: #{eigenvalues of problem b <= shifts[b, s]}.

    d: (B, n); e2: (B, n-1) squared off-diagonals; shifts: (B, S);
    pivmin: (B, 1).  One fused scan over the matrix rows carries all
    B x S pivot lanes at once -- the XLA realization of the Pallas
    kernel's problems x shift-blocks grid.  Returns (B, S) int32.
    """
    q = d[:, :1] - shifts                             # (B, S)
    q = jnp.where(jnp.abs(q) < pivmin, -pivmin, q)
    cnt = (q <= 0.0).astype(jnp.int32)
    if d.shape[1] == 1:
        return cnt

    def step(carry, inp):
        q, cnt = carry
        di, e2i = inp                                 # (B,), (B,)
        q = (di[:, None] - shifts) - e2i[:, None] / q
        q = jnp.where(jnp.abs(q) < pivmin, -pivmin, q)
        return (q, cnt + (q <= 0.0).astype(jnp.int32)), None

    (q, cnt), _ = jax.lax.scan(
        step, (q, cnt), (d[:, 1:].T, e2.T), unroll=_SCAN_UNROLL)
    return cnt


def _count_and_newton(d, e2, x, pivmin):
    """One pivot sweep returning (count, logdet') at each shift.

    Same recurrence as :func:`sturm_count_xla` plus its derivative:
    with q_i the pivots of T - xI, r_i = q_i'/q_i accumulates

        s = sum_i q_i'/q_i = d/dx log|det(T - xI)| = -sum_k 1/(lam_k - x)

    so the Newton step for the nearest eigenvalue is ``x - 1/s`` (near an
    isolated root the k-th term dominates and x - 1/s -> lam_k).  The
    derivative rides the count sweep for free -- one extra multiply-add
    per row, no extra memory.
    """
    q = d[:, :1] - x
    q = jnp.where(jnp.abs(q) < pivmin, -pivmin, q)
    cnt = (q <= 0.0).astype(jnp.int32)
    r = -1.0 / q                                      # q_1' = -1
    s = r
    if d.shape[1] == 1:
        return cnt, s

    def step(carry, inp):
        q, cnt, r, s = carry
        di, e2i = inp
        u = e2i[:, None] / q                          # e2 / q_{i-1}
        qn = (di[:, None] - x) - u
        qn = jnp.where(jnp.abs(qn) < pivmin, -pivmin, qn)
        dq = -1.0 + u * r                             # q_i' via r_{i-1}
        rn = dq / qn
        return (qn, cnt + (qn <= 0.0).astype(jnp.int32), rn, s + rn), None

    (q, cnt, r, s), _ = jax.lax.scan(
        step, (q, cnt, r, s), (d[:, 1:].T, e2.T), unroll=_SCAN_UNROLL)
    return cnt, s


def _slice_targets(d, e, targets, *, maxiter: int = DEFAULT_MAX_BISECT,
                   polish: int = DEFAULT_POLISH):
    """Eigenvalues lam[targets[b]] of each problem b (traced core).

    d: (B, n); e: (B, n-1); targets: (B, k) int32 ascending indices in
    [0, n).  All B x k brackets are initialized from the per-problem
    Gershgorin bounds and refined together: every while_loop trip runs
    ONE batched Sturm sweep at the k midpoints and halves each bracket on
    its own count, exiting when the *widest* bracket converges.  A short
    safeguarded Newton polish (bracket-guarded like the secular
    iteration; out-of-bracket candidates fall back to the midpoint)
    follows.  Returns (B, k) eigenvalues, ascending along k for
    ascending targets.
    """
    from repro.kernels import ops as _ops  # deferred: kernels import core

    B, n = d.shape
    dtype = d.dtype
    eps = jnp.finfo(dtype).eps
    e2 = e * e
    pivmin = _pivot_floor(e2, dtype)                  # (B, 1)

    # Gershgorin enclosure per problem, pre-widened by one pivot floor so
    # the invariant count(lo) <= j < count(hi) holds at the endpoints.
    if n > 1:
        radius = jnp.zeros_like(d)
        radius = radius.at[:, :-1].add(jnp.abs(e)).at[:, 1:].add(jnp.abs(e))
    else:
        radius = jnp.zeros_like(d)
    glo = jnp.min(d - radius, axis=1, keepdims=True) - pivmin  # (B, 1)
    ghi = jnp.max(d + radius, axis=1, keepdims=True) + pivmin
    scale = jnp.maximum(jnp.abs(glo), jnp.abs(ghi))            # ~ ||T||
    tol = 2.0 * eps * jnp.maximum(scale, jnp.finfo(dtype).tiny) + 2.0 * pivmin

    k = targets.shape[1]
    lo = jnp.broadcast_to(glo, (B, k))
    hi = jnp.broadcast_to(ghi, (B, k))

    def count(x):
        return _ops.sturm_count_batched(d, e2, x, pivmin)

    def cond(state):
        it, lo, hi = state
        return (it < maxiter) & jnp.any(hi - lo > tol)

    def body(state):
        it, lo, hi = state
        mid = 0.5 * (lo + hi)
        above = count(mid) > targets       # count(mid) >= j+1: lam_j <= mid
        # Freeze converged brackets: their result must not depend on how
        # long the *widest* bracket in the launch keeps iterating, so a
        # root's eigenvalue is bit-identical across batch shapes, k
        # buckets and window positions that happen to share its bracket.
        live = (hi - lo) > tol
        hi = jnp.where(above & live, mid, hi)
        lo = jnp.where(~above & live, mid, lo)
        return it + 1, lo, hi

    _, lo, hi = jax.lax.while_loop(
        cond, body, (jnp.asarray(0, jnp.int32), lo, hi))
    x = 0.5 * (lo + hi)

    for _ in range(polish):
        cnt, s = _count_and_newton(d, e2, x, pivmin)
        above = cnt > targets
        hi = jnp.where(above, x, hi)
        lo = jnp.where(above, lo, x)
        cand = x - 1.0 / s
        inb = jnp.isfinite(cand) & (cand > lo) & (cand < hi)
        x = jnp.where(inb, cand, 0.5 * (lo + hi))
    return x.astype(dtype)


@jax.jit
def _sturm_count_flat(d, e2, shifts):
    return sturm_count_xla(d[None, :], e2[None, :], shifts[None, :],
                           _pivot_floor(e2[None, :], d.dtype))[0]


def sturm_count(d, e, shifts):
    """#{eigenvalues of the tridiagonal (d, e) <= shift}, any shift shape.

    Single-problem convenience wrapper over the batched count (LAPACK
    DSTEBZ negcount convention: a pivot within the floor of zero counts
    as negative).  d: (n,); e: (n-1,); shifts: any shape.  Returns int32
    of ``shifts.shape``.  Malformed input (empty/non-1-D ``d``, ``e`` not
    of width n-1, NaN/Inf entries) raises
    :class:`repro.core.guard.InvalidInputError`.
    """
    if np.ndim(d) != 1:
        raise _guard.InvalidInputError(
            f"sturm_count: d must be 1-D (n,), got shape {np.shape(d)} "
            f"(use the plan/request layer for batched problems)",
            field="d")
    _guard.validate_problem(d, e, name="sturm_count")
    d = jnp.asarray(d)
    e = jnp.asarray(e)
    shifts = jnp.asarray(shifts, d.dtype)
    cnt = _sturm_count_flat(d, e * e, shifts.reshape(-1))
    return cnt.reshape(shifts.shape)


class SpectrumCertificate(NamedTuple):
    """Result of :func:`certify_spectrum`.

    certified: (n,) or (B, n) bool -- True where the true j-th eigenvalue
        provably lies within ``tol`` of ``lam[..., j]``.
    lo / hi: tightest count-verified enclosure the sweep observed for
        each eigenvalue (always valid, certified or not).
    tol: (1,) or (B, 1) absolute tolerance the certificate used,
        ``tol_factor * eps * max(1, ||T||_inf)`` per problem.
    """
    certified: object
    lo: object
    hi: object
    tol: object

    @property
    def all_certified(self) -> bool:
        return bool(np.asarray(self.certified).all())


def certify_spectrum(d, e, lam, *, tol: float = DEFAULT_REFINE_TOL,
                     nvalid=None):
    """Certify approximate eigenvalues with ONE batched Sturm count sweep.

    The robustness layer's product-facing certifier (PR 7's mixed-
    precision ``_certify_executor``, generalized to every method and
    precision): for each approximate eigenvalue ``lam[..., j]`` the sweep
    verifies -- by exact integer Sturm counts against the ORIGINAL
    ``(d, e)``, sound in any precision -- whether the true j-th
    eigenvalue lies in ``(lam_j - tol_abs, lam_j + tol_abs]`` where
    ``tol_abs = tol * eps * max(1, ||T||_inf)`` in the input dtype.
    Cost is one fused count sweep over 2n shifts per problem, the same
    executable the mixed pipeline reuses, amortized across coalesced
    flushes by the serving layer.

    Args:
      d: (n,) or (B, n) diagonals.
      e: (n-1,) or (B, n-1) off-diagonals.
      lam: approximate eigenvalues, ascending, same leading shape as d.
      tol: tolerance in ``eps * max(1, ||T||_inf)`` units (eps of the
        INPUT dtype, so f32 problems certify against an f32-meaningful
        bound).
      nvalid: optional (B,) real-lane counts for rows carrying decoupled
        sentinel padding (the plan/serve convention); padded lanes
        certify vacuously.

    Returns:
      :class:`SpectrumCertificate`; shapes follow the input (1-D in,
      1-D out).
    """
    _guard.validate_problem(d, e, name="certify_spectrum")
    single = np.ndim(d) == 1
    d = jnp.atleast_2d(jnp.asarray(d))
    e = jnp.atleast_2d(jnp.asarray(e))
    lam = jnp.atleast_2d(jnp.asarray(lam, d.dtype))
    if lam.shape != d.shape:
        raise _guard.InvalidInputError(
            f"certify_spectrum: lam must match d's shape {tuple(d.shape)} "
            f"(one estimate per eigenvalue), got {tuple(lam.shape)}",
            field="lam")
    B, n = d.shape
    nvalid_arr = (jnp.full((B,), n, jnp.int32) if nvalid is None
                  else jnp.atleast_1d(jnp.asarray(nvalid, jnp.int32)))
    if float(tol) <= 0.0:
        raise _guard.InvalidInputError(
            f"certify_spectrum: tol must be positive, got {tol}",
            field="tol")
    tol_arr = jnp.asarray(float(tol), d.dtype)
    cert, lo, hi, tol_abs = _certify_executor(d, e * e, lam, nvalid_arr,
                                              tol_arr)
    if single:
        cert, lo, hi, tol_abs = cert[0], lo[0], hi[0], tol_abs[0]
    return SpectrumCertificate(cert, lo, hi, tol_abs)


# ---------------------------------------------------------------------------
# Mixed-precision refinement: f64 Sturm certification + targeted polish
# ---------------------------------------------------------------------------


@jax.jit
def _certify_executor(d, e2, lam, nvalid, tol_factor):
    """Certify all approximate eigenvalues with ONE f64 count sweep.

    d: (B, N); e2: (B, N-1); lam: (B, N) approximate eigenvalues (rows may
    carry decoupled sentinel padding at index >= nvalid[b]); nvalid: (B,)
    int32 real targets per row; tol_factor: f64 scalar (traced, so one
    executable serves every tolerance).

    The 2N shifts ``lam_j -+ tol`` are evaluated in one fused sweep;
    target j is certified iff ``count(lam_j - tol) <= j`` and
    ``count(lam_j + tol) >= j + 1`` -- i.e. the true lam_j provably lies
    in ``(lam_j - tol, lam_j + tol]``.  Sorting the evaluated (shift,
    count) pairs makes the counts monotone, so each target also extracts
    the TIGHTEST verified bracket the sweep observed anywhere (a
    neighbour's shift is often far closer than the lane's own +-tol
    endpoints), which is what lets the refine loop start a few bisections
    from done.  Returns (cert (B, N) bool, lo (B, N), hi (B, N),
    tol (B, 1)); non-real lanes certify vacuously.
    """
    REFINE_EXECUTOR_TRACES.increment()
    B, N = d.shape
    dtype = d.dtype
    pivmin = _pivot_floor(e2, dtype)
    j = jnp.arange(N, dtype=jnp.int32)[None, :]
    valid = j < nvalid[:, None]

    # Per-problem scale masked to real rows: padded sentinel diagonals sit
    # ABOVE the real Gershgorin bound by construction and would inflate
    # the tolerance; sentinel couplings are exactly zero, so e2 needs no
    # mask.
    e_abs = jnp.sqrt(e2)
    dmax = jnp.max(jnp.where(valid, jnp.abs(d), 0.0), axis=1, keepdims=True)
    emax = (jnp.max(e_abs, axis=1, keepdims=True) if e2.shape[1]
            else jnp.zeros((B, 1), dtype))
    tol = tol_factor * jnp.finfo(dtype).eps * jnp.maximum(
        1.0, dmax + 2.0 * emax)

    shifts = jnp.concatenate([lam - tol, lam + tol], axis=1)     # (B, 2N)
    cnt = sturm_count_xla(d, e2, shifts, pivmin)                 # (B, 2N)
    cert = (cnt[:, :N] <= j) & (cnt[:, N:] >= j + 1) | ~valid

    order = jnp.argsort(shifts, axis=1)
    ss = jnp.take_along_axis(shifts, order, axis=1)
    cs = jnp.take_along_axis(cnt, order, axis=1)

    def brackets(cs_b):
        # cs_b is monotone nondecreasing along the sorted shifts:
        # largest evaluated shift with count <= j is a verified lower
        # bound (lam_j > shift), smallest with count >= j+1 a verified
        # upper bound (lam_j <= shift).
        ilo = jnp.searchsorted(cs_b, j[0], side="right") - 1
        ihi = jnp.searchsorted(cs_b, j[0] + 1, side="left")
        return ilo, ihi

    ilo, ihi = jax.vmap(brackets)(cs)
    # Gershgorin fallback at the sweep's extremes (padded rows only widen
    # the enclosure -- sentinel diagonals raise ghi, never lower glo --
    # so the unmasked bound stays sound).
    radius = jnp.zeros_like(d)
    if e2.shape[1]:
        radius = radius.at[:, :-1].add(e_abs).at[:, 1:].add(e_abs)
    glo = jnp.min(d - radius, axis=1, keepdims=True) - pivmin
    ghi = jnp.max(d + radius, axis=1, keepdims=True) + pivmin
    lo = jnp.where(ilo >= 0,
                   jnp.take_along_axis(ss, jnp.maximum(ilo, 0), axis=1), glo)
    hi = jnp.where(ihi < 2 * N,
                   jnp.take_along_axis(ss, jnp.minimum(ihi, 2 * N - 1),
                                       axis=1), ghi)
    return cert, lo, hi, tol


@functools.partial(jax.jit, static_argnames=("maxiter",))
def _refine_executor(d, e2, x, lo, hi, xp, gp, tgt, live, tol, *, maxiter):
    """Bracket-guarded f64 polish of the compacted live lanes.

    d: (B, n); e2: (B, n-1); x, lo, hi: (B, k) iterates and count-verified
    brackets; xp, gp: previous (iterate, g) pair seeding the secant slope
    (xp == x flags "no history": the slope divides to non-finite and the
    first trip falls back to Newton); tgt: (B, k) int32 target indices;
    live: (B, k); tol: (B, 1) certification tolerance.

    Each trip runs ONE fused count+derivative sweep over all lanes.  With
    s = -sum_k 1/(lam_k - x), the function g(x) = 1/s has a simple zero
    at each eigenvalue -- but its local slope is NOT 1 near close pairs
    (g there is ~the harmonic mean of the pole distances), which is why
    plain Newton ``x - g`` degrades to rate-1/2 linear convergence
    exactly on the clusters the f32 tree missed.  The secant step
    ``x - g * (x - xp) / (g - gp)`` measures the true slope and restores
    superlinear convergence (measured: halves total polish iterations);
    candidates are accepted only when finite, strictly inside the
    count-updated bracket, and on a credibly positive slope, falling back
    to Newton then to the bisection midpoint.  Convergence freezes a
    lane's entire state (freeze-per-bracket: results never depend on how
    long stragglers iterate, so refinement is deterministic across
    live-set compactions).  Returns (x, lo, hi, xp, gp, live, iters).
    """
    REFINE_EXECUTOR_TRACES.increment()
    pivmin = _pivot_floor(e2, d.dtype)
    tolf = 0.5 * tol     # freeze at half the certification tolerance

    def cond(state):
        it, x, lo, hi, xp, gp, live, its = state
        return (it < maxiter) & jnp.any(live)

    def body(state):
        it, x, lo, hi, xp, gp, live, its = state
        cnt, s = _count_and_newton(d, e2, x, pivmin)
        above = cnt > tgt                  # count(x) >= j+1: lam_j <= x
        nhi = jnp.where(above & live, x, hi)
        nlo = jnp.where(~above & live, x, lo)
        g = 1.0 / s
        cand_n = x - g
        slope = (g - gp) / (x - xp)
        cand_s = x - g / slope
        ok_s = (jnp.isfinite(cand_s) & (cand_s > nlo) & (cand_s < nhi)
                & (slope > 0.05))
        ok_n = jnp.isfinite(cand_n) & (cand_n > nlo) & (cand_n < nhi)
        nx = jnp.where(ok_s, cand_s,
                       jnp.where(ok_n, cand_n, 0.5 * (nlo + nhi)))
        conv = (nhi - nlo <= tolf) | (jnp.abs(nx - x) <= 0.25 * tolf)
        nxp = jnp.where(live, x, xp)
        ngp = jnp.where(live, g, gp)
        nx = jnp.where(live, nx, x)
        its = its + jnp.sum(live, dtype=jnp.int32)
        return it + 1, nx, nlo, nhi, nxp, ngp, live & ~conv, its

    state = (jnp.asarray(0, jnp.int32), x, lo, hi, xp, gp, live,
             jnp.asarray(0, jnp.int32))
    _, x, lo, hi, xp, gp, live, its = jax.lax.while_loop(cond, body, state)
    return x, lo, hi, xp, gp, live, its


def _bucket(k: int) -> int:
    """Next power of two (min 1) -- lane-count buckets keep the refine
    executor's trace count logarithmic in n."""
    return 1 << max(0, (int(k) - 1).bit_length())


def _refine_misses(d, e2, lamh, loh, hih, tol_dev, miss):
    """Host-driven refinement of the miss set with live-lane compaction.

    d, e2: device (B, n)/(B, n-1); lamh, loh, hih: HOST (B, n) float64
    state arrays (mutated in place: refined lanes are scattered back);
    tol_dev: (B, 1) device tolerance; miss: host (B, n) bool.

    Every ``_REFINE_TRIPS`` while_loop trips, still-live lanes are
    gathered to the host, compacted to each problem's live set (padded to
    the batch max, bucketed to a power of two so launches reuse cached
    executables), and re-launched -- the full-width sweep cost decays
    with the live set instead of paying n lanes until the last straggler
    freezes.  Secant history (xp, gp) is carried across compactions.
    Freeze-per-bracket makes per-lane trajectories independent of the
    compaction schedule, so results are deterministic.  Returns total
    polish iterations.
    """
    B, n = miss.shape
    xph = lamh.copy()      # xp == x: no secant history yet
    gph = np.zeros_like(lamh)
    idxs = [np.nonzero(miss[b])[0].astype(np.int32) for b in range(B)]
    iters = 0
    for _ in range(_REFINE_MAX_LAUNCHES):
        kmax = max(len(ix) for ix in idxs)
        if kmax == 0:
            break
        k = min(_bucket(kmax), n)
        gidx = np.zeros((B, k), np.int32)
        live = np.zeros((B, k), bool)
        for b, ix in enumerate(idxs):
            gidx[b, :len(ix)] = ix
            live[b, :len(ix)] = True
        take = lambda a: jnp.asarray(np.take_along_axis(a, gidx, axis=1))
        x1, lo1, hi1, xp1, gp1, live1, its = _refine_executor(
            d, e2, take(lamh), take(loh), take(hih), take(xph), take(gph),
            jnp.asarray(gidx), jnp.asarray(live), tol_dev,
            maxiter=_REFINE_TRIPS)
        iters += int(its)
        x1, lo1, hi1 = np.asarray(x1), np.asarray(lo1), np.asarray(hi1)
        xp1, gp1, live1 = np.asarray(xp1), np.asarray(gp1), np.asarray(live1)
        for b in range(B):
            ix = gidx[b, live[b]]
            for src, dst in ((x1, lamh), (lo1, loh), (hi1, hih),
                             (xp1, xph), (gp1, gph)):
                dst[b, ix] = src[b, live[b]]
            idxs[b] = gidx[b, live[b] & live1[b]]
    return iters


def refine_clusters(d, e, lam, *, nvalid=None,
                    tol_factor: float = DEFAULT_REFINE_TOL,
                    rounds: int = DEFAULT_REFINE_ROUNDS, sort: bool = True):
    """Sturm-certified f64 refinement of approximate eigenvalues.

    The mixed-precision pipeline's second stage: ``lam`` holds all n
    eigenvalue estimates of each problem (typically the f32 D&C tree's
    output, upcast), and this stage makes them meet the documented
    ``tol_factor * eps_f64 * max(1, ||T||_inf)`` bound against the
    original float64 (d, e) -- certifying everything with one f64 count
    sweep per round and polishing ONLY the non-certified clusters, so the
    f64 work is proportional to the miss set.

    Args:
      d: (B, n) float64 diagonals (rows may carry decoupled sentinel
        padding above ``nvalid[b]`` -- the plan/serve padding convention;
        sentinel lanes are never touched).
      e: (B, n-1) float64 off-diagonals.
      lam: (B, n) approximate eigenvalues, ascending per problem.
      nvalid: optional (B,) int32 count of real eigenvalues per row
        (default: n).
      tol_factor: certification tolerance in eps_f64 * ||T|| units.
      rounds: certify->refine rounds cap (see DEFAULT_REFINE_ROUNDS; the
        loop exits as soon as a certify pass accepts every target, which
        is what makes the heuristic freeze criteria sound).
      sort: re-sort each row ascending before returning (refined values
        each lie within tol of the sorted truth, so the sort restores
        exact ordering without breaking any per-index bound).  Callers
        that must permute companion state identically -- boundary rows --
        pass False and apply their own argsort.

    Returns:
      (lam_refined (B, n) float64, info) with info keys ``targets``
      (real eigenvalues certified), ``polished`` (lanes refined),
      ``iterations`` (total polish sweeps), ``rounds`` (certify->refine
      rounds that found misses), and ``polished_mask`` (host (B, n) bool:
      exactly the lanes the polish loop touched -- unset lanes are
      returned bit-identical to their input).
    """
    if not jax.config.jax_enable_x64:
        raise ValueError(
            "refine_clusters certifies against float64 Sturm counts; "
            "enable jax_enable_x64 (see the README mixed-precision "
            "runbook)")
    d = jnp.asarray(d, jnp.float64)
    e = jnp.asarray(e, jnp.float64)
    lam = jnp.asarray(lam, jnp.float64)
    B, n = d.shape
    e2 = e * e
    nvalid_arr = (jnp.full((B,), n, jnp.int32) if nvalid is None
                  else jnp.asarray(nvalid, jnp.int32))
    tol_arr = jnp.asarray(float(tol_factor), jnp.float64)

    polished_mask = np.zeros((B, n), bool)
    iters = 0
    rounds_used = 0
    lamh = None
    for _ in range(max(1, int(rounds))):
        cert, lo, hi, tol_dev = _certify_executor(d, e2, lam, nvalid_arr,
                                                  tol_arr)
        miss = ~np.asarray(cert)
        if not miss.any():
            break
        rounds_used += 1
        polished_mask |= miss
        lamh = np.asarray(lam).copy()
        iters += _refine_misses(d, e2, lamh, np.asarray(lo).copy(),
                                np.asarray(hi).copy(), tol_dev, miss)
        lam = jnp.asarray(lamh)
    if sort:
        lam = jnp.sort(lam, axis=1)
    info = {"targets": int(np.asarray(
                jnp.sum(jnp.minimum(nvalid_arr, n)))),
            "polished": int(polished_mask.sum()),
            "iterations": iters, "rounds": rounds_used,
            "polished_mask": polished_mask}
    return lam, info


def _validate_index_range(n: int, il, iu):
    il, iu = int(il), int(iu)
    if not (0 <= il <= iu < n):
        raise ValueError(
            f"index range must satisfy 0 <= il <= iu < n; got il={il}, "
            f"iu={iu}, n={n} (indices are 0-based and inclusive)")
    return il, iu


def eigvalsh_tridiagonal_range(d, e, *, select: str = "i",
                               il=None, iu=None, vl=None, vu=None,
                               maxiter: int = DEFAULT_MAX_BISECT,
                               polish: int = DEFAULT_POLISH,
                               dtype=None):
    """Selected eigenvalues of the symmetric tridiagonal (d, e).

    The partial-spectrum front door: brackets exactly the requested
    eigenvalues with Sturm-count bisection (all intervals refined in
    parallel) and polishes each with a bracket-safeguarded Newton
    iteration -- O(k * n) work and O(n + k) memory, no merge tree, which
    beats the full conquer by multiples for k << n (BENCH_partial.json).

    Args:
      d: (n,) diagonal, or (B, n) for a problem batch.
      e: (n-1,) off-diagonal, or (B, n-1).
      select: "i" -- eigenvalues with 0-based ascending indices in the
        inclusive range [il, iu] (scipy's ``select='i'`` convention);
        "v" -- eigenvalues in the half-open interval (vl, vu]
        (single-problem only: the per-problem hit count would be ragged
        across a batch).
      maxiter: bisection halvings cap (the loop exits early on
        convergence).
      polish: safeguarded Newton polish steps after bisection.

    Returns:
      (k,) ascending eigenvalues (or (B, k) for batched inputs) where
      k = iu - il + 1 for select="i" and the count of eigenvalues in
      (vl, vu] for select="v" (possibly 0).  Accuracy contract: each
      returned eigenvalue matches the corresponding entry of the full
      solve to <= 8 * eps * ||T||.
    """
    # The request core (repro.core.request) owns selection resolution
    # (select="v" becomes an index window via two Sturm counts there) and
    # the plan-cache launch; this wrapper exists for the keyword-argument
    # surface.  Service and sync range requests therefore share one code
    # path by construction.
    from repro.core.request import SolveRequest, execute_request
    knobs = {"maxiter": maxiter, "polish": polish}
    if dtype is not None:
        knobs["dtype"] = dtype
    req = SolveRequest(d=d, e=e, kind="range", select=select, il=il, iu=iu,
                       vl=vl, vu=vu, knobs=knobs)
    return execute_request(req).eigenvalues
