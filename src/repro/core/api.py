"""Public eigensolver API.

    from repro.core import eigvalsh_tridiagonal
    lam = eigvalsh_tridiagonal(d, e)                    # BR (paper), O(n) memory
    lam = eigvalsh_tridiagonal(d, e, method="sterf")    # QR/QL baseline
    lam = eigvalsh_tridiagonal(d, e, method="lazy")     # internal values-only D&C
    lam = eigvalsh_tridiagonal(d, e, method="full")     # conventional D&C (discard Q)
    lam = eigvalsh_tridiagonal(d, e, method="bisect")   # Sturm bisection reference

Partial spectrum (k << n eigenvalues by index or value window):

    from repro.core import eigvalsh_tridiagonal_range
    top = eigvalsh_tridiagonal_range(d, e, select="i", il=n - 32, iu=n - 1)
    band = eigvalsh_tridiagonal_range(d, e, select="v", vl=0.0, vu=2.5)

Batched front door (one device solve for B problems, B * O(n) state):

    from repro.core import eigvalsh_tridiagonal_batch
    res = eigvalsh_tridiagonal_batch(D, E)              # D (B, n), E (B, n-1)
    res.eigenvalues                                     # (B, n) ascending

``eigvalsh_tridiagonal`` itself also accepts stacked (B, n) inputs and
routes them per method: "br" runs natively batched through the
plan/executor core (one launch, bucketed compile cache) and "bisect"
through the batched range executor; the baselines (which exist to model
per-problem quadratic state) fall back to a loop of single solves and
return the stacked (B, n) spectra.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.bisect import eigvalsh_tridiagonal_range
from repro.core.br_dc import (eigvalsh_tridiagonal_batch,
                              eigvalsh_tridiagonal_br)
from repro.core.sterf import eigvalsh_tridiagonal_sterf
from repro.core import baselines as _bl

METHODS = ("br", "sterf", "lazy", "full", "eigh", "bisect")


def _solve_single(d, e, method, kw):
    if method == "br":
        return eigvalsh_tridiagonal_br(d, e, **kw).eigenvalues
    if method == "sterf":
        return eigvalsh_tridiagonal_sterf(d, e, **kw)
    if method == "lazy":
        return _bl.eigvalsh_tridiagonal_lazy(d, e, **kw)
    if method == "full":
        return _bl.eigvalsh_tridiagonal_full_discard(d, e, **kw)
    if method == "eigh":
        from repro.core.tridiag import dense_from_tridiag
        return jnp.linalg.eigvalsh(dense_from_tridiag(d, e))
    if method == "bisect":
        return _bl.eigvalsh_tridiagonal_bisect(d, e, **kw)
    raise ValueError(f"unknown method {method!r}; choose from {METHODS}")


def eigvalsh_tridiagonal(d, e, method: str = "br", **kw):
    """All eigenvalues (ascending) of the symmetric tridiagonal (d, e).

    1-D inputs solve one problem and return (n,); stacked (B, n) /
    (B, n-1) inputs solve the batch and return (B, n) -- natively for
    "br" (one device solve via the plan/executor core) and "bisect"
    (one sliced solve over all indices), looped for the baseline
    methods.
    """
    d = jnp.asarray(d)
    e = jnp.asarray(e)
    if d.ndim == 2:
        if method == "br":
            return eigvalsh_tridiagonal_batch(d, e, **kw).eigenvalues
        if method == "bisect":
            # Natively batched: one sliced solve covering all n indices.
            n = d.shape[1]
            return eigvalsh_tridiagonal_range(d, e, select="i", il=0,
                                              iu=n - 1, **kw)
        if method not in METHODS:
            raise ValueError(
                f"unknown method {method!r}; choose from {METHODS}")
        from repro.core.br_dc import _as_batch
        d, e = _as_batch(d, e, None)  # same shape contract as the br path
        return jnp.stack([_solve_single(d[b], e[b], method, kw)
                          for b in range(d.shape[0])])
    return _solve_single(d, e, method, kw)
