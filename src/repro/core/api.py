"""Public eigensolver API.

    from repro.core import eigvalsh_tridiagonal
    lam = eigvalsh_tridiagonal(d, e)                    # BR (paper), O(n) memory
    lam = eigvalsh_tridiagonal(d, e, method="sterf")    # QR/QL baseline
    lam = eigvalsh_tridiagonal(d, e, method="lazy")     # internal values-only D&C
    lam = eigvalsh_tridiagonal(d, e, method="full")     # conventional D&C (discard Q)
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.br_dc import eigvalsh_tridiagonal_br
from repro.core.sterf import eigvalsh_tridiagonal_sterf
from repro.core import baselines as _bl

METHODS = ("br", "sterf", "lazy", "full", "eigh")


def eigvalsh_tridiagonal(d, e, method: str = "br", **kw):
    """All eigenvalues (ascending) of the symmetric tridiagonal (d, e)."""
    if method == "br":
        return eigvalsh_tridiagonal_br(d, e, **kw).eigenvalues
    if method == "sterf":
        return eigvalsh_tridiagonal_sterf(d, e, **kw)
    if method == "lazy":
        return _bl.eigvalsh_tridiagonal_lazy(d, e, **kw)
    if method == "full":
        return _bl.eigvalsh_tridiagonal_full_discard(d, e, **kw)
    if method == "eigh":
        from repro.core.tridiag import dense_from_tridiag
        return jnp.linalg.eigvalsh(dense_from_tridiag(d, e))
    raise ValueError(f"unknown method {method!r}; choose from {METHODS}")
