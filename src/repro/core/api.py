"""Public eigensolver API.

    from repro.core import eigvalsh_tridiagonal
    lam = eigvalsh_tridiagonal(d, e)                    # BR (paper), O(n) memory
    lam = eigvalsh_tridiagonal(d, e, method="sterf")    # QR/QL baseline
    lam = eigvalsh_tridiagonal(d, e, method="lazy")     # internal values-only D&C
    lam = eigvalsh_tridiagonal(d, e, method="full")     # conventional D&C (discard Q)
    lam = eigvalsh_tridiagonal(d, e, method="bisect")   # Sturm bisection reference

Partial spectrum (k << n eigenvalues by index or value window):

    from repro.core import eigvalsh_tridiagonal_range
    top = eigvalsh_tridiagonal_range(d, e, select="i", il=n - 32, iu=n - 1)
    band = eigvalsh_tridiagonal_range(d, e, select="v", vl=0.0, vu=2.5)

Batched front door (one device solve for B problems, B * O(n) state):

    from repro.core import eigvalsh_tridiagonal_batch
    res = eigvalsh_tridiagonal_batch(D, E)              # D (B, n), E (B, n-1)
    res.eigenvalues                                     # (B, n) ascending

``eigvalsh_tridiagonal`` itself also accepts stacked (B, n) inputs and
routes them per method: "br" runs natively batched through the
plan/executor core (one launch, bucketed compile cache) and "bisect"
through the batched range executor; the baselines (which exist to model
per-problem quadratic state) fall back to a loop of single solves and
return the stacked (B, n) spectra.

Every call here is a thin wrapper over the request/response core
(``repro.core.request``): the arguments become a :class:`SolveRequest`,
which is routed to its bucketed compile-cache key and executed -- the
exact path the serving layer (``repro.serve``) drives concurrently, so a
request answered by the service is bit-for-bit the sync answer.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.bisect import eigvalsh_tridiagonal_range  # noqa: F401 (re-export)
from repro.core.br_dc import (eigvalsh_tridiagonal_batch,  # noqa: F401
                              eigvalsh_tridiagonal_br)     # noqa: F401
from repro.core.request import (METHODS, SolveRequest, SolveResult,
                                execute_request, route_request)

__all__ = ["METHODS", "SolveRequest", "SolveResult", "eigvalsh_tridiagonal",
           "eigvalsh_tridiagonal_batch", "eigvalsh_tridiagonal_br",
           "eigvalsh_tridiagonal_range", "execute_request", "route_request"]


def eigvalsh_tridiagonal(d, e, method: str = "br", **kw):
    """All eigenvalues (ascending) of the symmetric tridiagonal (d, e).

    1-D inputs solve one problem and return (n,); stacked (B, n) /
    (B, n-1) inputs solve the batch and return (B, n) -- natively for
    "br" (one device solve via the plan/executor core) and "bisect"
    (one sliced solve over all indices), looped for the baseline
    methods.

    "br" additionally accepts ``mesh=`` for distributed conquer: pass a
    power-of-two shard count (or a Mesh) to split the problem into
    contiguous shards over a 1-D device mesh; the default "auto" shards
    huge problems whenever several devices are visible and is a no-op
    otherwise.  ``compress_halo=True`` opts the sharded all-gather into
    int8 boundary-row compression.  ``precision="mixed"`` runs the whole
    D&C tree in f32 and Sturm-certifies / cluster-polishes the
    eigenvalues back to f64 (``refine_tol`` sets the certification
    tolerance in eps_f64 * ||T|| units) -- the big-n speed knob when
    LAPACK-grade f64 output is still required.  See
    :func:`repro.core.br_dc.eigvalsh_tridiagonal_br` for details.

    Every method accepts ``certify=True``: one extra batched Sturm-count
    sweep verifies each returned eigenvalue against the original (d, e)
    to ``refine_tol * eps * max(1, ||T||)`` and escalates misses or
    non-finite outputs down the graceful-degradation ladder
    (mixed -> native D&C -> per-lane Sturm bisection) instead of
    returning them.  Inputs are validated at the front door
    (``guard.InvalidInputError`` names the poisoned lane/index) and
    pathological scalings are equilibrated by an exact power of two --
    see the README "Robustness" section.
    """
    d = jnp.asarray(d)
    kind = "batch" if d.ndim == 2 else "full"
    req = SolveRequest(d=d, e=e, kind=kind, method=method,
                       return_boundary=bool(kw.pop("return_boundary", False)),
                       certify=bool(kw.pop("certify", False)),
                       deadline_ms=kw.pop("deadline_ms", None),
                       knobs=kw)
    return execute_request(req).eigenvalues
