"""One boundary-row D&C merge (paper Algorithm 1, lines 5-11), masked/fixed-shape.

Given two solved children (their spectra plus the boundary rows of their
eigenvector matrices) and the rank-one coupling (rho, s), produce the parent
spectrum and the parent's selected rows:

    z      = [ bhi(Q_L) ; s * blo(Q_R) ]          (Lemma 3.1)
    parent = eig( diag(LamL (+) LamR) + rho z z^T )
    R_new  = R_child @ S_v  via selected-row streaming  (Lemma 3.2)

The deflation pipeline mirrors LAPACK DLAED2 exactly (z-small test, then the
sequential close-pole Givens chain with the same (c, s) convention and
diagonal-value updates), but in a fixed-shape masked formulation: deflation
yields a compaction permutation + a traced active count K', never a dynamic
shape.  This is the XLA/TPU adaptation recorded in DESIGN.md -- semantics are
preserved, shapes are static.

The same `merge_node` serves three algorithms (DESIGN.md section 2):
  * BR (paper):       R has 2 rows -> O(n) persistent state.
  * full-vector D&C:  R has K rows = Q_L (+) Q_R  -> conventional quadratic.
  * lazy-replay:      R = I_K extracts the dense local transform S_v for
                      later replay (the paper's internal values-only baseline).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import secular as _sec
from repro.kernels import ops as _ops


class MergeResult(NamedTuple):
    lam: jax.Array        # (K,) parent eigenvalues, ascending
    rows: jax.Array       # (r, K) updated selected rows (zeros in root mode)
    kprime: jax.Array     # () int32 active secular rank after deflation
    rho_eff: jax.Array    # () effective rank-one strength (>= 0)


def _deflate_tolerance(d, z, rho_eff, tol_factor):
    dmax = jnp.max(jnp.abs(d))
    return tol_factor * jnp.finfo(d.dtype).eps * jnp.maximum(dmax, rho_eff)


def _close_pole_scan(d, z, R, small, tol):
    """Sequential close-pole deflation chain (LAPACK DLAED2 lines ~230-280).

    Walks the sorted poles carrying the last *kept* entry; when the current
    pole is within tolerance of it (measured by the rotated off-diagonal
    |t*c*s|), applies the Givens rotation that zeroes the previous z entry,
    updates both diagonal values, and marks the previous column deflated.

    Rotations touch only z, d and the r selected rows (paper Lemma A.2).
    Returns updated (d, z, R, deflated_mask).
    """
    r, K = R.shape
    dtype = d.dtype

    def step(carry, i):
        d_arr, z_arr, Rc, defl, pd, pz, pidx, pvalid = carry
        d_i = d_arr[i]
        z_i = z_arr[i]
        small_i = small[i]

        tau_g = jnp.hypot(pz, z_i)
        tau_safe = jnp.where(tau_g > 0.0, tau_g, 1.0)
        c = z_i / tau_safe          # LAPACK: C = Z(NJ)/TAU
        s_g = -pz / tau_safe        # LAPACK: S = -Z(PJ)/TAU
        t = d_i - pd
        close = pvalid & (~small_i) & (jnp.abs(t * c * s_g) <= tol) & (tau_g > 0.0)

        # Rotated diagonal values (weighted averages of the close pair).
        d_p_new = pd * c * c + d_i * s_g * s_g
        d_i_new = pd * s_g * s_g + d_i * c * c

        # Column rotation on the selected rows (drot with (c, s_g)):
        #   col_p <- c*col_p + s_g*col_i ; col_i <- -s_g*col_p + c*col_i
        pidx_safe = jnp.maximum(pidx, 0)
        col_p = Rc[:, pidx_safe]
        col_i = Rc[:, i]
        new_p = c * col_p + s_g * col_i
        new_i = -s_g * col_p + c * col_i

        def apply_close(args):
            d_arr, z_arr, Rc, defl = args
            d_arr = d_arr.at[pidx_safe].set(d_p_new).at[i].set(d_i_new)
            z_arr = z_arr.at[pidx_safe].set(0.0).at[i].set(tau_g)
            Rc = Rc.at[:, pidx_safe].set(new_p).at[:, i].set(new_i)
            defl = defl.at[pidx_safe].set(True)
            return d_arr, z_arr, Rc, defl

        d_arr, z_arr, Rc, defl = jax.lax.cond(
            close, apply_close, lambda a: a, (d_arr, z_arr, Rc, defl))

        # Carry the current entry forward as the new "last kept" unless it
        # was z-small deflated (then the previous kept entry persists).
        keep_cur = ~small_i
        npd = jnp.where(keep_cur, jnp.where(close, d_i_new, d_i), pd)
        npz = jnp.where(keep_cur, jnp.where(close, tau_g, z_i), pz)
        npidx = jnp.where(keep_cur, i, pidx)
        npvalid = pvalid | keep_cur
        return (d_arr, z_arr, Rc, defl, npd, npz, npidx, npvalid), None

    defl0 = jnp.asarray(small)
    init = (d, z, R, defl0,
            jnp.asarray(0.0, dtype), jnp.asarray(0.0, dtype),
            jnp.asarray(-1, jnp.int32), jnp.asarray(False))
    (d, z, R, defl, *_), _ = jax.lax.scan(step, init, jnp.arange(K, dtype=jnp.int32))
    return d, z, R, defl


DEFAULT_STREAM_THRESHOLD_ACCEL = 512


def default_stream_threshold() -> int:
    """Backend-aware dispatch default.

    On accelerators, small-K levels pay for the chunked ``lax.map`` twice:
    loop overhead AND serialization under the level vmap (large B, small K
    -- the worst trade), so they go dense up to K=512.  On CPU a merge
    with K <= chunk already runs as a single dense tile inside the
    streaming wrapper and there is no vmap parallelism to unlock, so the
    dense path is pure overhead: stream everything.
    """
    return 0 if jax.default_backend() == "cpu" \
        else DEFAULT_STREAM_THRESHOLD_ACCEL


def _merge_prepare(dL, dR, zL, zR, R, rho, sgn, tol_factor):
    """Per-node merge head: z assembly, pole sort, deflation, compaction.

    Everything up to (but excluding) the secular solve -- the part that is
    inherently per-node (the close-pole Givens chain is a sequential scan
    over this node's poles).  Returns (d, z, R, kprime, rho_eff) with the
    active poles sorted ascending in the prefix.
    """
    K = dL.shape[0] + dR.shape[0]
    d0 = jnp.concatenate([dL, dR])
    z0 = jnp.concatenate([zL, sgn * zR])
    nrm2 = jnp.sum(z0 * z0)
    nrm = jnp.sqrt(nrm2)
    z = z0 / jnp.where(nrm > 0.0, nrm, 1.0)
    rho_eff = rho * nrm2  # so that rho * z0 z0^T == rho_eff * z z^T, ||z|| = 1

    # ---- sort poles ascending -------------------------------------------
    p1 = jnp.argsort(d0)
    d = d0[p1]
    z = z[p1]
    R = R[:, p1]

    tol = _deflate_tolerance(d, z, rho_eff, tol_factor)

    # ---- type-1 deflation: negligible z entries -------------------------
    small = rho_eff * jnp.abs(z) <= tol
    z = jnp.where(small, 0.0, z)

    # ---- type-2 deflation: close poles (sequential Givens chain) --------
    d, z, R, deflated = _close_pole_scan(d, z, R, small, tol)
    z = jnp.where(deflated, 0.0, z)

    # ---- compaction: active first (sorted), deflated after --------------
    p2 = jnp.lexsort((d, deflated))
    d = d[p2]
    z = z[p2]
    R = R[:, p2]
    deflated = deflated[p2]
    kprime = (K - jnp.sum(deflated)).astype(jnp.int32)
    return d, z, R, kprime, rho_eff


def merge_level(lam_pairs, z_inner, R, rho, sgn, *,
                niter: int = 16, chunk: int = 256, use_zhat: bool = True,
                root_mode: bool = False, tol_factor: float = 8.0,
                stream_threshold: int | None = None,
                fused: bool = True) -> MergeResult:
    """One tree level of merges: all nodes solved as ONE batched sweep.

    lam_pairs: (W, 2, M) child spectra; z_inner: (W, 2, M) = (bhi_L, blo_R);
    R: (W, r, 2M); rho, sgn: (W,).  The leading axis is "independent
    merges" -- in the batch-first driver it is the flattened
    ``problems x nodes`` product, so a whole problem batch shares one
    level launch.

    Execution shape: the per-node head (deflation chain) runs vmapped,
    then the secular root solve and the fused post-pass run through the
    *batched* kernel dispatchers (`ops.secular_solve_batched` /
    `ops.secular_postpass_batched`) -- one launch for the whole level on
    the Pallas backend (problem-indexed grid axis), a W-wide vectorized
    sweep on XLA.

    Args:
      root_mode: skip all row propagation (paper's root-only mode).
      stream_threshold: size-adaptive dispatch -- levels with K at or below
        it run the dense vectorized secular paths (one (W, K, K) tile, no
        streaming loop), larger merges stream in O(chunk * K) tiles per
        node.  None: backend-aware default (see default_stream_threshold).
      fused: single fused delta pass for the post-solve phase (zhat + row
        update share each tile); False keeps the legacy two-pass form for
        benchmarking/regression.
    """
    K = 2 * lam_pairs.shape[-1]
    if stream_threshold is None:
        stream_threshold = default_stream_threshold()
    # fused=False reproduces the pre-fusion pipeline exactly (always
    # streamed, two post-passes) as the benchmark baseline.
    dense = fused and K <= stream_threshold
    dtype = lam_pairs.dtype

    d, z, Rp, kprime, rho_eff = jax.vmap(
        lambda lp, zi, r_, rh, sg: _merge_prepare(
            lp[0], lp[1], zi[0], zi[1], r_, rh, sg, tol_factor)
    )(lam_pairs, z_inner, R, rho, sgn)

    # ---- secular root solve (compact delta representation, batched) -----
    origin, tau = _ops.secular_solve_batched(
        d, z * z, rho_eff, kprime, niter=niter, chunk=chunk, dense=dense)
    lam = jnp.take_along_axis(d, origin, axis=1) + tau

    # ---- selected-row propagation (skipped at the root) ------------------
    if root_mode:
        rows = jnp.zeros_like(Rp)
    elif fused:
        # One pass over the delta structure for both zhat and the rows.
        _, rows = _ops.secular_postpass_batched(
            Rp, d, z, origin, tau, kprime, rho_eff,
            use_zhat=use_zhat, chunk=chunk, dense=dense)
    else:
        # Legacy two-pass conquer (streams the delta structure twice,
        # per node -- the benchmark baseline path).
        def two_pass(R_, d_, z_, origin_, tau_, kprime_, rho_):
            zr = z_
            if use_zhat:
                zr = _sec.zhat_reconstruct(d_, z_, origin_, tau_, kprime_,
                                           rho_, chunk=chunk)
            return _sec.boundary_rows_update(R_, d_, zr, origin_, tau_,
                                             kprime_, chunk=chunk)
        rows = jax.vmap(two_pass)(Rp, d, z, origin, tau, kprime, rho_eff)

    # ---- final ascending sort of the parent spectra ----------------------
    p3 = jnp.argsort(lam, axis=1)
    lam = jnp.take_along_axis(lam, p3, axis=1)
    if not root_mode:
        rows = jnp.take_along_axis(rows, p3[:, None, :], axis=2)

    return MergeResult(lam.astype(dtype), rows, kprime, rho_eff)


def merge_node(dL, dR, zL, zR, R, rho, sgn, **kw) -> MergeResult:
    """Merge one pair of solved children (single-node view of merge_level).

    dL, dR: (M,) ascending child eigenvalues; zL/zR the inner boundary
    rows; R (r, 2M) selected rows; rho scalar >= 0; sgn +-1.  Keyword
    knobs as in :func:`merge_level`.
    """
    res = merge_level(
        jnp.stack([dL, dR])[None], jnp.stack([zL, zR])[None], R[None],
        jnp.asarray(rho)[None], jnp.asarray(sgn)[None], **kw)
    return MergeResult(res.lam[0], res.rows[0], res.kprime[0],
                       res.rho_eff[0])


def merge_level_batched(lam_pairs, z_inner, R, rho, sgn, **kw):
    """Problem-batched level merge: one launch for B problems x nm nodes.

    lam_pairs: (B, nm, 2, M); z_inner: (B, nm, 2, M); R: (B, nm, r, 2M);
    rho, sgn: (B, nm).  The problem axis is absorbed into the node axis --
    merges of *different* problems at the same depth are exactly as
    independent as merges of the same problem, so the flattened
    (B * nm)-wide vmap is the native batched execution (no outer vmap, no
    per-problem dispatch).  Results are reshaped back to (B, nm, ...).
    """
    B, nm, _, M = lam_pairs.shape
    r = R.shape[2]
    res = merge_level(
        lam_pairs.reshape(B * nm, 2, M),
        z_inner.reshape(B * nm, 2, M),
        R.reshape(B * nm, r, 2 * M),
        rho.reshape(B * nm), sgn.reshape(B * nm), **kw)
    K = res.lam.shape[-1]
    return MergeResult(
        res.lam.reshape(B, nm, K),
        res.rows.reshape(B, nm, r, K),
        res.kprime.reshape(B, nm),
        res.rho_eff.reshape(B, nm))
