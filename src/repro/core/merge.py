"""One boundary-row D&C merge (paper Algorithm 1, lines 5-11), masked/fixed-shape.

Given two solved children (their spectra plus the boundary rows of their
eigenvector matrices) and the rank-one coupling (rho, s), produce the parent
spectrum and the parent's selected rows:

    z      = [ bhi(Q_L) ; s * blo(Q_R) ]          (Lemma 3.1)
    parent = eig( diag(LamL (+) LamR) + rho z z^T )
    R_new  = R_child @ S_v  via selected-row streaming  (Lemma 3.2)

The deflation pipeline mirrors LAPACK DLAED2 exactly (z-small test, then the
close-pole Givens chain with the same (c, s) convention and diagonal-value
updates), but in a fixed-shape masked formulation: deflation yields a
compaction permutation + a traced active count K', never a dynamic shape.
This is the XLA/TPU adaptation recorded in DESIGN.md -- semantics are
preserved, shapes are static.

The close-pole chain itself runs in a detect-compact-apply formulation
(parallel deflation head): the chain's "previous kept pole" linkage is fully
determined by the z-small mask, so close-pair candidates are detected in one
vectorized sweep, compacted into a fixed ``deflate_budget`` (with K/2 and
full-K escalation tiers for rotation-heavy levels), and the exact DLAED2
rotation chain runs only over that short list -- O(budget) dependent
steps per level instead of O(K).  A vectorized post-check proves the
restriction exact; a detected miss falls back to the sequential chain via
a level-scope ``lax.cond`` (one branch executes at runtime -- the cond
sits above the per-node vmap).  The restricted chain
performs the same rotations with the same operands in the same order as
the sequential one, so results are bit-identical whenever no rotation
fires (the low-deflation steady state) and agree to the compiler's
FMA-contraction freedom (one ulp per rotation update) otherwise.

The same `merge_node` serves three algorithms (DESIGN.md section 2):
  * BR (paper):       R has 2 rows -> O(n) persistent state.
  * full-vector D&C:  R has K rows = Q_L (+) Q_R  -> conventional quadratic.
  * lazy-replay:      R = I_K extracts the dense local transform S_v for
                      later replay (the paper's internal values-only baseline).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import secular as _sec
from repro.kernels import ops as _ops


class MergeResult(NamedTuple):
    lam: jax.Array        # (K,) parent eigenvalues, ascending
    rows: jax.Array       # (r, K) updated selected rows (zeros in root mode)
    kprime: jax.Array     # () int32 active secular rank after deflation
    rho_eff: jax.Array    # () effective rank-one strength (>= 0)


def _deflate_tolerance(d, z, rho_eff, tol_factor):
    # Dtype-generic by construction: finfo(d.dtype).eps makes the
    # deflation threshold track the tree's working precision, so the f32
    # (mixed-precision) tree deflates at f32 resolution instead of
    # carrying meaninglessly tight f64 tolerances through single
    # precision -- no separate f32 code path needed.
    dmax = jnp.max(jnp.abs(d))
    return tol_factor * jnp.finfo(d.dtype).eps * jnp.maximum(dmax, rho_eff)


def _close_pole_scan(d, z, R, small, tol):
    """Sequential close-pole deflation chain (LAPACK DLAED2 lines ~230-280).

    Walks the sorted poles carrying the last *kept* entry; when the current
    pole is within tolerance of it (measured by the rotated off-diagonal
    |t*c*s|), applies the Givens rotation that zeroes the previous z entry,
    updates both diagonal values, and marks the previous column deflated.

    Rotations touch only z, d and the r selected rows (paper Lemma A.2).
    Returns updated (d, z, R, deflated_mask).
    """
    r, K = R.shape
    dtype = d.dtype

    def step(carry, i):
        d_arr, z_arr, Rc, defl, pd, pz, pidx, pvalid = carry
        d_i = d_arr[i]
        z_i = z_arr[i]
        small_i = small[i]

        tau_g = jnp.hypot(pz, z_i)
        tau_safe = jnp.where(tau_g > 0.0, tau_g, 1.0)
        c = z_i / tau_safe          # LAPACK: C = Z(NJ)/TAU
        s_g = -pz / tau_safe        # LAPACK: S = -Z(PJ)/TAU
        t = d_i - pd
        close = pvalid & (~small_i) & (jnp.abs(t * c * s_g) <= tol) & (tau_g > 0.0)

        # Rotated diagonal values (weighted averages of the close pair).
        d_p_new = pd * c * c + d_i * s_g * s_g
        d_i_new = pd * s_g * s_g + d_i * c * c

        # Column rotation on the selected rows (drot with (c, s_g)):
        #   col_p <- c*col_p + s_g*col_i ; col_i <- -s_g*col_p + c*col_i
        pidx_safe = jnp.maximum(pidx, 0)
        col_p = Rc[:, pidx_safe]
        col_i = Rc[:, i]
        new_p = c * col_p + s_g * col_i
        new_i = -s_g * col_p + c * col_i

        def apply_close(args):
            d_arr, z_arr, Rc, defl = args
            d_arr = d_arr.at[pidx_safe].set(d_p_new).at[i].set(d_i_new)
            z_arr = z_arr.at[pidx_safe].set(0.0).at[i].set(tau_g)
            Rc = Rc.at[:, pidx_safe].set(new_p).at[:, i].set(new_i)
            defl = defl.at[pidx_safe].set(True)
            return d_arr, z_arr, Rc, defl

        d_arr, z_arr, Rc, defl = jax.lax.cond(
            close, apply_close, lambda a: a, (d_arr, z_arr, Rc, defl))

        # Carry the current entry forward as the new "last kept" unless it
        # was z-small deflated (then the previous kept entry persists).
        keep_cur = ~small_i
        npd = jnp.where(keep_cur, jnp.where(close, d_i_new, d_i), pd)
        npz = jnp.where(keep_cur, jnp.where(close, tau_g, z_i), pz)
        npidx = jnp.where(keep_cur, i, pidx)
        npvalid = pvalid | keep_cur
        return (d_arr, z_arr, Rc, defl, npd, npz, npidx, npvalid), None

    defl0 = jnp.asarray(small)
    init = (d, z, R, defl0,
            jnp.asarray(0.0, dtype), jnp.asarray(0.0, dtype),
            jnp.asarray(-1, jnp.int32), jnp.asarray(False))
    (d, z, R, defl, *_), _ = jax.lax.scan(step, init, jnp.arange(K, dtype=jnp.int32))
    return d, z, R, defl


# Tight-tier budget for the compacted close-pole rotation list.  Close
# pairs need BOTH poles' z entries above the z-small threshold, so
# random-spectrum families (uniform/normal/clustered) carry at most a
# handful of rotation candidates per node and 64 covers them with a wide
# margin; genuinely rotation-heavy spectra (glued Wilkinson's repeated
# cross-block eigenvalues reach O(K/4) candidates at the top merges)
# escalate to the exact K/2 and full-K tiers (see ``_deflate_level``),
# so the budget is a speed knob, never a semantics knob.  <= 0 disables
# the parallel head entirely (always sequential -- the benchmark
# baseline).
DEFAULT_DEFLATE_BUDGET = 64


def _deflate_candidates(d, z, small, tol):
    """Vectorized close-pair detection over one node's sorted poles.

    The sequential chain's "previous kept pole" linkage depends only on the
    z-small mask (rotation-deflated poles are never a 'previous' again:
    the carry moves to the surviving partner), so it is precomputable as an
    exclusive running maximum.  The DLAED2 closeness test is then evaluated
    for every kept pole against its predecessor in one sweep, plus two
    hops of successor propagation (a rotation rewrites the values its
    successor's test sees, so the successor must be re-tested exactly in
    the compacted chain).  Deeper cascades are caught by the post-hoc
    missed-rotation check and routed to the sequential fallback.

    Returns (candidate_mask (K,) bool, prevkept (K,) int32 with -1 for
    "no kept pole before me").
    """
    K = d.shape[0]
    idx = jnp.arange(K, dtype=jnp.int32)
    kept = ~small
    pkc = jax.lax.cummax(jnp.where(kept, idx, jnp.int32(-1)))
    prevkept = jnp.concatenate(
        [jnp.full((1,), -1, jnp.int32), pkc[:-1]])
    pk_safe = jnp.maximum(prevkept, 0)

    pz = z[pk_safe]
    tau_g = jnp.hypot(pz, z)
    tau_safe = jnp.where(tau_g > 0.0, tau_g, 1.0)
    c = z / tau_safe
    s_g = -pz / tau_safe
    t = d - d[pk_safe]
    link = kept & (prevkept >= 0)
    close0 = link & (jnp.abs(t * c * s_g) <= tol) & (tau_g > 0.0)
    cand = close0 | (link & close0[pk_safe])
    cand = cand | (link & cand[pk_safe])
    return cand, prevkept


def _deflate_apply(d, z, R, small, tol, prevkept, cand, count, *, budget):
    """Exact DLAED2 chain restricted to the compacted candidate list.

    Runs ``budget`` dependent steps (vs K for the full chain), each the
    verbatim arithmetic of :func:`_close_pole_scan`'s step on candidate
    pole ``i`` against its precomputed predecessor ``prevkept[i]`` -- the
    array state at that point equals the sequential carry exactly (the
    carry is redundant with the in-place updates), so whenever no
    rotation was missed (checked afterwards) the chains perform identical
    rotations on identical operands; any residual difference is XLA's
    per-program FMA-contraction choice in the update arithmetic (<= 1 ulp
    per rotation, zero when nothing rotates).  Slots past the traced
    candidate ``count`` are no-ops.
    """
    K = d.shape[0]
    idx = jnp.arange(K, dtype=jnp.int32)
    order = jnp.argsort(jnp.where(cand, idx, jnp.int32(K)))[:budget]

    def step(carry, inp):
        d_arr, z_arr, Rc, defl = carry
        i, slot = inp
        valid = slot < count
        j = prevkept[i]
        j_safe = jnp.maximum(j, 0)
        # Paired gathers/scatters: one 2-element op per array instead of
        # two scalar ops -- the scan step is launch-bound, not flop-bound.
        # (When j == -1 and i == 0 the pair aliases index 0, but then
        # close is False and both lanes write the value just read.)
        ij = jnp.stack([j_safe, i])
        dv = d_arr[ij]
        zv = z_arr[ij]
        pd, d_i = dv[0], dv[1]
        pz, z_i = zv[0], zv[1]

        tau_g = jnp.hypot(pz, z_i)
        tau_safe = jnp.where(tau_g > 0.0, tau_g, 1.0)
        c = z_i / tau_safe          # LAPACK: C = Z(NJ)/TAU
        s_g = -pz / tau_safe        # LAPACK: S = -Z(PJ)/TAU
        t = d_i - pd
        close = (valid & (j >= 0) & (~small[i])
                 & (jnp.abs(t * c * s_g) <= tol) & (tau_g > 0.0))

        d_p_new = pd * c * c + d_i * s_g * s_g
        d_i_new = pd * s_g * s_g + d_i * c * c
        cols = Rc[:, ij]                         # (r, 2)
        col_p, col_i = cols[:, 0], cols[:, 1]
        new_cols = jnp.stack([c * col_p + s_g * col_i,
                              -s_g * col_p + c * col_i], axis=1)

        d_arr = d_arr.at[ij].set(
            jnp.where(close, jnp.stack([d_p_new, d_i_new]), dv))
        z_arr = z_arr.at[ij].set(
            jnp.where(close, jnp.stack([jnp.zeros_like(tau_g), tau_g]), zv))
        Rc = Rc.at[:, ij].set(jnp.where(close, new_cols, cols))
        defl = defl.at[j_safe].set(defl[j_safe] | close)
        return (d_arr, z_arr, Rc, defl), None

    init = (d, z, R, jnp.asarray(small))
    (d, z, R, defl), _ = jax.lax.scan(
        step, init, (order.astype(jnp.int32),
                     jnp.arange(budget, dtype=jnp.int32)))
    return d, z, R, defl


def _deflate_missed(d0, z0, d1, z1, small, tol, prevkept, cand):
    """Exact post-hoc check that no unprocessed step would have rotated.

    For a kept pole ``i`` outside the candidate list, the sequential chain
    would test it with its predecessor's POST-step values (== the final
    arrays ``d1/z1`` at ``prevkept[i]``: a predecessor is only ever
    modified at its own step or at step ``i`` itself, which did not run)
    and with ``i``'s PRE-step values (== the originals ``d0/z0``: ``i`` is
    only modified at step ``i`` or later).  If any such test fires, the
    restricted chain diverged from the sequential one -- fall back.
    By induction over steps this check passing proves bit-equality.
    """
    pk_safe = jnp.maximum(prevkept, 0)
    pz = z1[pk_safe]
    tau_g = jnp.hypot(pz, z0)
    tau_safe = jnp.where(tau_g > 0.0, tau_g, 1.0)
    c = z0 / tau_safe
    s_g = -pz / tau_safe
    t = d0 - d1[pk_safe]
    close = ((~small) & (prevkept >= 0)
             & (jnp.abs(t * c * s_g) <= tol) & (tau_g > 0.0))
    return jnp.any(close & ~cand)


def _deflate_level(d, z, R, small, tol, *, budget: int):
    """Close-pole deflation for one whole level: (W, K) nodes at once.

    Parallel head: detect -> compact -> short exact chain at the smallest
    budget tier that holds the level's candidate count (tight budget,
    K/2, full K), with a level-scope ``lax.cond`` fallback to the vmapped
    sequential chain if the missed-rotation check fires.  The tier switch
    and the cond sit ABOVE the per-node vmap, so exactly one path
    executes at runtime (under a vmapped cond both branches would run as
    selects -- the level critical path this head exists to shorten).
    """
    W, K = d.shape
    seq = jax.vmap(_close_pole_scan)
    if budget <= 0 or budget >= K:
        # Parallel head cannot shorten the chain (disabled, or the budget
        # does not undercut K): run the sequential scan directly.
        return seq(d, z, R, small, tol)

    cand, pk = jax.vmap(_deflate_candidates)(d, z, small, tol)
    count = jnp.sum(cand, axis=1).astype(jnp.int32)
    cmax = jnp.max(count)

    def apply_with(b):
        return jax.vmap(functools.partial(_deflate_apply, budget=b))(
            d, z, R, small, tol, pk, cand, count)

    # Budget tiers, picked by the level's max candidate count: the tight
    # budget for the low-deflation steady state, K/2 for rotation-heavy
    # levels (glued spectra carry O(K/4) real close pairs at the top
    # merges), and a full-length K tier that holds EVERY candidate set --
    # the packed restricted step is cheaper than the sequential carry
    # step, so even the K tier undercuts the sequential chain and budget
    # overflow never forces a fallback.  Only a detected missed rotation
    # (a cascade deeper than the detection's successor hops) does.
    tiers = [budget]
    if K // 2 > budget:
        tiers.append(K // 2)
    tiers.append(K)
    index = sum((cmax > t).astype(jnp.int32) for t in tiers[:-1])
    d1, z1, R1, defl1 = jax.lax.switch(
        index, [lambda _, b=b: apply_with(b) for b in tiers], None)
    missed = jax.vmap(_deflate_missed)(d, z, d1, z1, small, tol, pk, cand)

    return jax.lax.cond(
        jnp.any(missed),
        lambda ops: seq(*ops),
        lambda ops: (d1, z1, R1, defl1),
        (d, z, R, small, tol))


DEFAULT_STREAM_THRESHOLD_ACCEL = 512


def default_stream_threshold() -> int:
    """Backend-aware dispatch default.

    On accelerators, small-K levels pay for the chunked ``lax.map`` twice:
    loop overhead AND serialization under the level vmap (large B, small K
    -- the worst trade), so they go dense up to K=512.  On CPU a merge
    with K <= chunk already runs as a single dense tile inside the
    streaming wrapper and there is no vmap parallelism to unlock, so the
    dense path is pure overhead: stream everything.
    """
    return 0 if jax.default_backend() == "cpu" \
        else DEFAULT_STREAM_THRESHOLD_ACCEL


DEFAULT_RESIDENT_THRESHOLD_ACCEL = 512


def default_resident_threshold() -> int:
    """Backend-aware residency threshold for the single-launch merge.

    Merges with K at or below it run the secular solve AND the fused
    post-pass as ONE dispatch (`ops.secular_merge_resident_batched`): on
    the Pallas backend that is literally one kernel launch per level with
    the whole pole/root structure VMEM-resident between the phases, so
    accelerators default to 512 (a (512, 512) f64 delta tile is ~2 MiB --
    comfortably resident).  On CPU the executor jit already fuses the two
    XLA phases into one program and the dense O(K^2) tile is pure memory
    overhead, so the default is 0 (off); the knob stays available for
    benchmarking the dispatch-collapse in isolation.
    """
    return 0 if jax.default_backend() == "cpu" \
        else DEFAULT_RESIDENT_THRESHOLD_ACCEL


def _merge_assemble(dL, dR, zL, zR, R, rho, sgn, tol_factor):
    """Per-node merge prelude: z assembly, pole sort, z-small deflation.

    Everything BEFORE the close-pole chain -- all of it elementwise or a
    single sort, so it stays under the per-node vmap.  Returns
    (d, z, R, small, tol, rho_eff) with poles sorted ascending and the
    z-small entries already zeroed.
    """
    d0 = jnp.concatenate([dL, dR])
    z0 = jnp.concatenate([zL, sgn * zR])
    nrm2 = jnp.sum(z0 * z0)
    nrm = jnp.sqrt(nrm2)
    z = z0 / jnp.where(nrm > 0.0, nrm, 1.0)
    rho_eff = rho * nrm2  # so that rho * z0 z0^T == rho_eff * z z^T, ||z|| = 1

    # ---- sort poles ascending -------------------------------------------
    p1 = jnp.argsort(d0)
    d = d0[p1]
    z = z[p1]
    R = R[:, p1]

    tol = _deflate_tolerance(d, z, rho_eff, tol_factor)

    # ---- type-1 deflation: negligible z entries -------------------------
    small = rho_eff * jnp.abs(z) <= tol
    z = jnp.where(small, 0.0, z)
    return d, z, R, small, tol, rho_eff


def _merge_compact(d, z, R, deflated):
    """Compaction permutation: active poles first (sorted), deflated after.

    Returns (d, z, R, kprime) -- the fixed-shape masked equivalent of
    DLAED2's dynamic shrink.
    """
    K = d.shape[0]
    p2 = jnp.lexsort((d, deflated))
    d = d[p2]
    z = z[p2]
    R = R[:, p2]
    deflated = deflated[p2]
    kprime = (K - jnp.sum(deflated)).astype(jnp.int32)
    return d, z, R, kprime


def _merge_head(lam_pairs, z_inner, R, rho, sgn, *, tol_factor,
                deflate_budget):
    """Everything before the secular solve, for one level of merges.

    Per-node prelude (z assembly, pole sort, z-small test) vmapped over
    the (W,) lane axis, then the parallel deflation head and the
    compaction permutation.  Returns (d, z, Rp, kprime, rho_eff) with
    shapes ((W, K), (W, K), (W, r, K), (W,), (W,)).  Shared by
    :func:`merge_level` and the cooperative distributed level
    (:func:`merge_level_coop`), which replicates the head on every
    device of the solver mesh -- it is O(K log K) per lane against the
    solve's O(K^2), and replicating it keeps the sharded solve's inputs
    bit-identical to the single-device path's.
    """
    d, z, Rp, small, tol, rho_eff = jax.vmap(
        lambda lp, zi, r_, rh, sg: _merge_assemble(
            lp[0], lp[1], zi[0], zi[1], r_, rh, sg, tol_factor)
    )(lam_pairs, z_inner, R, rho, sgn)
    d, z, Rp, deflated = _deflate_level(d, z, Rp, small, tol,
                                        budget=deflate_budget)
    z = jnp.where(deflated, 0.0, z)
    d, z, Rp, kprime = jax.vmap(_merge_compact)(d, z, Rp, deflated)
    return d, z, Rp, kprime, rho_eff


def merge_level(lam_pairs, z_inner, R, rho, sgn, *,
                niter: int = _sec.DEFAULT_NITER, chunk: int = 256,
                use_zhat: bool = True,
                root_mode: bool = False, tol_factor: float = 8.0,
                stream_threshold: int | None = None,
                deflate_budget: int = DEFAULT_DEFLATE_BUDGET,
                resident_threshold: int | None = None,
                fused: bool = True) -> MergeResult:
    """One tree level of merges: all nodes solved as ONE batched sweep.

    lam_pairs: (W, 2, M) child spectra; z_inner: (W, 2, M) = (bhi_L, blo_R);
    R: (W, r, 2M); rho, sgn: (W,).  The leading axis is "independent
    merges" -- in the batch-first driver it is the flattened
    ``problems x nodes`` product, so a whole problem batch shares one
    level launch.

    Execution shape: the per-node prelude (z assembly, sort, z-small
    test) runs vmapped; the close-pole chain runs through the parallel
    deflation head (`_deflate_level`: vectorized detection + short exact
    chain, sequential fallback behind a level-scope cond); then the
    secular root solve and the fused post-pass run through the *batched*
    kernel dispatchers -- for K at or below ``resident_threshold`` as ONE
    resident launch (`ops.secular_merge_resident_batched`), otherwise as
    the streamed two-launch pair (`ops.secular_solve_batched` +
    `ops.secular_postpass_batched`).

    Args:
      root_mode: skip all row propagation (paper's root-only mode).
      stream_threshold: size-adaptive dispatch -- levels with K at or below
        it run the dense vectorized secular paths (one (W, K, K) tile, no
        streaming loop), larger merges stream in O(chunk * K) tiles per
        node.  None: backend-aware default (see default_stream_threshold).
      deflate_budget: compacted rotation-candidate budget for the parallel
        deflation head; <= 0 forces the sequential chain (baseline).
        Overflow escalates to the exact K/2 / full-K tiers, so this is
        never a semantics knob.
      resident_threshold: levels with K at or below it collapse secular
        solve + post-pass into a single resident dispatch.  None:
        backend-aware default (see default_resident_threshold).
      fused: single fused delta pass for the post-solve phase (zhat + row
        update share each tile); False keeps the legacy two-pass form for
        benchmarking/regression.
    """
    K = 2 * lam_pairs.shape[-1]
    if stream_threshold is None:
        stream_threshold = default_stream_threshold()
    if resident_threshold is None:
        resident_threshold = default_resident_threshold()
    # fused=False reproduces the pre-fusion pipeline exactly (always
    # streamed, two post-passes) as the benchmark baseline.
    dense = fused and K <= stream_threshold
    dtype = lam_pairs.dtype

    # ---- merge head: prelude (vmapped) + parallel deflation + compaction
    d, z, Rp, kprime, rho_eff = _merge_head(lam_pairs, z_inner, R, rho, sgn,
                                            tol_factor=tol_factor,
                                            deflate_budget=deflate_budget)

    # ---- single-launch resident merge (small K, solve + post-pass) ------
    if fused and not root_mode and K <= resident_threshold:
        origin, tau, _, rows = _ops.secular_merge_resident_batched(
            d, z, Rp, rho_eff, kprime, niter=niter, use_zhat=use_zhat)
        lam = jnp.take_along_axis(d, origin, axis=1) + tau
        p3 = jnp.argsort(lam, axis=1)
        lam = jnp.take_along_axis(lam, p3, axis=1)
        rows = jnp.take_along_axis(rows, p3[:, None, :], axis=2)
        return MergeResult(lam.astype(dtype), rows, kprime, rho_eff)

    # ---- secular root solve (compact delta representation, batched) -----
    origin, tau = _ops.secular_solve_batched(
        d, z * z, rho_eff, kprime, niter=niter, chunk=chunk, dense=dense)
    lam = jnp.take_along_axis(d, origin, axis=1) + tau

    # ---- selected-row propagation (skipped at the root) ------------------
    if root_mode:
        rows = jnp.zeros_like(Rp)
    elif fused:
        # One pass over the delta structure for both zhat and the rows.
        _, rows = _ops.secular_postpass_batched(
            Rp, d, z, origin, tau, kprime, rho_eff,
            use_zhat=use_zhat, chunk=chunk, dense=dense)
    else:
        # Legacy two-pass conquer (streams the delta structure twice,
        # per node -- the benchmark baseline path).
        def two_pass(R_, d_, z_, origin_, tau_, kprime_, rho_):
            zr = z_
            if use_zhat:
                zr = _sec.zhat_reconstruct(d_, z_, origin_, tau_, kprime_,
                                           rho_, chunk=chunk)
            return _sec.boundary_rows_update(R_, d_, zr, origin_, tau_,
                                             kprime_, chunk=chunk)
        rows = jax.vmap(two_pass)(Rp, d, z, origin, tau, kprime, rho_eff)

    # ---- final ascending sort of the parent spectra ----------------------
    p3 = jnp.argsort(lam, axis=1)
    lam = jnp.take_along_axis(lam, p3, axis=1)
    if not root_mode:
        rows = jnp.take_along_axis(rows, p3[:, None, :], axis=2)

    return MergeResult(lam.astype(dtype), rows, kprime, rho_eff)


def merge_node(dL, dR, zL, zR, R, rho, sgn, **kw) -> MergeResult:
    """Merge one pair of solved children (single-node view of merge_level).

    dL, dR: (M,) ascending child eigenvalues; zL/zR the inner boundary
    rows; R (r, 2M) selected rows; rho scalar >= 0; sgn +-1.  Keyword
    knobs as in :func:`merge_level`.
    """
    res = merge_level(
        jnp.stack([dL, dR])[None], jnp.stack([zL, zR])[None], R[None],
        jnp.asarray(rho)[None], jnp.asarray(sgn)[None], **kw)
    return MergeResult(res.lam[0], res.rows[0], res.kprime[0],
                       res.rho_eff[0])


def merge_level_coop(lam_pairs, z_inner, R, rho, sgn, *, axis_name: str,
                     shards: int,
                     niter: int = _sec.DEFAULT_NITER, chunk: int = 256,
                     use_zhat: bool = True, root_mode: bool = False,
                     tol_factor: float = 8.0,
                     stream_threshold: int | None = None,
                     deflate_budget: int = DEFAULT_DEFLATE_BUDGET,
                     resident_threshold: int | None = None,
                     fused: bool = True) -> MergeResult:
    """One *cooperative* tree level inside a shard_map body.

    Called with fully replicated level state (every device of the 1-D
    solver mesh holds all ``nm`` merges after the subtree->cooperative
    all-gather).  Work splits three ways:

      * merge head (assemble, deflation, compaction): replicated -- it is
        O(K log K) per lane and replicating it keeps every device's pole
        state bit-identical to the single-device path's;
      * secular root solve -- the level's O(K^2) dominant cost -- sharded:
        device p solves the root window ``[w * Kw, (w+1) * Kw)`` of merge
        ``m`` where ``m = p // G``, ``w = p % G``, ``G = shards / nm``
        windows per merge and ``Kw = K / G`` (== N / shards roots per
        device at every cooperative level), then the (origin, tau)
        windows are all-gathered -- the O(n) halo the paper's linear
        state makes cheap.  Per-root arithmetic depends only on the root
        index and the replicated pole state, so the gathered roots are
        bit-identical to a single-device solve;
      * fused post-pass + final sort: replicated.  The post-pass is the
        level's second-order cost (~K per root vs the solve's
        niter * K); replicating it avoids re-associating its streamed
        accumulation, which keeps the whole cooperative level
        bit-identical to the single-device path whenever the lane math
        itself is (see merge-head contract).

    ``lam_pairs`` (B, nm, 2, M) etc. as in :func:`merge_level_batched`;
    ``nm`` must divide ``shards``.  Merges small enough for the resident
    single-launch path run fully replicated through
    :func:`merge_level_batched` instead -- window sharding buys nothing
    at resident sizes and the branch structure must mirror
    :func:`merge_level`'s for bit-identity.
    """
    B, nm, _, M = lam_pairs.shape
    K = 2 * M
    if stream_threshold is None:
        stream_threshold = default_stream_threshold()
    if resident_threshold is None:
        resident_threshold = default_resident_threshold()
    if shards % nm:
        raise ValueError(
            f"cooperative level expects nm | shards; got nm={nm}, "
            f"shards={shards}")
    G = shards // nm                     # root windows per merge
    if (fused and not root_mode and K <= resident_threshold) or G <= 1 \
            or K % G:
        return merge_level_batched(
            lam_pairs, z_inner, R, rho, sgn, niter=niter, chunk=chunk,
            use_zhat=use_zhat, root_mode=root_mode, tol_factor=tol_factor,
            stream_threshold=stream_threshold,
            deflate_budget=deflate_budget,
            resident_threshold=resident_threshold, fused=fused)
    Kw = K // G
    dense = fused and K <= stream_threshold
    dtype = lam_pairs.dtype
    r = R.shape[2]

    # ---- merge head, replicated over the flattened (B * nm) lanes -------
    d, z, Rp, kprime, rho_eff = _merge_head(
        lam_pairs.reshape(B * nm, 2, M), z_inner.reshape(B * nm, 2, M),
        R.reshape(B * nm, r, K), rho.reshape(B * nm), sgn.reshape(B * nm),
        tol_factor=tol_factor, deflate_budget=deflate_budget)
    d_n = d.reshape(B, nm, K)
    z_n = z.reshape(B, nm, K)
    kprime_n = kprime.reshape(B, nm)
    rho_n = rho_eff.reshape(B, nm)

    # ---- sharded secular solve: this device's (merge, window) pair ------
    p = jax.lax.axis_index(axis_name)
    m = p // G
    w = p % G
    d_m = jnp.take(d_n, m, axis=1)           # (B, K)
    z2_m = jnp.take(z_n, m, axis=1) ** 2
    origin_w, tau_w = _sec.secular_solve_window_batched(
        d_m, z2_m, jnp.take(rho_n, m, axis=1), jnp.take(kprime_n, m, axis=1),
        w * Kw, Kw, niter=niter, chunk=chunk, dense=dense)

    # ---- window all-gather: device order IS global root order -----------
    gathered = jax.lax.all_gather((origin_w, tau_w), axis_name)
    origin, tau = jax.tree.map(
        lambda x: x.reshape(nm, G, B, Kw).transpose(2, 0, 1, 3)
                   .reshape(B * nm, K),
        gathered)
    lam = jnp.take_along_axis(d, origin, axis=1) + tau

    # ---- replicated post-pass + sort (same code path as merge_level) ----
    if root_mode:
        rows = jnp.zeros_like(Rp)
    elif fused:
        _, rows = _ops.secular_postpass_batched(
            Rp, d, z, origin, tau, kprime, rho_eff,
            use_zhat=use_zhat, chunk=chunk, dense=dense)
    else:
        def two_pass(R_, d_, z_, origin_, tau_, kprime_, rho_):
            zr = z_
            if use_zhat:
                zr = _sec.zhat_reconstruct(d_, z_, origin_, tau_, kprime_,
                                           rho_, chunk=chunk)
            return _sec.boundary_rows_update(R_, d_, zr, origin_, tau_,
                                             kprime_, chunk=chunk)
        rows = jax.vmap(two_pass)(Rp, d, z, origin, tau, kprime, rho_eff)

    p3 = jnp.argsort(lam, axis=1)
    lam = jnp.take_along_axis(lam, p3, axis=1)
    if not root_mode:
        rows = jnp.take_along_axis(rows, p3[:, None, :], axis=2)

    return MergeResult(lam.astype(dtype).reshape(B, nm, K),
                       rows.reshape(B, nm, r, K),
                       kprime.reshape(B, nm), rho_eff.reshape(B, nm))


def merge_level_batched(lam_pairs, z_inner, R, rho, sgn, **kw):
    """Problem-batched level merge: one launch for B problems x nm nodes.

    lam_pairs: (B, nm, 2, M); z_inner: (B, nm, 2, M); R: (B, nm, r, 2M);
    rho, sgn: (B, nm).  The problem axis is absorbed into the node axis --
    merges of *different* problems at the same depth are exactly as
    independent as merges of the same problem, so the flattened
    (B * nm)-wide vmap is the native batched execution (no outer vmap, no
    per-problem dispatch).  Results are reshaped back to (B, nm, ...).
    """
    B, nm, _, M = lam_pairs.shape
    r = R.shape[2]
    res = merge_level(
        lam_pairs.reshape(B * nm, 2, M),
        z_inner.reshape(B * nm, 2, M),
        R.reshape(B * nm, r, 2 * M),
        rho.reshape(B * nm), sgn.reshape(B * nm), **kw)
    K = res.lam.shape[-1]
    return MergeResult(
        res.lam.reshape(B, nm, K),
        res.rows.reshape(B, nm, r, K),
        res.kprime.reshape(B, nm),
        res.rho_eff.reshape(B, nm))
