"""Lightweight instrumentation counters for the solver core.

The solver counts *device solves* (executor launches), not problems: a
batched solve of 256 tridiagonals is ONE launch.  Regression tests pin
invariants like "padded ``return_boundary`` costs exactly one solve" and
"SLQ performs one device solve for any number of probes" against these
counters, so they must be cheap, thread-safe, and easy to scope to a
code region without races between tests.

Counters also carry an opt-in **deflation-ratio gauge**: the per-level
observed secular rank fraction ``kprime / K``.  Deflation is the paper's
(and LAPACK's) dominant effective-work lever -- a glued-Wilkinson merge
that deflates 90% of its poles does 10% of the secular work -- so
benchmarks want it visible without re-running the solver.  Recording
requires a host transfer of the (tiny) per-level kprime arrays, so it is
gated: only ``measure(deflation=True)`` windows enable it, and the
steady-state solve path pays nothing.

The **refinement gauge** mirrors it for the mixed-precision pipeline:
per-solve (targets, polished, polish iterations, certify rounds) from the
f64 Sturm certification / cluster-polish stage.  The polish fraction is
the mixed path's effective-work lever exactly like the deflation ratio is
the merge tree's, and the refinement loop is host-driven anyway (its
live-set counts already cross to the host), so recording is free --
gating via ``measure(refinement=True)`` just keeps the bookkeeping out of
steady-state windows that never read it.
"""

from __future__ import annotations

import contextlib
import threading


class LatencyRecorder:
    """Thread-safe bounded sample buffer with percentile readout.

    The serving layer records one sample per request (submit -> demux)
    and per flush; ``percentile`` uses the nearest-rank convention on a
    sorted copy, so p50/p99 match what a load generator would report.
    Bounded (drops oldest beyond ``maxlen``) so a long-lived service
    never grows its metrics without bound.
    """

    def __init__(self, maxlen: int = 4096):
        self._lock = threading.Lock()
        self._maxlen = maxlen
        self._samples: list[float] = []
        self._count = 0

    def record(self, value: float) -> None:
        with self._lock:
            self._count += 1
            self._samples.append(float(value))
            if len(self._samples) > self._maxlen:
                del self._samples[: len(self._samples) - self._maxlen]

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile (q in [0, 100]) of the retained
        samples; 0.0 when nothing was recorded."""
        with self._lock:
            if not self._samples:
                return 0.0
            s = sorted(self._samples)
        rank = max(1, int(-(-q * len(s) // 100)))  # ceil(q/100 * N)
        return s[min(rank, len(s)) - 1]

    def snapshot(self) -> dict:
        return {"count": self.count, "p50": self.percentile(50),
                "p99": self.percentile(99)}


class CounterWindow:
    """A read-only view of a :class:`SolveCounter` since a start mark."""

    def __init__(self, counter: "SolveCounter", start: int,
                 deflation_start: int = 0, refinement_start: int = 0,
                 degradation_start: int = 0):
        self._counter = counter
        self._start = start
        self._deflation_start = deflation_start
        self._refinement_start = refinement_start
        self._degradation_start = degradation_start

    @property
    def count(self) -> int:
        """Increments observed since the window opened."""
        return self._counter.count - self._start

    @property
    def deflation_ratios(self) -> dict:
        """Per-level observed deflation, aggregated over the window.

        Maps merge-tree level -> mean ``kprime / K`` across every node of
        every solve recorded since the window opened (level 0 is the
        leaf-pair merge).  Empty unless the window was opened with
        ``measure(deflation=True)`` and at least one solve ran.
        """
        events = self._counter.deflation_events(self._deflation_start)
        acc: dict[int, list] = {}
        for level, kprime_sum, total in events:
            s = acc.setdefault(level, [0.0, 0])
            s[0] += kprime_sum
            s[1] += total
        return {level: s[0] / s[1] for level, s in sorted(acc.items())
                if s[1] > 0}

    @property
    def degradation_stats(self) -> dict:
        """Graceful-degradation gauge, aggregated over the window.

        Every ladder escalation (mixed -> native, native -> bisect, ...)
        is recorded unconditionally -- escalations are rare by design and
        each one matters operationally.  Returns ``events`` (escalation
        count), ``lanes`` (total eigenvalue lanes recomputed), and
        ``by_transition`` mapping ``"from->to"`` to its event count.
        """
        events = self._counter.degradation_events(self._degradation_start)
        by: dict[str, int] = {}
        for frm, to, lanes in events:
            key = f"{frm}->{to}"
            by[key] = by.get(key, 0) + 1
        return {"events": len(events),
                "lanes": sum(e[2] for e in events),
                "by_transition": by}

    @property
    def refinement_stats(self) -> dict:
        """Mixed-precision refinement gauge, aggregated over the window.

        Sums the per-solve (targets, polished, iterations) of every
        mixed-precision solve recorded since the window opened, plus the
        derived ``polish_fraction`` (polished / targets) and the maximum
        certify->refine round count seen.  Empty-dict semantics match
        ``deflation_ratios``: requires ``measure(refinement=True)`` and at
        least one mixed solve; ``solves`` is 0 otherwise.
        """
        events = self._counter.refinement_events(self._refinement_start)
        targets = sum(e[0] for e in events)
        polished = sum(e[1] for e in events)
        iterations = sum(e[2] for e in events)
        return {"solves": len(events), "targets": targets,
                "polished": polished,
                "polish_fraction": polished / targets if targets else 0.0,
                "iterations": iterations,
                "max_rounds": max((e[3] for e in events), default=0)}


class SolveCounter:
    """Thread-safe monotonic event counter with scoped measurement.

    Usage (the regression-test idiom)::

        with SOLVE_COUNTER.measure() as window:
            eigvalsh_tridiagonal_br(d, e, return_boundary=True)
        assert window.count == 1

    ``measure()`` never mutates the global tally (it is a read-only view
    from a start mark), so opening a window cannot corrupt another
    window's baseline the way a ``reset()``-based idiom would.  Note the
    counter itself is process-global: a window observes increments from
    ALL threads, so exact-count assertions belong in code that owns the
    counter for the measured region (the test suite runs solves
    sequentially).  ``reset()`` exists for callers that want a hard zero.

    ``measure(deflation=True)`` additionally enables the deflation-ratio
    gauge for the window's lifetime: the solver records per-level
    ``(kprime_sum, total_poles)`` after each solve and the window exposes
    the aggregate through ``window.deflation_ratios``.
    """

    def __init__(self, name: str = "solves"):
        self.name = name
        self._lock = threading.Lock()
        self._count = 0
        self._deflation: list[tuple[int, float, int]] = []
        self._deflation_depth = 0
        self._refinement: list[tuple[int, int, int, int]] = []
        self._refinement_depth = 0
        self._degradation: list[tuple[str, str, int]] = []

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def increment(self, n: int = 1) -> None:
        with self._lock:
            self._count += n

    @property
    def deflation_enabled(self) -> bool:
        """True while at least one ``measure(deflation=True)`` window is
        open -- the solver checks this before paying the host transfer."""
        with self._lock:
            return self._deflation_depth > 0

    def record_deflation(self, level: int, kprime_sum: float,
                         total: int) -> None:
        """Record one level's observed secular rank: ``kprime_sum`` summed
        over the level's nodes, ``total`` the corresponding pole count."""
        with self._lock:
            self._deflation.append((int(level), float(kprime_sum),
                                    int(total)))

    def deflation_events(self, start: int = 0) -> list:
        with self._lock:
            return list(self._deflation[start:])

    @property
    def refinement_enabled(self) -> bool:
        """True while at least one ``measure(refinement=True)`` window is
        open -- the mixed-precision solve path checks this before
        recording its per-solve polish statistics."""
        with self._lock:
            return self._refinement_depth > 0

    def record_refinement(self, targets: int, polished: int,
                          iterations: int, rounds: int) -> None:
        """Record one mixed-precision solve's refinement work: ``targets``
        real eigenvalues certified, ``polished`` of them refined in f64,
        ``iterations`` total polish sweeps, over ``rounds`` certify->refine
        rounds."""
        with self._lock:
            self._refinement.append((int(targets), int(polished),
                                     int(iterations), int(rounds)))

    def refinement_events(self, start: int = 0) -> list:
        with self._lock:
            return list(self._refinement[start:])

    # Bound on retained degradation events: escalations are rare, but a
    # long-lived service under a persistent fault must not grow its
    # metrics without limit (same policy as LatencyRecorder).
    _DEGRADATION_MAXLEN = 4096

    def record_degradation(self, frm: str, to: str, lanes: int) -> None:
        """Record one graceful-degradation escalation: a solve stage
        ``frm`` handed ``lanes`` eigenvalue lanes to stage ``to``.
        Recorded unconditionally (no gate): escalations are rare and each
        one is operationally significant."""
        with self._lock:
            self._degradation.append((str(frm), str(to), int(lanes)))
            if len(self._degradation) > self._DEGRADATION_MAXLEN:
                del self._degradation[: len(self._degradation)
                                      - self._DEGRADATION_MAXLEN]

    def degradation_events(self, start: int = 0) -> list:
        with self._lock:
            return list(self._degradation[start:])

    def clear_degradation(self) -> None:
        """Drop recorded escalations (``clear_plan_cache`` calls this so
        chaos tests cannot leak ladder events into neighboring tests).
        The trimming in record_degradation can shift event indices under
        an open window; windows opened across a clear are void anyway."""
        with self._lock:
            self._degradation.clear()

    def reset(self) -> None:
        with self._lock:
            self._count = 0
            self._deflation.clear()
            self._refinement.clear()
            self._degradation.clear()

    @contextlib.contextmanager
    def measure(self, deflation: bool = False, refinement: bool = False):
        """Context manager yielding a window counting from entry.

        Args:
          deflation: also enable the deflation-ratio gauge while the
            window is open (costs one tiny host transfer per solve).
          refinement: also enable the mixed-precision refinement gauge
            (free -- the refinement loop is host-driven already).
        """
        with self._lock:
            start = self._count
            dstart = len(self._deflation)
            rstart = len(self._refinement)
            gstart = len(self._degradation)
            if deflation:
                self._deflation_depth += 1
            if refinement:
                self._refinement_depth += 1
        try:
            yield CounterWindow(self, start, dstart, rstart, gstart)
        finally:
            if deflation or refinement:
                with self._lock:
                    if deflation:
                        self._deflation_depth -= 1
                    if refinement:
                        self._refinement_depth -= 1

    def __int__(self) -> int:
        return self.count

    def __repr__(self) -> str:
        return f"SolveCounter({self.name}={self.count})"
