"""Lightweight instrumentation counters for the solver core.

The solver counts *device solves* (executor launches), not problems: a
batched solve of 256 tridiagonals is ONE launch.  Regression tests pin
invariants like "padded ``return_boundary`` costs exactly one solve" and
"SLQ performs one device solve for any number of probes" against these
counters, so they must be cheap, thread-safe, and easy to scope to a
code region without races between tests.
"""

from __future__ import annotations

import contextlib
import threading


class CounterWindow:
    """A read-only view of a :class:`SolveCounter` since a start mark."""

    def __init__(self, counter: "SolveCounter", start: int):
        self._counter = counter
        self._start = start

    @property
    def count(self) -> int:
        """Increments observed since the window opened."""
        return self._counter.count - self._start


class SolveCounter:
    """Thread-safe monotonic event counter with scoped measurement.

    Usage (the regression-test idiom)::

        with SOLVE_COUNTER.measure() as window:
            eigvalsh_tridiagonal_br(d, e, return_boundary=True)
        assert window.count == 1

    ``measure()`` never mutates the global tally (it is a read-only view
    from a start mark), so opening a window cannot corrupt another
    window's baseline the way a ``reset()``-based idiom would.  Note the
    counter itself is process-global: a window observes increments from
    ALL threads, so exact-count assertions belong in code that owns the
    counter for the measured region (the test suite runs solves
    sequentially).  ``reset()`` exists for callers that want a hard zero.
    """

    def __init__(self, name: str = "solves"):
        self.name = name
        self._lock = threading.Lock()
        self._count = 0

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def increment(self, n: int = 1) -> None:
        with self._lock:
            self._count += n

    def reset(self) -> None:
        with self._lock:
            self._count = 0

    @contextlib.contextmanager
    def measure(self):
        """Context manager yielding a window counting from entry."""
        yield CounterWindow(self, self.count)

    def __int__(self) -> int:
        return self.count

    def __repr__(self) -> str:
        return f"SolveCounter({self.name}={self.count})"
