"""Eigenvalue-only QR/QL baseline (LAPACK xSTERF analogue) in JAX.

Classic implicit-shift QL iteration on the (d, e) arrays only -- the
lowest-memory eigenvalue-only tridiagonal solver and the paper's primary
CPU baseline (Table 2).  The computation is inherently sequential: an outer
while-loop peels off converged eigenvalues; each QL sweep is a reverse scan
over the active block.  We implement it with fixed-shape masked sweeps
(`lax.scan` over the full array, masked to [l, m]), which preserves the
algorithm's O(n^2) total work while staying jit-compatible.

This is a *baseline*: it intentionally exposes no coarse-grained
parallelism, exactly the property the paper's BR algorithm removes the need
to accept.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _ql_sweep(d, e, l, m):
    """One implicit-shift QL sweep on block [l, m] (NR `tqli` inner loop).

    Masked fixed-shape version: iterates i = m-1 down to 0, only indices in
    [l, m-1] take effect.  Returns updated (d, e).
    """
    n = d.shape[0]
    dtype = d.dtype

    # Wilkinson-style shift from the top 2x2 of the block (QL convention).
    # NR tqli: g = d[m] - d[l] + e[l] / (g0 + sign(r0, g0)) is the *initial
    # rotation argument* fed to the bulge chase, not a value to re-subtract.
    d_l = d[l]
    d_l1 = d[jnp.minimum(l + 1, n - 1)]
    e_l = e[l]
    g0 = (d_l1 - d_l) / (2.0 * jnp.where(e_l == 0.0, 1.0, e_l))
    r0 = jnp.hypot(g0, jnp.asarray(1.0, dtype))
    denom = g0 + jnp.where(g0 >= 0.0, r0, -r0)
    g_init = d[m] - d_l + e_l / jnp.where(denom == 0.0, 1.0, denom)

    def body(carry, i):
        d_c, e_c, g, s, c, p, done = carry
        inside = (i >= l) & (i <= m - 1) & (~done)

        f = s * e_c[i]
        b = c * e_c[i]
        r = jnp.hypot(f, g)
        # e[i+1] <- r (store rotation result above)
        e_c = jnp.where(inside, e_c.at[i + 1].set(r), e_c)
        zero_r = inside & (r == 0.0)
        # r == 0: premature deflation -- d[i+1] -= p; e[m] = 0; stop sweep.
        d_c = jnp.where(zero_r, d_c.at[i + 1].add(-p), d_c)
        e_c = jnp.where(zero_r, e_c.at[m].set(0.0), e_c)
        done = done | zero_r

        r_safe = jnp.where(r == 0.0, 1.0, r)
        s_n = jnp.where(inside, f / r_safe, s)
        c_n = jnp.where(inside, g / r_safe, c)
        g_n = d_c[i + 1] - p
        r2 = (d_c[i] - g_n) * s_n + 2.0 * c_n * b
        p_n = s_n * r2
        d_c = jnp.where(inside & ~zero_r, d_c.at[i + 1].set(g_n + p_n), d_c)
        g2 = c_n * r2 - b

        s = jnp.where(inside & ~zero_r, s_n, s)
        c = jnp.where(inside & ~zero_r, c_n, c)
        p = jnp.where(inside & ~zero_r, p_n, p)
        g = jnp.where(inside & ~zero_r, g2, g)
        return (d_c, e_c, g, s, c, p, done), None

    init = (d, e, g_init, jnp.asarray(1.0, dtype), jnp.asarray(1.0, dtype),
            jnp.asarray(0.0, dtype), jnp.asarray(False))
    idx = jnp.arange(n - 1, -1, -1)
    (d, e, g, s, c, p, done), _ = jax.lax.scan(body, init, idx)

    d = jnp.where(~done, d.at[l].add(-p), d)
    e = jnp.where(~done, e.at[l].set(g), e)
    e = jnp.where(~done, e.at[m].set(0.0), e)
    return d, e


@functools.partial(jax.jit, static_argnames=("max_sweeps_per_eig",))
def _sterf_jit(d, e_in, max_sweeps_per_eig: int = 30):
    n = d.shape[0]
    dtype = d.dtype
    # e padded to length n; e[n-1] is a permanent zero sentinel.
    e = jnp.zeros((n,), dtype).at[: n - 1].set(e_in)
    eps = jnp.finfo(dtype).eps

    def find_m(d, e, l):
        """Smallest m >= l with negligible e[m] (converged split point)."""
        i = jnp.arange(n)
        thresh = eps * (jnp.abs(d) + jnp.abs(jnp.roll(d, -1)))
        negligible = (jnp.abs(e) <= thresh) | (i >= n - 1)
        cand = jnp.where((i >= l) & negligible, i, n)
        return jnp.min(cand)

    def cond(state):
        d, e, l, it = state
        return (l < n) & (it < max_sweeps_per_eig * n)

    def body(state):
        d, e, l, it = state
        m = find_m(d, e, l)

        def converged(args):
            d, e, l = args
            return d, e, l + 1

        def sweep(args):
            d, e, l = args
            d, e = _ql_sweep(d, e, l, m)
            return d, e, l

        d, e, l = jax.lax.cond(m == l, converged, sweep, (d, e, l))
        return d, e, l, it + 1

    d, e, l, it = jax.lax.while_loop(
        cond, body, (d, e, jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32)))
    return jnp.sort(d), it


def eigvalsh_tridiagonal_sterf(d, e, *, dtype=None):
    """All eigenvalues of (d, e) via sequential implicit-shift QL."""
    d = jnp.asarray(d)
    e = jnp.asarray(e)
    if dtype is not None:
        d = d.astype(dtype)
        e = e.astype(dtype)
    if d.shape[0] == 1:
        return d
    lam, _ = _sterf_jit(d, e)
    return lam
