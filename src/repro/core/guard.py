"""Guarded front door: input validation, overflow-safe equilibration,
and the robustness error taxonomy.

A production eigensolver service has to survive the inputs the
correctness proofs assume away: NaN/Inf poisoned problems, pathological
scalings where ``e**2`` (the Sturm recurrence's working quantity) or
``d**2 + e**2`` overflows or underflows, and malformed shapes submitted
by remote callers.  This module is the single place those concerns live:

  * :class:`InvalidInputError` -- structured rejection naming the
    offending field, lane, and index, raised HOST-SIDE at route time so
    the serving scheduler fails a poisoned request's own future before it
    joins (and could poison) a coalesced flush.
  * :func:`validate_problem` -- shape / dtype / finiteness checks shared
    by ``route_request`` and the public utilities (``sturm_count``,
    ``certify_spectrum``).
  * :func:`equilibrate` -- LAPACK-style norm scaling (DSTEDC's ``orgnrm``
    guard): when the problem's Gershgorin scale leaves the range where
    squared off-diagonals are representable, (d, e) are scaled by an
    exact power of two and eigenvalues are inverse-scaled on output.
    Scaling by powers of two is exact in binary floating point, so the
    scaled solve's Sturm counts (and therefore its certification) are
    mathematically those of the original problem, and ``scale == 1``
    traffic is bit-identical to an unguarded solve by construction.
  * The degradation-ladder error classes and process-wide counters
    (:data:`DEGRADATIONS`, :data:`DEADLINES`) the serve/metrics stack
    reports and ``clear_plan_cache`` resets.

The module deliberately imports no solver code -- it must be importable
from ``request``/``plan``/``serve`` without cycles.
"""

from __future__ import annotations

import math

import numpy as np

from repro.core.instrument import SolveCounter


class InvalidInputError(ValueError):
    """A malformed or poisoned problem, rejected at the front door.

    Subclasses ValueError so existing ``pytest.raises(ValueError)`` /
    caller ``except ValueError`` contracts keep holding; carries
    structured fields so a service operator can see WHICH lane of WHICH
    submitted batch was poisoned without parsing the message.
    """

    def __init__(self, message: str, *, field: str | None = None,
                 lane: int | None = None, index: int | None = None):
        super().__init__(message)
        self.field = field
        self.lane = lane
        self.index = index


class DeadlineExceeded(TimeoutError):
    """A request outlived its ``deadline_ms`` budget.

    Raised (onto the request's future) by the serving scheduler at
    flush-assembly time and by the engine post-launch -- an expired
    request must not hold a flush slot or force a caller to wait for an
    answer it can no longer use.
    """


class CertificationError(RuntimeError):
    """The graceful-degradation ladder was exhausted: even the final
    Sturm-bisection rung could not produce a certified, finite answer
    (or a boundary-row contract could not be met after re-solve)."""


# Process-wide robustness counters, reset by ``plan.clear_plan_cache``
# (chaos tests must not leak escalation counts into neighboring tests --
# the same isolation contract EXECUTOR_TRACES got in PR 5).
DEGRADATIONS = SolveCounter("degradations")
DEADLINES = SolveCounter("deadline_expired")


def _is_jax_array(x) -> bool:
    # Avoid importing jax for plain-numpy traffic paths.
    import sys
    jax = sys.modules.get("jax")
    return jax is not None and isinstance(x, jax.Array)


def _first_nonfinite(arr: np.ndarray):
    """(lane, index) of the first non-finite entry (lane None for 1-D)."""
    bad = ~np.isfinite(arr)
    flat = int(np.argmax(bad))
    if arr.ndim == 1:
        return None, flat
    return flat // arr.shape[1], flat % arr.shape[1]


def _check_finite(arr, name: str) -> None:
    """Host-side finiteness check; localizes the offending entry only on
    failure (the pass path is one reduction, no per-element work)."""
    host = np.asarray(arr)
    if np.isfinite(host).all():
        return
    lane, index = _first_nonfinite(host)
    kind = "NaN" if np.isnan(host.reshape(-1)[
        (0 if lane is None else lane * host.shape[1]) + index]) else "Inf"
    where = (f"index {index}" if lane is None
             else f"lane {lane}, index {index}")
    raise InvalidInputError(
        f"{name} contains {kind} at {where}; poisoned problems are "
        f"rejected at the front door (fix the input or filter the lane)",
        field=name, lane=lane, index=index)


def validate_problem(d, e, *, name: str = "problem",
                     check_finite: bool = True):
    """Validate a tridiagonal (d, e) pair: shapes, dtype, finiteness.

    Accepts 1-D ``(n,)/(n-1,)`` or stacked ``(B, n)/(B, n-1)`` input of
    any array library (numpy is used host-side; jax arrays are pulled
    once).  Raises :class:`InvalidInputError` naming the offending
    field/lane/index.  Returns ``(d, e)`` as given (no copies, no dtype
    changes) so callers can keep zero-copy submission semantics.
    """
    d_shape = np.shape(d)
    e_shape = np.shape(e)
    if len(d_shape) not in (1, 2):
        raise InvalidInputError(
            f"{name}: d must be 1-D (n,) or stacked 2-D (B, n), got "
            f"shape {d_shape}", field="d")
    if d_shape[-1] == 0 or (len(d_shape) == 2 and d_shape[0] == 0):
        raise InvalidInputError(
            f"{name}: d must be non-empty, got shape {d_shape}", field="d")
    if len(e_shape) != len(d_shape):
        raise InvalidInputError(
            f"{name}: e must have d's rank; got d {d_shape} vs e "
            f"{e_shape}", field="e")
    n = d_shape[-1]
    if e_shape[-1] != max(n - 1, 0) or (len(d_shape) == 2
                                        and e_shape[0] != d_shape[0]):
        raise InvalidInputError(
            f"{name}: e must have shape {d_shape[:-1] + (max(n - 1, 0),)} "
            f"(n-1 off-diagonals per lane of d {d_shape}), got {e_shape}",
            field="e")
    for arr, field in ((d, "d"), (e, "e")):
        dt = np.asarray(arr).dtype if not _is_jax_array(arr) else arr.dtype
        if not np.issubdtype(dt, np.floating):
            raise InvalidInputError(
                f"{name}: {field} must be real floating point, got dtype "
                f"{dt}", field=field)
    if check_finite:
        _check_finite(d, "d")
        if n > 1:
            _check_finite(e, "e")
    return d, e


# Equilibration thresholds.  The Sturm/secular recurrences square the
# off-diagonals, so the working range is the square root of the dtype's:
# any Gershgorin scale outside [2^-500, 2^500] (f64: overflow at 2^1024,
# e**2 overflow at 2^512) is scaled by an exact power of two to ~1.
# f32 ranges are narrower (e**2 overflows at 2^64), hence per-dtype.
_SAFE_EXP = {np.dtype(np.float64): 500, np.dtype(np.float32): 60,
             np.dtype(np.float16): 6}


def _safe_exponent(dtype) -> int:
    return _SAFE_EXP.get(np.dtype(dtype), 500)


def equilibrate(d, e):
    """Overflow/underflow-safe scaling of (d, e) -- LAPACK's orgnrm guard.

    Computes the problem's scale ``orgnrm = max(|d|, |e|)`` host-side.
    When it lies inside the dtype's safe range (almost all traffic), the
    INPUT ARRAYS ARE RETURNED UNTOUCHED with ``scale == 1.0`` -- the
    guarded path is bit-identical to the unguarded one.  Otherwise (d, e)
    are multiplied by an exact power of two bringing orgnrm to ~1, so

      * ``e**2`` and ``d**2 + e**2`` can neither overflow nor underflow
        inside the tree / Sturm sweeps, and
      * eigenvalues of the scaled problem are EXACTLY ``scale * lam``
        (power-of-two scaling is exact in binary FP barring over/
        underflow of individual entries -- which the scale choice
        precludes), so the caller's inverse scaling ``lam / scale``
        reproduces the mathematically correct spectrum with no extra
        rounding.

    Returns ``(d_scaled, e_scaled, scale)``; callers divide output
    eigenvalues by ``scale``.  Boundary rows (eigenvector entries) are
    scale-invariant and need no correction.  All-zero problems return
    untouched (nothing to protect).
    """
    dh = np.asarray(d) if not _is_jax_array(d) else d
    eh = np.asarray(e) if not _is_jax_array(e) else e
    if _is_jax_array(dh) or _is_jax_array(eh):
        import jax.numpy as jnp
        dmax = float(jnp.max(jnp.abs(dh)))
        emax = float(jnp.max(jnp.abs(eh))) if np.shape(eh)[-1] else 0.0
    else:
        dmax = float(np.max(np.abs(dh)))
        emax = float(np.max(np.abs(eh))) if eh.shape[-1] else 0.0
    orgnrm = max(dmax, emax)
    dtype = np.dtype(dh.dtype) if not _is_jax_array(dh) else np.dtype(
        dh.dtype.name)
    safe = _safe_exponent(dtype)
    if orgnrm == 0.0 or 2.0 ** -safe <= orgnrm <= 2.0 ** safe:
        return d, e, 1.0
    # Exact power-of-two factor bringing orgnrm into [0.5, 1).
    scale = 2.0 ** -(math.frexp(orgnrm)[1])
    s = dtype.type(scale)
    return d * s, e * s, float(scale)


def robustness_counters() -> dict:
    """Process-wide robustness counter snapshot (joined into
    ``plan_cache_stats`` so dashboards get one view)."""
    return {"degradations": DEGRADATIONS.count,
            "deadline_expired": DEADLINES.count}


def reset_robustness_counters() -> None:
    DEGRADATIONS.reset()
    DEADLINES.reset()
