"""Boundary-row divide-and-conquer driver (paper Algorithm 1).

Level-synchronous bottom-up realization of the recursion: all merges at the
same tree depth are independent and executed as one vmapped batch -- the JAX
analogue of the paper's per-level batched CUDA kernels (Section 4.1).

Persistent eigenvector-derived state per level:

    lam   (num_nodes, node_size)      -- child spectra
    rows  (num_nodes, r, node_size)   -- selected eigenvector-matrix rows

with r == 2 for the plain eigenvalue run (blo, bhi -- the rows that feed
the rank-one coupling vectors) and r == 3 when boundary rows of the full
matrix are requested on a padded problem: the third slot tracks the row at
*original* index n-1 through the tree, so ``return_boundary`` costs one
D&C solve even when padding appends sentinel rows below it (the old
formulation re-ran the whole solver on the reversed problem to recover
that row via the flip identity).

State is 3N-4N floats total, O(N).  Transients are O(chunk * K) on
streamed levels and O(B * K^2) <= O(N * stream_threshold) on dense levels
(see merge.py's size-adaptive dispatch).  The conventional baselines in
baselines.py carry quadratic state instead; nothing else differs.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import merge as _merge

# Python-level call counter: regression tests assert that
# return_boundary=True on a padded size performs exactly ONE solve (the
# pre-fusion code recursed on the reversed problem to recover bhi).
SOLVE_INVOCATIONS = 0


class BRResult(NamedTuple):
    eigenvalues: jax.Array     # (n,) ascending
    blo: jax.Array | None      # (n,) first row of Q (None in root mode)
    bhi: jax.Array | None      # (n,) last row of Q
    kprime_per_level: tuple    # diagnostics: active ranks per level


def _tree_shape(n: int, leaf: int):
    """Static padded size N = leaf * 2^L with N >= n."""
    nblocks = max(1, math.ceil(n / leaf))
    L = math.ceil(math.log2(nblocks))
    return leaf * (1 << L), L


def _pad_problem(d, e, leaf):
    """Pad to N = leaf * 2^L with decoupled sentinel 1x1 blocks (exact)."""
    n = d.shape[0]
    N, L = _tree_shape(n, leaf)
    if N == n:
        return d, jnp.pad(e, (0, 1)), N, L  # e padded to length N for indexing
    # Sentinel above the Gershgorin upper bound: pads sort to the top and
    # deflate exactly (their z entries are identically zero since e = 0).
    hi = jnp.max(jnp.abs(d)) + 2.0 * (jnp.max(jnp.abs(e)) if e.shape[0] else 0.0)
    sentinel = hi + 1.0
    d_pad = jnp.concatenate([d, jnp.full((N - n,), sentinel, d.dtype)])
    e_pad = jnp.concatenate([e, jnp.zeros((N - n + 1,), d.dtype)])
    return d_pad, e_pad, N, L


def _leaf_solve(d_adj, e_pad, leaf, track_local=None):
    """Batched leaf eigensolves (paper Sec. 4: parallel leaf initialization).

    Builds the (B, leaf, leaf) dense leaf blocks (off-diagonals at block
    boundaries excluded -- they are the rank-one couplings) and eigendecomposes
    them in one batch.  Keeps the first/last eigenvector rows, plus the row
    at local index ``track_local`` when given (the selected-row slot that
    follows original row n-1 through padding; only the leaf that actually
    contains it propagates a meaningful value upward).
    """
    N = d_adj.shape[0]
    B = N // leaf
    db = d_adj.reshape(B, leaf)
    # e within a block: positions [b*leaf, b*leaf + leaf - 2]
    eb = e_pad[: N].reshape(B, leaf)[:, : leaf - 1] if leaf > 1 else None

    ii = jnp.arange(leaf)
    T = jnp.zeros((B, leaf, leaf), d_adj.dtype)
    T = T.at[:, ii, ii].set(db)
    if leaf > 1:
        j = jnp.arange(leaf - 1)
        T = T.at[:, j, j + 1].set(eb).at[:, j + 1, j].set(eb)
    lam, Q = jnp.linalg.eigh(T)          # ascending
    selected = [Q[:, 0, :], Q[:, leaf - 1, :]]
    if track_local is not None:
        selected.append(Q[:, track_local, :])
    rows = jnp.stack(selected, axis=1)   # (B, r, leaf)
    return lam, rows


def _level_coupling(e_pad, level: int, leaf: int, num_merges: int):
    """(rho, sgn) for every merge at this level.

    Merge i at level ``level`` joins nodes of size M = leaf * 2^level; the
    split sits at original index k = (2i+1) * M, coupling strength e[k-1].
    """
    M = leaf * (1 << level)
    k = (2 * jnp.arange(num_merges) + 1) * M
    beta = e_pad[k - 1]
    return jnp.abs(beta), jnp.where(beta >= 0.0, 1.0, -1.0).astype(e_pad.dtype)


@functools.partial(jax.jit, static_argnames=(
    "leaf", "chunk", "niter", "use_zhat", "return_boundary", "tol_factor",
    "stream_threshold", "fused", "track_idx"))
def _br_dc_padded(d_pad, e_pad, *, leaf, chunk, niter, use_zhat,
                  return_boundary, tol_factor, stream_threshold, fused,
                  track_idx):
    N = d_pad.shape[0]
    L = int(math.log2(N // leaf))

    # Pre-subtract every rank-one coupling from the boundary diagonals
    # (each interior leaf boundary is split exactly once in the tree).
    if N // leaf > 1:
        k = leaf * jnp.arange(1, N // leaf)
        rho_all = jnp.abs(e_pad[k - 1])
        sub = jnp.zeros_like(d_pad).at[k - 1].add(rho_all).at[k].add(rho_all)
        d_adj = d_pad - sub
    else:
        d_adj = d_pad

    track_local = None if track_idx is None else track_idx % leaf
    lam, rows = _leaf_solve(d_adj, e_pad, leaf, track_local=track_local)
    r = rows.shape[1]

    kprimes = []
    for level in range(L):
        B = lam.shape[0] // 2
        M = lam.shape[1]
        root = (B == 1) and not return_boundary
        rho, sgn = _level_coupling(e_pad, level, leaf, B)

        lam_pairs = lam.reshape(B, 2, M)
        rows_pairs = rows.reshape(B, 2, r, M)   # (B, child, slot, M)
        z_inner = jnp.stack(
            [rows_pairs[:, 0, 1, :], rows_pairs[:, 1, 0, :]], axis=1)
        zeros = jnp.zeros((B, M), lam.dtype)
        # Parent slot sources: blo <- [blo_L, 0]; bhi <- [0, bhi_R]; the
        # tracked row lives in whichever child spans index track_idx at
        # this level (a static side -- the same for every node; only the
        # one node on the tracked row's spine carries a meaningful value).
        selected = [
            jnp.concatenate([rows_pairs[:, 0, 0, :], zeros], axis=-1),
            jnp.concatenate([zeros, rows_pairs[:, 1, 1, :]], axis=-1),
        ]
        if track_idx is not None:
            side = (track_idx // M) % 2
            selected.append(
                jnp.concatenate([rows_pairs[:, 0, 2, :], zeros], axis=-1)
                if side == 0 else
                jnp.concatenate([zeros, rows_pairs[:, 1, 2, :]], axis=-1))
        R = jnp.stack(selected, axis=1)           # (B, r, 2M)

        res = _merge.merge_level(
            lam_pairs, z_inner, R, rho, sgn,
            niter=niter, chunk=chunk, use_zhat=use_zhat,
            root_mode=root, tol_factor=tol_factor,
            stream_threshold=stream_threshold, fused=fused)
        lam, rows = res.lam, res.rows
        kprimes.append(res.kprime)

    return lam[0], rows[0], kprimes


def eigvalsh_tridiagonal_br(d, e, *, leaf: int = 32, chunk: int = 256,
                            niter: int = 16, use_zhat: bool = True,
                            return_boundary: bool = False,
                            tol_factor: float = 8.0,
                            stream_threshold: int | None = None,
                            fused: bool = True,
                            dtype=None) -> BRResult:
    """All eigenvalues of the symmetric tridiagonal (d, e) via boundary-row D&C.

    O(n) auxiliary memory; same secular merges as conventional D&C
    (paper Theorem 3.3).

    Args:
      d: (n,) diagonal.  e: (n-1,) off-diagonal.
      leaf: leaf block size (power-of-two tree is built above it).
      chunk: streaming chunk for secular/row updates (memory knob).
      niter: fixed secular iteration budget.
      use_zhat: Gu-Eisenstat weight reconstruction for propagated rows.
      return_boundary: also return (blo, bhi) of the full eigenvector matrix
        (propagates rows through the root merge -- tests/consumers).  Costs
        exactly one solve: on padded sizes the last *original* row is
        tracked as an extra selected row instead of re-solving the flipped
        problem.
      stream_threshold: merges with K <= threshold take the dense
        vectorized path (speed knob; larger values trade O(B K^2) transient
        memory for batch parallelism at the bottom of the tree).  None
        picks the backend-aware default: 0 on CPU (stream everything),
        512 on accelerators (see merge.default_stream_threshold).
      fused: use the single-pass fused conquer post-phase (False: legacy
        two-pass, kept as benchmark baseline).
    """
    global SOLVE_INVOCATIONS
    SOLVE_INVOCATIONS += 1
    d = jnp.asarray(d)
    e = jnp.asarray(e)
    if dtype is not None:
        d = d.astype(dtype)
        e = e.astype(dtype)
    n = d.shape[0]
    if n == 1:
        one = jnp.ones((1,), d.dtype)
        return BRResult(d, one, one, ())

    d_pad, e_pad, N, L = _pad_problem(d, e, leaf)
    if L == 0:
        # Single (possibly padded) leaf: direct small solve.  Track row
        # n-1 explicitly -- with padding, row N-1 is a sentinel row whose
        # support is disjoint from the true spectrum's columns.
        lam, rows = _leaf_solve(d_pad, e_pad, N, track_local=n - 1)
        return BRResult(lam[0][:n], rows[0, 0, :n], rows[0, 2, :n], ())

    # The tracked third row is only needed when padding appends sentinel
    # rows below row n-1; unpadded problems already carry it as bhi.
    track_idx = n - 1 if (return_boundary and N != n) else None
    lam, rows, kprimes = _br_dc_padded(
        d_pad, e_pad, leaf=leaf, chunk=chunk, niter=niter,
        use_zhat=use_zhat, return_boundary=return_boundary,
        tol_factor=tol_factor, stream_threshold=stream_threshold,
        fused=fused, track_idx=track_idx)

    lam = lam[:n]  # sentinels sort above the Gershgorin bound -> dropped
    if return_boundary:
        bhi = rows[2, :n] if track_idx is not None else rows[1, :n]
        return BRResult(lam, rows[0, :n], bhi, tuple(kprimes))
    return BRResult(lam, None, None, tuple(kprimes))


def workspace_model(n: int, leaf: int = 32, chunk: int = 128,
                    itemsize: int = 8, stream_threshold: int = 512) -> dict:
    """Analytic auxiliary-workspace model (Table 1 accounting).

    BR persistent state: lam (N) + rows (2N) + d,e inputs held once (2N);
    transients: the larger of the streamed secular evaluation at the top
    merge, O(chunk * K), the dense small-K levels' batched tiles,
    O(N * min(stream_threshold, N)), and the leaf eigendecomposition batch
    (N * leaf).
    """
    N, _ = _tree_shape(n, leaf)
    persistent = 3 * N * itemsize
    dense_tile = N * min(stream_threshold, N)
    transient = (max(chunk * 2 * N, dense_tile) + N * leaf) * itemsize
    return {
        "persistent_bytes": persistent,
        "transient_bytes": transient,
        "total_bytes": persistent + transient,
        "model": f"3N + (max(2*chunk, min(T,N)) + leaf)*N floats, N={N}",
    }
