"""Boundary-row divide-and-conquer driver (paper Algorithm 1), batch-first.

Level-synchronous bottom-up realization of the recursion: all merges at the
same tree depth are independent and executed as one vmapped batch -- the JAX
analogue of the paper's per-level batched CUDA kernels (Section 4.1).

The core is *batch-first*: every internal array carries a leading problem
axis ``B`` and the per-level merge batch is the flattened ``B x num_nodes``
product, so a batch of independent tridiagonals costs one executor launch
and one XLA program (the distributed-memory hybrid-D&C direction of
arXiv:1612.07526, realized here as a single fused level schedule).
Persistent eigenvector-derived state per level:

    lam   (B, num_nodes, node_size)      -- child spectra
    rows  (B, num_nodes, r, node_size)   -- selected eigenvector-matrix rows

with r == 2 for the plain eigenvalue run (blo, bhi -- the rows that feed
the rank-one coupling vectors) and r == 3 when boundary rows of the full
matrix are requested: the third slot tracks the row at *original* index
n-1 through the tree (a traced per-problem index, so mixed original sizes
inside one padded bucket share one compiled executable), which keeps
``return_boundary`` at one D&C solve even when padding appends sentinel
rows below it.

State is B * (3N-4N) floats total, B * O(N).  Transients are
O(B * chunk * K) on streamed levels and O(B * nodes * K^2) <=
O(B * N * stream_threshold) on dense levels (see merge.py's size-adaptive
dispatch).  The conventional baselines in baselines.py carry quadratic
state instead; nothing else differs.

Compilation is owned by ``repro.core.plan``: both public drivers below
build a :class:`~repro.core.plan.SolvePlan` (single solves are the
batch == 1 bucket) and run its cached executor.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import merge as _merge
from repro.core.instrument import SolveCounter
from repro.core.secular import DEFAULT_NITER

# Device-solve instrumentation: one increment per executor launch (a batch
# of B problems is ONE solve).  Regression tests pin one-solve invariants
# (padded ``return_boundary``, whole-batch SLQ) through this counter.
SOLVE_COUNTER = SolveCounter("device_solves")


class BRResult(NamedTuple):
    eigenvalues: jax.Array     # (n,) ascending
    blo: jax.Array | None      # (n,) first row of Q (None in root mode)
    bhi: jax.Array | None      # (n,) last row of Q
    kprime_per_level: tuple    # diagnostics: active ranks per level


class BRBatchResult(NamedTuple):
    eigenvalues: jax.Array     # (B, n) ascending per problem
    blo: jax.Array | None      # (B, n) first rows of Q (None unless requested)
    bhi: jax.Array | None      # (B, n) last rows of Q
    kprime_per_level: tuple    # diagnostics: (B, num_merges) per level


def _tree_shape(n: int, leaf: int):
    """Static padded size N = leaf * 2^L with N >= n."""
    nblocks = max(1, math.ceil(n / leaf))
    L = math.ceil(math.log2(nblocks))
    return leaf * (1 << L), L


def _pad_problem(d, e, leaf):
    """Pad a batch to N = leaf * 2^L with decoupled sentinel 1x1 blocks.

    d: (B, n), e: (B, n-1).  Returns (d_pad (B, N), e_pad (B, N), N, L);
    e is padded to length N for uniform split indexing.  Sentinels sit
    above each problem's own Gershgorin upper bound, so pads sort to the
    top and deflate exactly (their z entries are identically zero).
    """
    B, n = d.shape
    N, L = _tree_shape(n, leaf)
    if N == n:
        return d, jnp.pad(e, ((0, 0), (0, 1))), N, L
    emax = (jnp.max(jnp.abs(e), axis=1) if e.shape[1]
            else jnp.zeros((B,), d.dtype))
    sentinel = jnp.max(jnp.abs(d), axis=1) + 2.0 * emax + 1.0
    d_pad = jnp.concatenate(
        [d, jnp.broadcast_to(sentinel[:, None], (B, N - n)).astype(d.dtype)],
        axis=1)
    e_pad = jnp.concatenate([e, jnp.zeros((B, N - n + 1), d.dtype)], axis=1)
    return d_pad, e_pad, N, L


def _leaf_solve(d_adj, e_pad, leaf, track_local=None):
    """Batched leaf eigensolves (paper Sec. 4: parallel leaf initialization).

    d_adj, e_pad: (B, N).  Builds the (B, nb, leaf, leaf) dense leaf blocks
    (off-diagonals at block boundaries excluded -- they are the rank-one
    couplings) and eigendecomposes them in one batch.  Keeps the first/last
    eigenvector rows, plus the per-problem row at local index
    ``track_local`` ((B,) int32, traced) when given -- the selected-row
    slot that follows original row n-1 through padding; only the leaf that
    actually contains it propagates a meaningful value upward.

    Returns (lam (B, nb, leaf), rows (B, nb, r, leaf)).
    """
    B, N = d_adj.shape
    nb = N // leaf
    db = d_adj.reshape(B, nb, leaf)
    # e within a block: positions [b*leaf, b*leaf + leaf - 2]
    eb = (e_pad[:, :N].reshape(B, nb, leaf)[:, :, : leaf - 1]
          if leaf > 1 else None)

    ii = jnp.arange(leaf)
    T = jnp.zeros((B, nb, leaf, leaf), d_adj.dtype)
    T = T.at[:, :, ii, ii].set(db)
    if leaf > 1:
        j = jnp.arange(leaf - 1)
        T = T.at[:, :, j, j + 1].set(eb).at[:, :, j + 1, j].set(eb)
    lam, Q = jnp.linalg.eigh(T)          # ascending
    selected = [Q[:, :, 0, :], Q[:, :, leaf - 1, :]]
    if track_local is not None:
        tl = jnp.asarray(track_local, jnp.int32)
        idx = jnp.broadcast_to(tl[:, None, None, None], (B, nb, 1, leaf))
        selected.append(jnp.take_along_axis(Q, idx, axis=2)[:, :, 0, :])
    rows = jnp.stack(selected, axis=2)   # (B, nb, r, leaf)
    return lam, rows


def _level_coupling(e_pad, level: int, leaf: int, num_merges: int):
    """(rho, sgn), each (B, num_merges), for every merge at this level.

    Merge i at level ``level`` joins nodes of size M = leaf * 2^level; the
    split sits at original index k = (2i+1) * M, coupling strength e[k-1].
    """
    M = leaf * (1 << level)
    k = (2 * jnp.arange(num_merges) + 1) * M
    beta = e_pad[:, k - 1]
    return jnp.abs(beta), jnp.where(beta >= 0.0, 1.0, -1.0).astype(e_pad.dtype)


def _level_pairs(lam, rows, track, M):
    """Pair adjacent nodes for one level of merges.

    lam: (B, 2*nm, M); rows: (B, 2*nm, r, M); track: (B,) int32 *global*
    tracked row index or None; M the child node size.  Returns
    (lam_pairs (B, nm, 2, M), z_inner (B, nm, 2, M), R (B, nm, r, 2M)).

    Parent slot sources: blo <- [blo_L, 0]; bhi <- [0, bhi_R]; the
    tracked row lives in whichever child spans index track[b] at this
    level -- a traced per-problem side, identical for every node of that
    problem (only the one node on the tracked row's spine carries a
    meaningful value).  ``(track // M) % 2`` uses the *global* index even
    for shard-local subtrees: a shard's origin is a multiple of 2M at
    every subtree level, so the parity is the same in local and global
    coordinates.
    """
    B = lam.shape[0]
    nm = lam.shape[1] // 2
    r = rows.shape[2]
    lam_pairs = lam.reshape(B, nm, 2, M)
    rows_pairs = rows.reshape(B, nm, 2, r, M)  # (B, merge, child, slot, M)
    z_inner = jnp.stack(
        [rows_pairs[:, :, 0, 1, :], rows_pairs[:, :, 1, 0, :]], axis=2)
    zeros = jnp.zeros((B, nm, M), lam.dtype)
    selected = [
        jnp.concatenate([rows_pairs[:, :, 0, 0, :], zeros], axis=-1),
        jnp.concatenate([zeros, rows_pairs[:, :, 1, 1, :]], axis=-1),
    ]
    if track is not None:
        side = (track // M) % 2                            # (B,)
        left = jnp.concatenate([rows_pairs[:, :, 0, 2, :], zeros],
                               axis=-1)
        right = jnp.concatenate([zeros, rows_pairs[:, :, 1, 2, :]],
                                axis=-1)
        selected.append(
            jnp.where((side == 0)[:, None, None], left, right))
    return lam_pairs, z_inner, jnp.stack(selected, axis=2)  # R (B,nm,r,2M)


def _br_dc_padded_batch(d_pad, e_pad, track, *, leaf, chunk, niter, use_zhat,
                        return_boundary, tol_factor, stream_threshold,
                        deflate_budget, resident_threshold, fused):
    """Batch-first padded D&C body (traced; jitted by plan._executor).

    d_pad, e_pad: (B, N); track: (B,) int32 per-problem tracked original
    row index, or None.  Returns (lam (B, N), rows (B, r, N), kprimes:
    list of (B, num_merges) per level).
    """
    B, N = d_pad.shape
    L = int(math.log2(N // leaf))
    nb = N // leaf

    # Pre-subtract every rank-one coupling from the boundary diagonals
    # (each interior leaf boundary is split exactly once in the tree).
    if nb > 1:
        k = leaf * jnp.arange(1, nb)
        rho_all = jnp.abs(e_pad[:, k - 1])
        sub = jnp.zeros_like(d_pad).at[:, k - 1].add(rho_all) \
                                   .at[:, k].add(rho_all)
        d_adj = d_pad - sub
    else:
        d_adj = d_pad

    track_local = None if track is None else track % leaf
    lam, rows = _leaf_solve(d_adj, e_pad, leaf, track_local=track_local)

    kprimes = []
    for level in range(L):
        nm = lam.shape[1] // 2
        M = lam.shape[2]
        root = (nm == 1) and not return_boundary
        rho, sgn = _level_coupling(e_pad, level, leaf, nm)   # (B, nm)
        lam_pairs, z_inner, R = _level_pairs(lam, rows, track, M)

        res = _merge.merge_level_batched(
            lam_pairs, z_inner, R, rho, sgn,
            niter=niter, chunk=chunk, use_zhat=use_zhat,
            root_mode=root, tol_factor=tol_factor,
            stream_threshold=stream_threshold,
            deflate_budget=deflate_budget,
            resident_threshold=resident_threshold, fused=fused)
        lam, rows = res.lam, res.rows             # (B, nm, 2M) / (B, nm, r, 2M)
        kprimes.append(res.kprime)                # (B, nm)

    return lam[:, 0], rows[:, 0], kprimes


def _br_dc_sharded_batch(d_loc, e_loc, track, *, shards, axis_name, leaf,
                         chunk, niter, use_zhat, return_boundary, tol_factor,
                         stream_threshold, deflate_budget,
                         resident_threshold, fused, compress_halo=False):
    """Distributed-conquer D&C body: runs inside a 1-D shard_map mesh.

    d_loc, e_loc: (B, Np) -- this device's contiguous slice of the padded
    (B, N = shards * Np) problem; track: (B,) int32 *global* tracked row
    index or None (replicated).  Returns the same (lam (B, N), rows
    (B, r, N), kprimes) as :func:`_br_dc_padded_batch`, replicated on
    every device.

    Phase structure (the paper's O(n) conquer state is what makes every
    cross-device transfer linear):

      1. *Divide*: rank-one coupling pre-subtraction.  Couplings interior
         to the shard are local; each shard-edge coupling lives in the
         left neighbour's last ``e`` slot, fetched with a one-element
         ppermute halo (`dist.sharding.halo_from_left`).  Scatter-add
         grouping mirrors the single-device path (all ``k-1`` slots, then
         all ``k`` slots) so ``d_adj`` is bit-identical to its slice of
         the unsharded computation.
      2. *Independent subtrees*: leaves and the ``log2(Np/leaf)`` low
         merge levels run embarrassingly parallel per device -- the same
         level loop as the single-device path on the local slice, never
         in root mode.
      3. *Transition*: one all-gather of the O(n) state -- each shard's
         eigenvalues (Np) and r selected rows (r * Np); optionally int8
         error-feedback compressed rows (``compress_halo``).
      4. *Cooperative levels*: state is replicated; each level's merge
         head and post-pass run replicated while the O(K^2) secular root
         solve is sharded into N/shards-root windows per device and the
         (origin, tau) windows all-gathered (see
         :func:`repro.core.merge.merge_level_coop`).
    """
    from repro.dist import sharding as _dist
    B, Np = d_loc.shape
    if Np % leaf:
        raise ValueError(
            f"shard width {Np} must be a multiple of leaf={leaf} "
            f"(route resolution guarantees 2^L >= shards)")
    L_loc = int(math.log2(Np // leaf))
    L_coop = int(math.log2(shards))
    nb_loc = Np // leaf
    p = jax.lax.axis_index(axis_name)

    # ---- divide: coupling pre-subtraction with shard-edge halo ----------
    edge = jnp.abs(e_loc[:, -1])                       # right-edge coupling
    from_left = _dist.halo_from_left(edge, shards, axis_name)  # 0 on shard 0
    sub = jnp.zeros_like(d_loc)
    # Group scatter-adds exactly like the single-device path: first every
    # boundary's k-1 slot, then every k slot (a position can receive one
    # of each; FP addition order must match for bit-identity).
    if nb_loc > 1:
        k = leaf * jnp.arange(1, nb_loc)
        rho_int = jnp.abs(e_loc[:, k - 1])
        sub = sub.at[:, k - 1].add(rho_int)
    # e_loc[:, -1] is zero-padded on the last shard, so its edge term
    # vanishes there exactly as the global boundary list ends at N - leaf.
    sub = sub.at[:, Np - 1].add(edge)
    if nb_loc > 1:
        sub = sub.at[:, k].add(rho_int)
    sub = sub.at[:, 0].add(from_left)
    d_adj = d_loc - sub

    # ---- phase 2: leaves + independent local subtree --------------------
    # Shard origins are multiples of leaf, so leaf-local positions (and
    # the level-side parities in _level_pairs) match global coordinates.
    track_local = None if track is None else track % leaf
    lam, rows = _leaf_solve(d_adj, e_loc, leaf, track_local=track_local)

    kprimes = []
    for level in range(L_loc):
        nm_loc = lam.shape[1] // 2
        M = lam.shape[2]
        rho, sgn = _level_coupling(e_loc, level, leaf, nm_loc)
        lam_pairs, z_inner, R = _level_pairs(lam, rows, track, M)
        res = _merge.merge_level_batched(
            lam_pairs, z_inner, R, rho, sgn,
            niter=niter, chunk=chunk, use_zhat=use_zhat,
            root_mode=False,  # the local root is never the global root
            tol_factor=tol_factor, stream_threshold=stream_threshold,
            deflate_budget=deflate_budget,
            resident_threshold=resident_threshold, fused=fused)
        lam, rows = res.lam, res.rows
        # Diagnostics keep the global (B, num_merges) layout: shard-local
        # nodes are contiguous in the global node order.
        kprimes.append(_dist.gather_lanes(res.kprime, axis_name))

    # ---- phase 3: the O(n) state all-gather -----------------------------
    lam, rows = _dist.gather_tree_state(lam[:, 0], rows[:, 0], axis_name,
                                        compress=compress_halo)
    # Shard-edge couplings for the cooperative levels (one (B,) value per
    # shard; sgn needs the raw signed e, so gather before the abs).
    e_edges = _dist.gather_lanes(e_loc[:, -1:], axis_name)   # (B, shards)

    # ---- phase 4: cooperative levels ------------------------------------
    for _ in range(L_coop):
        nm = lam.shape[1] // 2
        M = lam.shape[2]
        root = (nm == 1) and not return_boundary
        q = (2 * jnp.arange(nm) + 1) * (M // Np) - 1
        beta = e_edges[:, q]                               # (B, nm)
        rho = jnp.abs(beta)
        sgn = jnp.where(beta >= 0.0, 1.0, -1.0).astype(e_loc.dtype)
        lam_pairs, z_inner, R = _level_pairs(lam, rows, track, M)
        res = _merge.merge_level_coop(
            lam_pairs, z_inner, R, rho, sgn,
            axis_name=axis_name, shards=shards,
            niter=niter, chunk=chunk, use_zhat=use_zhat,
            root_mode=root, tol_factor=tol_factor,
            stream_threshold=stream_threshold,
            deflate_budget=deflate_budget,
            resident_threshold=resident_threshold, fused=fused)
        lam, rows = res.lam, res.rows
        kprimes.append(res.kprime)

    return lam[:, 0], rows[:, 0], kprimes


def _as_batch(d, e, dtype):
    d = jnp.asarray(d)
    e = jnp.asarray(e)
    if dtype is not None:
        d = d.astype(dtype)
        e = e.astype(dtype)
    if (d.ndim != 2 or e.ndim != 2 or e.shape[0] != d.shape[0]
            or e.shape[1] != max(d.shape[1] - 1, 0)):
        raise ValueError(
            f"batched solve expects d (B, n) and e (B, n-1); "
            f"got {d.shape} / {e.shape}")
    return d, e


def eigvalsh_tridiagonal_batch(d, e, *, leaf: int = 32, chunk: int = 256,
                               niter: int | None = None,
                               use_zhat: bool = True,
                               return_boundary: bool = False,
                               tol_factor: float = 8.0,
                               stream_threshold: int | None = None,
                               deflate_budget: int | None = None,
                               resident_threshold: int | None = None,
                               fused: bool = True,
                               dtype=None, mesh="auto",
                               compress_halo: bool = False,
                               precision: str = "native",
                               refine_tol: float | None = None
                               ) -> BRBatchResult:
    """All eigenvalues of B independent symmetric tridiagonals at once.

    One executor launch, one XLA program, B * O(n) persistent state: the
    per-level merge batch absorbs the problem axis, so every secular
    solve / deflation scan across the whole batch runs in a single
    vectorized sweep.  Compiled executables are cached per
    ``(padded N, leaf, batch bucket, dtype, flags)`` bucket with batch
    buckets rounded up to powers of two (see ``repro.core.plan``), so
    arbitrary request batches hit a handful of traces.

    Args:
      d: (B, n) diagonals.  e: (B, n-1) off-diagonals.
      return_boundary: also return (blo, bhi) of each problem's full
        eigenvector matrix (one extra tracked selected row; still one
        solve).
      Remaining knobs as in :func:`eigvalsh_tridiagonal_br`.

    Returns:
      BRBatchResult with eigenvalues (B, n) ascending per problem.
    """
    if precision == "mixed" and dtype is None:
        dtype = jnp.float64   # mixed certifies / returns in f64
    d, e = _as_batch(d, e, dtype)
    B, n = d.shape
    if n == 1:
        ones = jnp.ones((B, 1), d.dtype)
        SOLVE_COUNTER.increment()
        return BRBatchResult(d, ones if return_boundary else None,
                             ones if return_boundary else None, ())

    from repro.core import plan as _plan  # deferred: plan imports br_dc
    p = _plan.make_plan(n, B, leaf=leaf, chunk=chunk, niter=niter,
                        use_zhat=use_zhat, return_boundary=return_boundary,
                        tol_factor=tol_factor,
                        stream_threshold=stream_threshold,
                        deflate_budget=deflate_budget,
                        resident_threshold=resident_threshold, fused=fused,
                        dtype=d.dtype, mesh=mesh, compress_halo=compress_halo,
                        precision=precision, refine_tol=refine_tol)
    return p.execute(d, e)


def eigvalsh_tridiagonal_br(d, e, *, leaf: int = 32, chunk: int = 256,
                            niter: int | None = None,
                            use_zhat: bool = True,
                            return_boundary: bool = False,
                            tol_factor: float = 8.0,
                            stream_threshold: int | None = None,
                            deflate_budget: int | None = None,
                            resident_threshold: int | None = None,
                            fused: bool = True,
                            dtype=None, mesh="auto",
                            compress_halo: bool = False,
                            precision: str = "native",
                            refine_tol: float | None = None) -> BRResult:
    """All eigenvalues of the symmetric tridiagonal (d, e) via boundary-row D&C.

    O(n) auxiliary memory; same secular merges as conventional D&C
    (paper Theorem 3.3).  A single solve is the batch == 1 bucket of the
    plan/executor core -- see :func:`eigvalsh_tridiagonal_batch` for the
    many-problem front door sharing the same compiled executables.

    Args:
      d: (n,) diagonal.  e: (n-1,) off-diagonal.
      leaf: leaf block size (power-of-two tree is built above it).
      chunk: streaming chunk for secular/row updates (memory knob).
      niter: fixed secular iteration budget.  None picks the precision's
        default: ``secular.DEFAULT_NITER`` for native trees,
        ``secular.DEFAULT_NITER_F32`` for f32/mixed trees (single
        precision hits its accuracy floor in fewer iterations).
      use_zhat: Gu-Eisenstat weight reconstruction for propagated rows.
      return_boundary: also return (blo, bhi) of the full eigenvector matrix
        (propagates rows through the root merge -- tests/consumers).  Costs
        exactly one solve: on padded sizes the last *original* row is
        tracked as an extra selected row instead of re-solving the flipped
        problem.
      stream_threshold: merges with K <= threshold take the dense
        vectorized path (speed knob; larger values trade O(B K^2) transient
        memory for batch parallelism at the bottom of the tree).  None
        picks the backend-aware default: 0 on CPU (stream everything),
        512 on accelerators (see merge.default_stream_threshold).
      deflate_budget: rotation-candidate budget of the parallel deflation
        head (merges run a short exact close-pole chain over at most this
        many candidates instead of a K-step scan; overflow escalates to
        exact K/2 / full-K tiers).  None: the library default
        (merge.DEFAULT_DEFLATE_BUDGET); <= 0 forces the sequential chain.
      resident_threshold: merges with K at or below it run the secular
        solve + fused post-pass as ONE resident dispatch (a single Pallas
        launch per level on TPU).  None picks the backend-aware default:
        0 on CPU, 512 on accelerators (merge.default_resident_threshold).
      fused: use the single-pass fused conquer post-phase (False: legacy
        two-pass, kept as benchmark baseline).
      mesh: distributed-conquer routing.  "auto" (default) shards huge
        problems (padded N >= plan.DIST_AUTO_MIN_N) over the largest
        power-of-two device count available -- a no-op on one device; an
        int or a Mesh demands exactly that many contiguous problem
        shards and raises when devices or tree leaves are short; 1/None
        forces the single-device path.
      compress_halo: int8-compress the boundary rows in the sharded
        path's subtree->cooperative all-gather (off by default; the
        uncompressed sharded path is bit-identical to single-device).
      precision: "native" (default) runs the tree in the input dtype;
        "mixed" runs the ENTIRE tree -- leaves, deflation, secular
        iteration, fused post-pass, resident kernel, sharded halo -- in
        f32, then certifies every eigenvalue with f64 Sturm counts
        against the original (d, e) and polishes only the non-certified
        clusters with bracket-guarded f64 iteration
        (``bisect.refine_clusters``).  Output is float64 with every
        eigenvalue within ``refine_tol * eps_f64 * ||T||_1`` of a true
        eigenvalue.  Requires x64 mode.  Boundary rows under mixed are
        f32-accurate (cast to f64, permuted with the eigenvalues) --
        only the eigenvalues are refined.
      refine_tol: mixed-precision certification tolerance in units of
        ``eps_f64 * ||T||_1`` (default ``bisect.DEFAULT_REFINE_TOL``);
        only valid with precision="mixed".
    """
    d = jnp.asarray(d)
    e = jnp.asarray(e)
    if precision == "mixed" and dtype is None:
        dtype = jnp.float64   # mixed certifies / returns in f64
    if dtype is not None:
        d = d.astype(dtype)
        e = e.astype(dtype)
    n = d.shape[0]
    if n == 1:
        one = jnp.ones((1,), d.dtype)
        SOLVE_COUNTER.increment()
        return BRResult(d, one, one, ())

    N, L = _tree_shape(n, leaf)
    from repro.core import plan as _plan  # deferred: plan imports br_dc
    # Single (possibly padded) leaf trees carry their boundary rows for
    # free (no root merge to skip them at), matching the historical
    # contract that L == 0 always returns (blo, bhi).
    p = _plan.make_plan(n, 1, leaf=leaf, chunk=chunk, niter=niter,
                        use_zhat=use_zhat,
                        return_boundary=return_boundary or L == 0,
                        tol_factor=tol_factor,
                        stream_threshold=stream_threshold,
                        deflate_budget=deflate_budget,
                        resident_threshold=resident_threshold, fused=fused,
                        dtype=d.dtype, mesh=mesh, compress_halo=compress_halo,
                        precision=precision, refine_tol=refine_tol)
    res = p.execute(d[None, :], e[None, :])
    blo = None if res.blo is None else res.blo[0]
    bhi = None if res.bhi is None else res.bhi[0]
    return BRResult(res.eigenvalues[0], blo, bhi,
                    tuple(k[0] for k in res.kprime_per_level))


def workspace_model(n: int, leaf: int = 32, chunk: int = 128,
                    itemsize: int = 8, stream_threshold: int = 512,
                    batch: int = 1) -> dict:
    """Analytic auxiliary-workspace model (Table 1 accounting).

    BR persistent state per problem: lam (N) + rows (2N) + d,e inputs held
    once (2N); transients: the larger of the streamed secular evaluation
    at the top merge, O(chunk * K), the dense small-K levels' batched
    tiles, O(N * min(stream_threshold, N)), and the leaf
    eigendecomposition batch (N * leaf).  A batch of B problems scales
    every term linearly: B * O(N) persistent -- the memory model that
    makes many-problem workloads viable (the lazy/full baselines would
    pay B * O(N^2)).
    """
    N, _ = _tree_shape(n, leaf)
    persistent = batch * 3 * N * itemsize
    dense_tile = N * min(stream_threshold, N)
    transient = batch * (max(chunk * 2 * N, dense_tile) + N * leaf) * itemsize
    return {
        "persistent_bytes": persistent,
        "transient_bytes": transient,
        "total_bytes": persistent + transient,
        "model": f"B*(3N + (max(2*chunk, min(T,N)) + leaf)*N) floats, "
                 f"N={N}, B={batch}",
    }
