"""Boundary-row divide-and-conquer driver (paper Algorithm 1).

Level-synchronous bottom-up realization of the recursion: all merges at the
same tree depth are independent and executed as one vmapped batch -- the JAX
analogue of the paper's per-level batched CUDA kernels (Section 4.1).

Persistent eigenvector-derived state per level:

    lam   (num_nodes, node_size)      -- child spectra
    rows  (num_nodes, 2, node_size)   -- (blo, bhi) boundary rows   <-- BR

i.e. 3N floats total, O(N).  Transients are O(chunk * K) by construction
(see secular.py).  The conventional baselines in baselines.py carry
quadratic state instead; nothing else differs.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import merge as _merge


class BRResult(NamedTuple):
    eigenvalues: jax.Array     # (n,) ascending
    blo: jax.Array | None      # (n,) first row of Q (None in root mode)
    bhi: jax.Array | None      # (n,) last row of Q
    kprime_per_level: tuple    # diagnostics: active ranks per level


def _tree_shape(n: int, leaf: int):
    """Static padded size N = leaf * 2^L with N >= n."""
    nblocks = max(1, math.ceil(n / leaf))
    L = math.ceil(math.log2(nblocks))
    return leaf * (1 << L), L


def _pad_problem(d, e, leaf):
    """Pad to N = leaf * 2^L with decoupled sentinel 1x1 blocks (exact)."""
    n = d.shape[0]
    N, L = _tree_shape(n, leaf)
    if N == n:
        return d, jnp.pad(e, (0, 1)), N, L  # e padded to length N for indexing
    # Sentinel above the Gershgorin upper bound: pads sort to the top and
    # deflate exactly (their z entries are identically zero since e = 0).
    hi = jnp.max(jnp.abs(d)) + 2.0 * (jnp.max(jnp.abs(e)) if e.shape[0] else 0.0)
    sentinel = hi + 1.0
    d_pad = jnp.concatenate([d, jnp.full((N - n,), sentinel, d.dtype)])
    e_pad = jnp.concatenate([e, jnp.zeros((N - n + 1,), d.dtype)])
    return d_pad, e_pad, N, L


def _leaf_solve(d_adj, e_pad, leaf):
    """Batched leaf eigensolves (paper Sec. 4: parallel leaf initialization).

    Builds the (B, leaf, leaf) dense leaf blocks (off-diagonals at block
    boundaries excluded -- they are the rank-one couplings) and eigendecomposes
    them in one batch.  Only the first/last eigenvector rows are kept.
    """
    N = d_adj.shape[0]
    B = N // leaf
    db = d_adj.reshape(B, leaf)
    # e within a block: positions [b*leaf, b*leaf + leaf - 2]
    eb = e_pad[: N].reshape(B, leaf)[:, : leaf - 1] if leaf > 1 else None

    ii = jnp.arange(leaf)
    T = jnp.zeros((B, leaf, leaf), d_adj.dtype)
    T = T.at[:, ii, ii].set(db)
    if leaf > 1:
        j = jnp.arange(leaf - 1)
        T = T.at[:, j, j + 1].set(eb).at[:, j + 1, j].set(eb)
    lam, Q = jnp.linalg.eigh(T)          # ascending
    rows = jnp.stack([Q[:, 0, :], Q[:, leaf - 1, :]], axis=1)  # (B, 2, leaf)
    return lam, rows


def _level_coupling(e_pad, level: int, leaf: int, num_merges: int):
    """(rho, sgn) for every merge at this level.

    Merge i at level ``level`` joins nodes of size M = leaf * 2^level; the
    split sits at original index k = (2i+1) * M, coupling strength e[k-1].
    """
    M = leaf * (1 << level)
    k = (2 * jnp.arange(num_merges) + 1) * M
    beta = e_pad[k - 1]
    return jnp.abs(beta), jnp.where(beta >= 0.0, 1.0, -1.0).astype(e_pad.dtype)


@functools.partial(jax.jit, static_argnames=(
    "leaf", "chunk", "niter", "use_zhat", "return_boundary", "tol_factor"))
def _br_dc_padded(d_pad, e_pad, *, leaf, chunk, niter, use_zhat,
                  return_boundary, tol_factor):
    N = d_pad.shape[0]
    L = int(math.log2(N // leaf))

    # Pre-subtract every rank-one coupling from the boundary diagonals
    # (each interior leaf boundary is split exactly once in the tree).
    if N // leaf > 1:
        k = leaf * jnp.arange(1, N // leaf)
        rho_all = jnp.abs(e_pad[k - 1])
        sub = jnp.zeros_like(d_pad).at[k - 1].add(rho_all).at[k].add(rho_all)
        d_adj = d_pad - sub
    else:
        d_adj = d_pad

    lam, rows = _leaf_solve(d_adj, e_pad, leaf)

    kprimes = []
    for level in range(L):
        B = lam.shape[0] // 2
        M = lam.shape[1]
        root = (B == 1) and not return_boundary
        rho, sgn = _level_coupling(e_pad, level, leaf, B)

        lam_pairs = lam.reshape(B, 2, M)
        rows_pairs = rows.reshape(B, 2, 2, M)   # (B, child, {blo,bhi}, M)
        z_inner = jnp.stack(
            [rows_pairs[:, 0, 1, :], rows_pairs[:, 1, 0, :]], axis=1)
        zeros = jnp.zeros((B, M), lam.dtype)
        # Parent blo source: [blo_L, 0]; parent bhi source: [0, bhi_R].
        R = jnp.stack([
            jnp.concatenate([rows_pairs[:, 0, 0, :], zeros], axis=-1),
            jnp.concatenate([zeros, rows_pairs[:, 1, 1, :]], axis=-1),
        ], axis=1)                                # (B, 2, 2M)

        res = _merge.merge_level(
            lam_pairs, z_inner, R, rho, sgn,
            niter=niter, chunk=chunk, use_zhat=use_zhat,
            root_mode=root, tol_factor=tol_factor)
        lam, rows = res.lam, res.rows
        kprimes.append(res.kprime)

    return lam[0], rows[0], kprimes


def eigvalsh_tridiagonal_br(d, e, *, leaf: int = 32, chunk: int = 256,
                            niter: int = 16, use_zhat: bool = True,
                            return_boundary: bool = False,
                            tol_factor: float = 8.0,
                            dtype=None, _flip_for_bhi: bool = True) -> BRResult:
    """All eigenvalues of the symmetric tridiagonal (d, e) via boundary-row D&C.

    O(n) auxiliary memory; same secular merges as conventional D&C
    (paper Theorem 3.3).

    Args:
      d: (n,) diagonal.  e: (n-1,) off-diagonal.
      leaf: leaf block size (power-of-two tree is built above it).
      chunk: streaming chunk for secular/row updates (memory knob).
      niter: fixed secular iteration budget.
      use_zhat: Gu-Eisenstat weight reconstruction for propagated rows.
      return_boundary: also return (blo, bhi) of the full eigenvector matrix
        (propagates rows through the root merge -- tests/consumers).
    """
    d = jnp.asarray(d)
    e = jnp.asarray(e)
    if dtype is not None:
        d = d.astype(dtype)
        e = e.astype(dtype)
    n = d.shape[0]
    if n == 1:
        one = jnp.ones((1,), d.dtype)
        return BRResult(d, one, one, ())

    d_pad, e_pad, N, L = _pad_problem(d, e, leaf)
    if L == 0:
        # Single leaf: direct small solve.
        lam, rows = _leaf_solve(d_pad, e_pad, N)
        return BRResult(lam[0][:n], rows[0, 0, :n], rows[0, 1, :n], ())

    lam, rows, kprimes = _br_dc_padded(
        d_pad, e_pad, leaf=leaf, chunk=chunk, niter=niter,
        use_zhat=use_zhat, return_boundary=return_boundary,
        tol_factor=tol_factor)

    lam = lam[:n]  # sentinels sort above the Gershgorin bound -> dropped
    if return_boundary:
        bhi = rows[1, :n]
        if N != n and _flip_for_bhi:
            # Padding appends sentinel rows *below* row n-1, so the tracked
            # "last row" is a pad row.  Recover the true last row via the
            # flip identity bhi(T) = blo(J T J) (J T J has d, e reversed and
            # the same ascending eigenvalue column order).
            res_flip = eigvalsh_tridiagonal_br(
                d[::-1], e[::-1], leaf=leaf, chunk=chunk, niter=niter,
                use_zhat=use_zhat, return_boundary=True,
                tol_factor=tol_factor, dtype=dtype, _flip_for_bhi=False)
            bhi = res_flip.blo
        return BRResult(lam, rows[0, :n], bhi, tuple(kprimes))
    return BRResult(lam, None, None, tuple(kprimes))


def workspace_model(n: int, leaf: int = 32, chunk: int = 128,
                    itemsize: int = 8) -> dict:
    """Analytic auxiliary-workspace model (Table 1 accounting).

    BR persistent state: lam (N) + rows (2N) + d,e inputs held once (2N);
    transients: O(chunk * K) for the streamed secular evaluations at the top
    merge plus the leaf eigendecomposition batch (N * leaf).
    """
    N, _ = _tree_shape(n, leaf)
    persistent = 3 * N * itemsize
    transient = (chunk * 2 * N + N * leaf) * itemsize
    return {
        "persistent_bytes": persistent,
        "transient_bytes": transient,
        "total_bytes": persistent + transient,
        "model": f"3N + (2*chunk + leaf)*N floats, N={N}",
    }
