"""repro.core -- boundary-row D&C eigenvalue-only tridiagonal eigensolver.

The paper's contribution (BR) plus the three baselines it is evaluated
against, all sharing one merge core so exact-arithmetic equivalence
(paper Theorem 3.3) holds by construction.
"""

from repro.core.api import eigvalsh_tridiagonal, METHODS
from repro.core.bisect import (SpectrumCertificate, certify_spectrum,
                               eigvalsh_tridiagonal_range, sturm_count)
from repro.core.guard import (CertificationError, DeadlineExceeded,
                              InvalidInputError, equilibrate,
                              validate_problem)
from repro.core.request import (
    KINDS,
    RoutedRequest,
    SolveRequest,
    SolveResult,
    execute_request,
    route_request,
)
from repro.core.br_dc import (
    BRBatchResult,
    BRResult,
    SOLVE_COUNTER,
    eigvalsh_tridiagonal_batch,
    eigvalsh_tridiagonal_br,
    workspace_model,
)
from repro.core.plan import (
    RangePlan,
    SolvePlan,
    clear_plan_cache,
    make_plan,
    make_range_plan,
    plan_cache_stats,
    plan_for_route,
    prewarm,
    range_plan_for_route,
    resolve_range_route,
    resolve_solve_route,
)
from repro.core.sterf import eigvalsh_tridiagonal_sterf
from repro.core.baselines import (
    eig_tridiagonal_full_dc,
    eigvalsh_tridiagonal_bisect,
    eigvalsh_tridiagonal_full_discard,
    eigvalsh_tridiagonal_lazy,
    workspace_model_bisect,
    workspace_model_full,
    workspace_model_lazy,
    workspace_model_sterf,
)
from repro.core.secular import (
    boundary_rows_update,
    secular_eigenvalues,
    secular_solve,
    zhat_reconstruct,
)
from repro.core.tridiag import (
    FAMILIES,
    dense_from_tridiag,
    gershgorin_bounds,
    make_family,
    make_family_batch,
)

__all__ = [
    "BRBatchResult", "BRResult", "CertificationError", "DeadlineExceeded",
    "FAMILIES", "InvalidInputError", "KINDS", "METHODS",
    "RangePlan", "RoutedRequest",
    "SOLVE_COUNTER",
    "SolvePlan", "SolveRequest", "SolveResult", "SpectrumCertificate",
    "boundary_rows_update", "certify_spectrum", "clear_plan_cache",
    "dense_from_tridiag", "equilibrate",
    "eig_tridiagonal_full_dc", "eigvalsh_tridiagonal",
    "eigvalsh_tridiagonal_batch", "eigvalsh_tridiagonal_bisect",
    "eigvalsh_tridiagonal_br",
    "eigvalsh_tridiagonal_full_discard",
    "eigvalsh_tridiagonal_lazy", "eigvalsh_tridiagonal_range",
    "eigvalsh_tridiagonal_sterf", "execute_request",
    "gershgorin_bounds", "make_family", "make_family_batch",
    "make_plan", "make_range_plan", "plan_cache_stats", "plan_for_route",
    "prewarm", "range_plan_for_route", "resolve_range_route",
    "resolve_solve_route", "route_request",
    "secular_eigenvalues",
    "secular_solve", "sturm_count", "validate_problem", "workspace_model",
    "workspace_model_bisect", "workspace_model_full",
    "workspace_model_lazy", "workspace_model_sterf", "zhat_reconstruct",
]
