"""Conventional D&C baselines the paper compares against (Tables 3-4).

Both reuse the *same* merge core as BR (merge.py) so that Theorem 3.3's
"same split tree / deflation / secular convention" premise holds exactly --
the only difference is what eigenvector-derived state persists across
levels:

  * ``full_dc``  -- conventional D&C: propagates the complete eigenvector
    matrix rows through every merge.  Quadratic state; also returns Q
    (used as an independent oracle in tests and as the cuSOLVER
    Xstedc(compz='N')-style "compute and discard" stand-in).

  * ``lazy_dc``  -- the paper's "internal values-only D&C" baseline
    (LAPACK DLAED0(ICOMPQ=0) + DLAEDA): stores the dense local secular
    transform S_v of every merge (obtained by pushing an identity through
    the merge) and *replays* chains of them to reconstruct the boundary
    rows each parent needs (Fig. 2: r_l = ((r_0 S_1) S_2) ... S_l).
    Quadratic replay state, sum_v K_v^2 ~ 2 n^2 floats.

Their workspace models are reported by ``workspace_model_*`` and measured
in benchmarks/bench_workspace.py.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core import merge as _merge
from repro.core.secular import DEFAULT_NITER
from repro.core.br_dc import _leaf_solve, _pad_problem, _level_coupling


def _prepare(d, e, leaf, dtype):
    d = jnp.asarray(d)
    e = jnp.asarray(e)
    if dtype is not None:
        d = d.astype(dtype)
        e = e.astype(dtype)
    n = d.shape[0]
    # The br_dc helpers are batch-first; the baselines are single-problem
    # by design (their whole point is quadratic per-problem state), so
    # wrap/unwrap a singleton batch axis.
    d_pad, e_pad, N, L = _pad_problem(d[None, :], e[None, :], leaf)
    d_pad, e_pad = d_pad[0], e_pad[0]
    if N // leaf > 1:
        k = leaf * jnp.arange(1, N // leaf)
        rho_all = jnp.abs(e_pad[k - 1])
        sub = jnp.zeros_like(d_pad).at[k - 1].add(rho_all).at[k].add(rho_all)
        d_adj = d_pad - sub
    else:
        d_adj = d_pad
    return d_adj, e_pad, n, N, L


# ---------------------------------------------------------------------------
# Full-vector D&C (conventional; quadratic by design)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("leaf", "chunk", "niter", "use_zhat"))
def _full_dc_jit(d_adj, e_pad, *, leaf, chunk, niter, use_zhat):
    N = d_adj.shape[0]
    L = int(math.log2(N // leaf))
    B0 = N // leaf

    db = d_adj.reshape(B0, leaf)
    eb = e_pad[:N].reshape(B0, leaf)[:, : leaf - 1]
    ii = jnp.arange(leaf)
    T = jnp.zeros((B0, leaf, leaf), d_adj.dtype)
    T = T.at[:, ii, ii].set(db)
    jj = jnp.arange(leaf - 1)
    T = T.at[:, jj, jj + 1].set(eb).at[:, jj + 1, jj].set(eb)
    lam, Q = jnp.linalg.eigh(T)      # (B0, leaf) / (B0, leaf, leaf)

    for level in range(L):
        B = lam.shape[0] // 2
        M = lam.shape[1]
        rho, sgn = _level_coupling(e_pad[None, :], level, leaf, B)
        rho, sgn = rho[0], sgn[0]
        lam_pairs = lam.reshape(B, 2, M)
        Q_pairs = Q.reshape(B, 2, M, M)
        z_inner = jnp.stack(
            [Q_pairs[:, 0, M - 1, :], Q_pairs[:, 1, 0, :]], axis=1)
        # Full row set: the block-diagonal Q_L (+) Q_R  -> (B, 2M, 2M)
        zeros = jnp.zeros((B, M, M), lam.dtype)
        top = jnp.concatenate([Q_pairs[:, 0], zeros], axis=-1)
        bot = jnp.concatenate([zeros, Q_pairs[:, 1]], axis=-1)
        R = jnp.concatenate([top, bot], axis=-2)
        res = _merge.merge_level(lam_pairs, z_inner, R, rho, sgn,
                                 niter=niter, chunk=chunk, use_zhat=use_zhat,
                                 root_mode=False)
        lam, Q = res.lam, res.rows
    return lam[0], Q[0]


def eig_tridiagonal_full_dc(d, e, *, leaf: int = 32, chunk: int = 128,
                            niter: int = DEFAULT_NITER, use_zhat: bool = True,
                            dtype=None):
    """Conventional full-eigenvector D&C.  Returns (eigenvalues, Q)."""
    d_adj, e_pad, n, N, L = _prepare(d, e, leaf, dtype)
    if L == 0:
        from repro.core.tridiag import dense_from_tridiag  # local import
        A = dense_from_tridiag(jnp.asarray(d), jnp.asarray(e))
        w, Q = jnp.linalg.eigh(A)
        return w, Q
    lam, Q = _full_dc_jit(d_adj, e_pad, leaf=leaf, chunk=chunk,
                          niter=niter, use_zhat=use_zhat)
    return lam[:n], Q[:n, :n]


def eigvalsh_tridiagonal_full_discard(d, e, **kw):
    """Values-only via conventional D&C: compute Q, discard (Table 4 stand-in
    for cuSOLVER Xstedc compz='N' -- full quadratic workspace, values out)."""
    lam, _ = eig_tridiagonal_full_dc(d, e, **kw)
    return lam


# ---------------------------------------------------------------------------
# Lazy-replay internal values-only D&C (paper's quadratic baseline)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("leaf", "chunk", "niter", "use_zhat"))
def _lazy_dc_jit(d_adj, e_pad, *, leaf, chunk, niter, use_zhat):
    """Values-only D&C that stores dense local transforms and replays them.

    Persistent per-level state: S_levels[l] has shape (B_l, K_l, K_l) --
    the dense local secular transform of every merge at level l (including
    deflation permutations/rotations), exactly the replayable state DLAEDA
    walks.  Boundary rows for a level-l merge are reconstructed by replaying
    the child-spine chains bottom-up: r <- r @ S (GEMV chain of cost
    c_rep*K^2, the term BR eliminates).
    """
    N = d_adj.shape[0]
    L = int(math.log2(N // leaf))
    B0 = N // leaf

    db = d_adj.reshape(B0, leaf)
    eb = e_pad[:N].reshape(B0, leaf)[:, : leaf - 1]
    ii = jnp.arange(leaf)
    T = jnp.zeros((B0, leaf, leaf), d_adj.dtype)
    T = T.at[:, ii, ii].set(db)
    jj = jnp.arange(leaf - 1)
    T = T.at[:, jj, jj + 1].set(eb).at[:, jj + 1, jj].set(eb)
    lam, Qleaf = jnp.linalg.eigh(T)

    # Leaf boundary rows (kept; they are O(n) and seed every replay chain).
    blo_leaf = Qleaf[:, 0, :]     # (B0, leaf)
    bhi_leaf = Qleaf[:, leaf - 1, :]

    S_levels = []   # S_levels[l]: (B_l, K_l, K_l) dense local transforms

    def replay_row(node, level, want_hi):
        """Reconstruct blo/bhi(Q_node) at `level` by replaying transforms.

        The first row of Q_node lives in its leftmost leaf; the last row in
        its rightmost leaf.  Walk the stored S chain from that leaf upward:
        r <- [r, 0...] @ S  (or [0..., r] @ S), growing 2x per level.
        """
        num_leaves = 1 << level
        leaf_idx = node * num_leaves + (num_leaves - 1 if want_hi else 0)
        r = bhi_leaf[leaf_idx] if want_hi else blo_leaf[leaf_idx]
        for l in range(level):
            Ksub = leaf * (1 << l)
            parent = leaf_idx >> (l + 1)
            zeros = jnp.zeros((Ksub,), r.dtype)
            if want_hi:
                r = jnp.concatenate([zeros, r])   # rightmost child is right
            else:
                r = jnp.concatenate([r, zeros])
            r = r @ S_levels[l][parent]
        return r

    for level in range(L):
        B = lam.shape[0] // 2
        M = lam.shape[1]
        rho, sgn = _level_coupling(e_pad[None, :], level, leaf, B)
        rho, sgn = rho[0], sgn[0]
        lam_pairs = lam.reshape(B, 2, M)

        # Reconstruct the needed boundary rows for every merge by replay.
        zL = jnp.stack([replay_row(2 * b, level, want_hi=True)
                        for b in range(B)])       # bhi(Q_L)
        zR = jnp.stack([replay_row(2 * b + 1, level, want_hi=False)
                        for b in range(B)])       # blo(Q_R)
        z_inner = jnp.stack([zL, zR], axis=1)

        # Push an identity through the merge to extract the dense local
        # transform S_v (this is what the lazy path must store).
        Ieye = jnp.broadcast_to(jnp.eye(2 * M, dtype=lam.dtype), (B, 2 * M, 2 * M))
        res = _merge.merge_level(lam_pairs, z_inner, Ieye, rho, sgn,
                                 niter=niter, chunk=chunk, use_zhat=use_zhat,
                                 root_mode=False)
        lam = res.lam
        S_levels.append(res.rows)   # (B, 2M, 2M) -- quadratic state

    return lam[0]


def eigvalsh_tridiagonal_lazy(d, e, *, leaf: int = 32, chunk: int = 128,
                              niter: int = DEFAULT_NITER, use_zhat: bool = True,
                              dtype=None):
    """Internal values-only D&C with lazy replay (quadratic workspace)."""
    d_adj, e_pad, n, N, L = _prepare(d, e, leaf, dtype)
    if L == 0:
        lam, _ = _leaf_solve(d_adj[None, :], e_pad[None, :], N)
        return lam[0, 0][:n]
    lam = _lazy_dc_jit(d_adj, e_pad, leaf=leaf, chunk=chunk,
                       niter=niter, use_zhat=use_zhat)
    return lam[:n]


# ---------------------------------------------------------------------------
# Sturm bisection full-spectrum reference (linear workspace, O(n^2) work)
# ---------------------------------------------------------------------------

def eigvalsh_tridiagonal_bisect(d, e, *, maxiter: int | None = None,
                                polish: int | None = None, dtype=None):
    """All eigenvalues via Sturm-count bisection (DSTEBZ-style reference).

    The full-spectrum degenerate case of the partial-spectrum front end
    (``repro.core.bisect``): every index bracketed by Gershgorin bounds
    and refined in one all-intervals-in-parallel bisection.  O(n + k)
    workspace like BR but O(n^2 log eps) work -- it exists as an
    algorithmically independent cross-check (no merge tree, no secular
    equation, no deflation), which is what makes it valuable to the
    conformance suite.
    """
    from repro.core.bisect import eigvalsh_tridiagonal_range
    d = jnp.asarray(d)
    n = d.shape[-1]
    kw = {}
    if maxiter is not None:
        kw["maxiter"] = maxiter
    if polish is not None:
        kw["polish"] = polish
    return eigvalsh_tridiagonal_range(d, e, select="i", il=0, iu=n - 1,
                                      dtype=dtype, **kw)


# ---------------------------------------------------------------------------
# Workspace models (paper Table 1 / Section 5.3 accounting)
# ---------------------------------------------------------------------------

def workspace_model_lazy(n: int, leaf: int = 32, itemsize: int = 8) -> dict:
    """sum over levels of B_l * K_l^2 = N * sum K_l ~ 2 N^2 floats."""
    from repro.core.br_dc import _tree_shape
    N, L = _tree_shape(n, leaf)
    total = 0
    for l in range(L):
        K = leaf * (1 << (l + 1))
        B = N // K
        total += B * K * K
    return {"persistent_bytes": total * itemsize,
            "model": f"sum B_l*K_l^2 = {total} floats (~2N^2), N={N}"}


def workspace_model_full(n: int, leaf: int = 32, itemsize: int = 8) -> dict:
    from repro.core.br_dc import _tree_shape
    N, _ = _tree_shape(n, leaf)
    return {"persistent_bytes": N * N * itemsize,
            "model": f"N^2 floats, N={N}"}


def workspace_model_sterf(n: int, itemsize: int = 8) -> dict:
    return {"persistent_bytes": 2 * n * itemsize, "model": "d,e arrays only"}


def workspace_model_bisect(n: int, k: int | None = None, batch: int = 1,
                           itemsize: int = 8) -> dict:
    """Spectrum slicing: d, e^2 inputs + 3k bracket/pivot lanes per problem.

    No merge tree and no selected rows -- the entire state of a k-slice
    solve is the input pair plus (lo, hi, mid) per requested root, so a
    top-32 slice of n = 4096 carries ~2n + 3k floats per problem.
    """
    k = n if k is None else k
    per_problem = 2 * n + 3 * k
    return {"persistent_bytes": batch * per_problem * itemsize,
            "model": f"B*(2n + 3k) floats, n={n}, k={k}, B={batch}"}
