"""Symmetric tridiagonal matrix utilities and the paper's test families.

The four spectral families follow the paper's Section 5.1 exactly:
  uniform:   d ~ U[-1, 1],   e ~ U[0.10, 0.30]
  normal:    d ~ N(0, 1),    e ~ U[0.10, 0.30]
  toeplitz:  d = 2,          e = 0.25
  clustered: d = 1 + 1e-12*(i - (n+1)/2),  e = 1e-4*(1 + 0.1*cos(0.33*i))

Fixed seeds keyed by (family, n) make every matrix exactly reproducible,
mirroring the paper's xorshift convention.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp


def dense_from_tridiag(d, e):
    """Materialize the dense symmetric matrix (test/oracle use only)."""
    d = jnp.asarray(d)
    e = jnp.asarray(e)
    n = d.shape[0]
    A = jnp.zeros((n, n), d.dtype)
    A = A.at[jnp.arange(n), jnp.arange(n)].set(d)
    if n > 1:
        i = jnp.arange(n - 1)
        A = A.at[i, i + 1].set(e).at[i + 1, i].set(e)
    return A


def gershgorin_bounds(d, e):
    """(lo, hi) enclosing all eigenvalues."""
    d = jnp.asarray(d)
    e = jnp.asarray(e)
    n = d.shape[0]
    if n == 1:
        return d[0], d[0]
    radius = jnp.zeros(n, d.dtype)
    radius = radius.at[:-1].add(jnp.abs(e)).at[1:].add(jnp.abs(e))
    return jnp.min(d - radius), jnp.max(d + radius)


def _seed_for(family: str, n: int) -> int:
    return (hash(family) ^ (n * 0x9E3779B9)) & 0x7FFFFFFF


def make_family(family: str, n: int, dtype=np.float64, seed: int | None = None):
    """Generate (d, e) for one of the paper's test families (numpy arrays)."""
    if seed is None:
        seed = _seed_for(family, n)
    rng = np.random.default_rng(seed)
    i = np.arange(1, n + 1, dtype=np.float64)
    if family == "uniform":
        d = rng.uniform(-1.0, 1.0, n)
        e = rng.uniform(0.10, 0.30, n - 1)
    elif family == "normal":
        d = rng.standard_normal(n)
        e = rng.uniform(0.10, 0.30, n - 1)
    elif family == "toeplitz":
        d = np.full(n, 2.0)
        e = np.full(n - 1, 0.25)
    elif family == "clustered":
        d = 1.0 + 1e-12 * (i - (n + 1) / 2.0)
        e = 1e-4 * (1.0 + 0.1 * np.cos(0.33 * i[:-1]))
    elif family == "wilkinson":
        # W_n^+ : classic near-degenerate stress matrix (extra coverage).
        m = (n - 1) / 2.0
        d = np.abs(i - 1 - m)
        e = np.ones(n - 1)
    elif family == "glued_wilkinson":
        # Copies of a small W^+ block glued with weak couplings (1e-4):
        # the canonical deflation-heavy D&C stress input -- nearly every
        # merge deflates almost everything (repeated eigenvalues across
        # blocks + tiny z entries).  Not in FAMILIES (it exercises the
        # deflation path, not general accuracy sweeps).
        blk = min(21, n)
        blk -= (blk % 2 == 0)           # odd Wilkinson block size
        ib = np.arange(1, blk + 1, dtype=np.float64)
        db = np.abs(ib - 1 - (blk - 1) / 2.0)
        d = np.tile(db, n // blk + 1)[:n]
        e = np.ones(n - 1)
        e[blk - 1::blk] = 1e-4          # glue strength
    else:
        raise ValueError(f"unknown family: {family}")
    return d.astype(dtype), e.astype(dtype)


def make_family_batch(family: str, n: int, batch: int, dtype=np.float64,
                      seed0: int = 0):
    """Stacked (B, n)/(B, n-1) batch of one family, seeds seed0..seed0+B-1.

    The input layout ``eigvalsh_tridiagonal_batch`` consumes; shared by
    benchmarks, examples and tests so the seeding convention lives in
    one place.
    """
    problems = [make_family(family, n, dtype=dtype, seed=seed0 + s)
                for s in range(batch)]
    return (np.stack([d for d, _ in problems]),
            np.stack([e for _, e in problems]))


FAMILIES = ("uniform", "normal", "toeplitz", "clustered", "wilkinson")
