"""Request/response core: every eigensolve is a routable SolveRequest.

The sync API (``repro.core.api``), the serving layer (``repro.serve``)
and SLQ all funnel through this module.  A request is normalized and
validated once, *routed* to the bucketed compile-cache key its launch
will use (a :class:`~repro.core.plan.PlanKey` or
:class:`~repro.core.plan.RangePlanKey` with the batch axis unresolved),
and executed by exactly one code path:

    SolveRequest -> route_request -> RoutedRequest -> execute_request

Routing is pure (no cache mutation, no device work except the two Sturm
counts a ``select="v"`` window needs) and total: requests that cannot
share a compiled executable -- the quadratic-state baselines, the n == 1
short circuits -- route to ``None`` and execute directly.  Everything
else carries the key the serving scheduler groups on: two requests with
equal route keys are guaranteed to coalesce into one device launch, and
:func:`execute_request` on a routed request is bit-for-bit the solve the
service performs for it (the property ``tests/test_serve.py`` pins).

Request kinds:

    full   -- one problem, all eigenvalues            -> (n,)
    batch  -- B stacked problems, all eigenvalues     -> (B, n)
    range  -- selected eigenvalues by index or value  -> (k,) / (B, k)
    slq    -- batch + boundary rows (the SLQ quadrature rule: nodes are
              the eigenvalues, weights are blo(Q)^2)  -> (B, n) + rows
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax.numpy as jnp
import numpy as np

KINDS = ("full", "batch", "range", "slq")

METHODS = ("br", "sterf", "lazy", "full", "eigh", "bisect")

# Methods whose solves route through a bucketed plan cache and can
# therefore coalesce; the rest exist to model quadratic-state baselines
# and execute one problem at a time.
_PLANNED_METHODS = ("br", "bisect")


@dataclasses.dataclass(frozen=True)
class SolveRequest:
    """One eigensolve, as data.  ``knobs`` holds the solver keywords of
    the matching sync entry point (leaf, chunk, niter, ... for "br";
    maxiter, polish for "bisect"/range; dtype for any).

    Distributed conquer rides the same knobs: "br" requests accept
    ``mesh`` (default "auto": huge-n problems shard over the visible
    devices, see ``plan.DIST_AUTO_MIN_N``) and ``compress_halo``.  The
    shard count lands in the route key, so the serving scheduler
    coalesces same-mesh traffic and never mixes mesh shapes in a flush.

    So does the mixed-precision pipeline: "br" requests accept
    ``precision`` ("native"/"mixed") and ``refine_tol``; both land in the
    route key, so mixed traffic coalesces with (only) other mixed traffic
    of the same tolerance and prewarms its own executables.  Mixed
    requests with no explicit dtype normalize to float64 (the output
    dtype) before routing.
    """
    d: Any
    e: Any
    kind: str = "full"
    method: str = "br"
    return_boundary: bool = False
    select: str = "i"
    il: int | None = None
    iu: int | None = None
    vl: float | None = None
    vu: float | None = None
    knobs: Mapping[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class SolveResult:
    """What comes back: eigenvalues in the kind's natural shape, plus
    boundary rows when the request asked for them."""
    eigenvalues: Any
    blo: Any = None
    bhi: Any = None
    kind: str = "full"
    method: str = "br"


@dataclasses.dataclass(frozen=True)
class RoutedRequest:
    """A validated request bound to its route.

    ``d``/``e`` are normalized to stacked (B, n)/(B, n-1) arrays of the
    solve dtype; ``route`` is the batch-unresolved PlanKey/RangePlanKey
    (None: direct execution, uncoalescable).  Range routes carry the
    resolved index window (select="v" is turned into indices here, so the
    scheduler never sees values).  ``empty`` marks a value window that
    contains no eigenvalues -- nothing to launch.
    """
    request: SolveRequest
    d: Any
    e: Any
    batch: int
    n: int
    route: Any
    il: int = 0
    k: int = 0
    empty: bool = False
    single: bool = False   # caller passed 1-D arrays: unwrap on the way out

    @property
    def return_boundary(self) -> bool:
        return bool(getattr(self.route, "return_boundary", False))


def _as_host(x):
    """asarray that never moves data: jax arrays stay on device (the sync
    path's inputs usually already live there), everything else becomes
    numpy -- so service submissions of host data cost no device round
    trip until their flush stages the coalesced batch."""
    import jax
    return x if isinstance(x, jax.Array) else np.asarray(x)


def _normalize(req: SolveRequest):
    """Validate kind/method and normalize d, e to stacked (B, n) arrays."""
    if req.kind not in KINDS:
        raise ValueError(f"unknown kind {req.kind!r}; choose from {KINDS}")
    if req.method not in METHODS:
        raise ValueError(
            f"unknown method {req.method!r}; choose from {METHODS}")
    d = _as_host(req.d)
    e = _as_host(req.e)
    dtype = req.knobs.get("dtype")
    if dtype is None and req.knobs.get("precision") == "mixed":
        dtype = np.float64   # mixed certifies / returns in f64
    if dtype is not None:
        d = d.astype(dtype)
        e = e.astype(dtype)
    if e.dtype != d.dtype:
        e = e.astype(d.dtype)
    single = d.ndim == 1
    if req.kind == "full" and not single:
        raise ValueError(
            f"kind='full' expects 1-D d, got shape {d.shape}")
    if req.kind in ("batch", "slq") and single:
        raise ValueError(
            f"kind={req.kind!r} expects stacked (B, n) d, got 1-D")
    if single:
        d = d[None, :]
        e = e[None, :] if e.ndim == 1 else e
    # Same contract (and message) as br_dc._as_batch, without forcing a
    # device transfer at submit time.
    if (d.ndim != 2 or e.ndim != 2 or e.shape[0] != d.shape[0]
            or e.shape[1] != max(d.shape[1] - 1, 0)):
        raise ValueError(
            f"batched solve expects d (B, n) and e (B, n-1); "
            f"got {d.shape} / {e.shape}")
    return d, e, single


def _solve_knobs(req: SolveRequest) -> dict:
    kw = {k: v for k, v in req.knobs.items() if k != "return_boundary"}
    return kw


def route_request(req: SolveRequest) -> RoutedRequest:
    """Resolve a request to its (batch-unresolved) compile-cache key.

    Pure with respect to the plan cache; raises on malformed requests --
    the serving scheduler turns that into a failed future without
    touching flushmates.
    """
    from repro.core import plan as _plan
    d, e, single = _normalize(req)
    B, n = d.shape
    kw = _solve_knobs(req)

    if req.method != "br" and (req.return_boundary or req.kind == "slq"):
        # Boundary rows are BR selected-row state; silently returning a
        # result without them would let a caller believe the flag took
        # effect (the old per-method signatures raised TypeError too).
        raise TypeError(
            "return_boundary (and kind='slq') require method='br'; "
            f"got method={req.method!r}")

    if req.kind == "range" or req.method == "bisect":
        range_kw = {k: v for k, v in kw.items()
                    if k in ("maxiter", "polish")}
        unknown = set(kw) - {"maxiter", "polish", "dtype"}
        if unknown:
            raise TypeError(
                f"{'range' if req.kind == 'range' else 'bisect'} requests "
                f"accept knobs (maxiter, polish, dtype); "
                f"got unexpected {sorted(unknown)}")
        if req.kind == "range":
            il, k, empty = _resolve_window(req, d, e, B, n, single)
        else:
            il, k, empty = 0, n, False   # full-spectrum bisect reference
        route = None
        if not empty:
            route = _plan.resolve_range_route(n, k, dtype=d.dtype,
                                              **range_kw)
        return RoutedRequest(request=req, d=d, e=e, batch=B, n=n,
                             route=route, il=il, k=k, empty=empty,
                             single=single)

    if req.method == "br" and n > 1:
        return_boundary = req.return_boundary or req.kind == "slq"
        if req.kind == "full":
            # Single (possibly padded) leaf trees return their boundary
            # rows for free -- mirror eigvalsh_tridiagonal_br's contract
            # that L == 0 always yields (blo, bhi).
            from repro.core.br_dc import _tree_shape
            leaf = kw.get("leaf", 32)
            return_boundary = return_boundary or _tree_shape(n, leaf)[1] == 0
        route = _plan.resolve_solve_route(
            n, return_boundary=return_boundary, dtype=d.dtype,
            **{k: v for k, v in kw.items() if k != "dtype"})
        return RoutedRequest(request=req, d=d, e=e, batch=B, n=n,
                             route=route, single=single)

    # Baselines (and the n == 1 short circuits): direct, uncoalescable.
    return RoutedRequest(request=req, d=d, e=e, batch=B, n=n, route=None,
                         single=single)


def _resolve_window(req: SolveRequest, d, e, B: int, n: int, single: bool):
    """Turn a range request's selection into an index window (il, k)."""
    from repro.core.bisect import _validate_index_range, sturm_count
    if req.select == "i":
        if req.il is None or req.iu is None:
            raise ValueError("select='i' requires il and iu")
        il, iu = _validate_index_range(n, req.il, req.iu)
        return il, iu - il + 1, False
    if req.select == "v":
        if req.vl is None or req.vu is None:
            raise ValueError("select='v' requires vl and vu")
        if not (float(req.vl) < float(req.vu)):
            raise ValueError(
                f"select='v' requires vl < vu; got ({req.vl}, {req.vu})")
        if not single:
            raise ValueError(
                "select='v' supports single problems only (the number of "
                "eigenvalues in (vl, vu] differs per problem); loop or "
                "use select='i'")
        # Two Sturm counts turn the value window into an index window
        # (one tiny host sync; the sliced solve then reuses the same
        # bucketed executables as any select='i' request).
        bounds = sturm_count(d[0], e[0],
                             jnp.asarray([req.vl, req.vu], d.dtype))
        c_lo, c_hi = int(bounds[0]), int(bounds[1])
        if c_hi <= c_lo:
            return 0, 0, True
        return c_lo, c_hi - c_lo, False
    raise ValueError(f"select must be 'i' or 'v', got {req.select!r}")


def _solve_direct_single(d, e, method: str, kw: dict):
    """One problem through the non-plan paths (moved from core.api)."""
    from repro.core import baselines as _bl
    from repro.core.br_dc import eigvalsh_tridiagonal_br
    from repro.core.sterf import eigvalsh_tridiagonal_sterf
    if method == "br":
        res = eigvalsh_tridiagonal_br(d, e, **kw)
        return res.eigenvalues, res.blo, res.bhi
    if method == "sterf":
        return eigvalsh_tridiagonal_sterf(d, e, **kw), None, None
    if method == "lazy":
        return _bl.eigvalsh_tridiagonal_lazy(d, e, **kw), None, None
    if method == "full":
        return _bl.eigvalsh_tridiagonal_full_discard(d, e, **kw), None, None
    if method == "eigh":
        from repro.core.tridiag import dense_from_tridiag
        return jnp.linalg.eigvalsh(dense_from_tridiag(d, e)), None, None
    raise ValueError(f"unknown method {method!r}; choose from {METHODS}")


def execute_request(req: SolveRequest | RoutedRequest) -> SolveResult:
    """Execute a (routed) request synchronously.

    This is the single launch path: the sync API wraps it, and the
    serving engine's flush is the same plan execution over a coalesced
    batch -- which is why service results are bit-for-bit the sync ones.
    """
    routed = route_request(req) if isinstance(req, SolveRequest) else req
    req = routed.request
    single = routed.single

    if routed.empty:
        lam = jnp.zeros((0,), routed.d.dtype)
        return SolveResult(eigenvalues=lam if single else lam[None, :],
                           kind=req.kind, method=req.method)

    from repro.core import plan as _plan
    if isinstance(routed.route, _plan.PlanKey):
        plan = _plan.plan_for_route(routed.route, routed.batch)
        res = plan.execute(routed.d, routed.e)
        if single:
            return SolveResult(
                eigenvalues=res.eigenvalues[0],
                blo=None if res.blo is None else res.blo[0],
                bhi=None if res.bhi is None else res.bhi[0],
                kind=req.kind, method=req.method)
        return SolveResult(eigenvalues=res.eigenvalues, blo=res.blo,
                           bhi=res.bhi, kind=req.kind, method=req.method)
    if isinstance(routed.route, _plan.RangePlanKey):
        plan = _plan.range_plan_for_route(routed.route, routed.batch)
        lam = plan.execute(routed.d, routed.e, routed.il, routed.k)
        return SolveResult(eigenvalues=lam[0] if single else lam,
                           kind=req.kind, method=req.method)

    # Direct path: baselines and n == 1 short circuits, one problem at a
    # time (these methods exist to model per-problem quadratic state).
    kw = _solve_knobs(req)
    if req.return_boundary and req.method == "br":
        kw["return_boundary"] = True
    outs = [_solve_direct_single(routed.d[b], routed.e[b], req.method, kw)
            for b in range(routed.batch)]
    lam = jnp.stack([o[0] for o in outs])
    blo = (jnp.stack([o[1] for o in outs])
           if outs and outs[0][1] is not None else None)
    bhi = (jnp.stack([o[2] for o in outs])
           if outs and outs[0][2] is not None else None)
    if single:
        lam = lam[0]
        blo = None if blo is None else blo[0]
        bhi = None if bhi is None else bhi[0]
    return SolveResult(eigenvalues=lam, blo=blo, bhi=bhi, kind=req.kind,
                       method=req.method)
