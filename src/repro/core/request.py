"""Request/response core: every eigensolve is a routable SolveRequest.

The sync API (``repro.core.api``), the serving layer (``repro.serve``)
and SLQ all funnel through this module.  A request is normalized and
validated once, *routed* to the bucketed compile-cache key its launch
will use (a :class:`~repro.core.plan.PlanKey` or
:class:`~repro.core.plan.RangePlanKey` with the batch axis unresolved),
and executed by exactly one code path:

    SolveRequest -> route_request -> RoutedRequest -> execute_request

Routing is pure (no cache mutation, no device work except the two Sturm
counts a ``select="v"`` window needs) and total: requests that cannot
share a compiled executable -- the quadratic-state baselines, the n == 1
short circuits -- route to ``None`` and execute directly.  Everything
else carries the key the serving scheduler groups on: two requests with
equal route keys are guaranteed to coalesce into one device launch, and
:func:`execute_request` on a routed request is bit-for-bit the solve the
service performs for it (the property ``tests/test_serve.py`` pins).

Request kinds:

    full   -- one problem, all eigenvalues            -> (n,)
    batch  -- B stacked problems, all eigenvalues     -> (B, n)
    range  -- selected eigenvalues by index or value  -> (k,) / (B, k)
    slq    -- batch + boundary rows (the SLQ quadrature rule: nodes are
              the eigenvalues, weights are blo(Q)^2)  -> (B, n) + rows
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax.numpy as jnp
import numpy as np

from repro.core import guard as _guard
from repro.runtime import faults as _faults

KINDS = ("full", "batch", "range", "slq")

METHODS = ("br", "sterf", "lazy", "full", "eigh", "bisect")

# Methods whose solves route through a bucketed plan cache and can
# therefore coalesce; the rest exist to model quadratic-state baselines
# and execute one problem at a time.
_PLANNED_METHODS = ("br", "bisect")


@dataclasses.dataclass(frozen=True)
class SolveRequest:
    """One eigensolve, as data.  ``knobs`` holds the solver keywords of
    the matching sync entry point (leaf, chunk, niter, ... for "br";
    maxiter, polish for "bisect"/range; dtype for any).

    Distributed conquer rides the same knobs: "br" requests accept
    ``mesh`` (default "auto": huge-n problems shard over the visible
    devices, see ``plan.DIST_AUTO_MIN_N``) and ``compress_halo``.  The
    shard count lands in the route key, so the serving scheduler
    coalesces same-mesh traffic and never mixes mesh shapes in a flush.

    So does the mixed-precision pipeline: "br" requests accept
    ``precision`` ("native"/"mixed") and ``refine_tol``; both land in the
    route key, so mixed traffic coalesces with (only) other mixed traffic
    of the same tolerance and prewarms its own executables.  Mixed
    requests with no explicit dtype normalize to float64 (the output
    dtype) before routing.

    Robustness knobs (first-class fields, not ``knobs`` entries, because
    they apply to EVERY method):

    ``certify=True`` asks for a Sturm-certified result: one extra batched
    count sweep (``bisect.certify_spectrum``) verifies every returned
    eigenvalue against the original (d, e) and any miss -- or non-finite
    output -- escalates down the graceful-degradation ladder
    (mixed -> native D&C -> per-lane Sturm bisection) before the result
    is returned; what happened is recorded in ``SolveResult.diagnostics``
    and the degradation gauge.  ``range``/``bisect`` solves are
    count-verified by construction and certify for free.

    ``deadline_ms`` (serve-only budget, measured from submission) fails
    the request's future with :class:`repro.core.guard.DeadlineExceeded`
    instead of letting it hold a flush slot past its usefulness; the sync
    path validates but does not enforce it (there is no queueing to
    outlive).
    """
    d: Any
    e: Any
    kind: str = "full"
    method: str = "br"
    return_boundary: bool = False
    select: str = "i"
    il: int | None = None
    iu: int | None = None
    vl: float | None = None
    vu: float | None = None
    certify: bool = False
    deadline_ms: float | None = None
    knobs: Mapping[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class SolveResult:
    """What comes back: eigenvalues in the kind's natural shape, plus
    boundary rows when the request asked for them.

    ``diagnostics`` is None on the steady-state path and a small dict
    when the robustness layer has something to report: ``certified`` /
    ``lanes`` (certificate tally for ``certify=True``), ``escalations``
    (tuple of ``{"from", "to", "lanes"}`` degradation-ladder records),
    and ``equilibration_scale`` (the exact power-of-two factor applied to
    a pathologically scaled input; eigenvalues are already inverse-scaled
    back).
    """
    eigenvalues: Any
    blo: Any = None
    bhi: Any = None
    kind: str = "full"
    method: str = "br"
    diagnostics: Any = None


@dataclasses.dataclass(frozen=True)
class RoutedRequest:
    """A validated request bound to its route.

    ``d``/``e`` are normalized to stacked (B, n)/(B, n-1) arrays of the
    solve dtype; ``route`` is the batch-unresolved PlanKey/RangePlanKey
    (None: direct execution, uncoalescable).  Range routes carry the
    resolved index window (select="v" is turned into indices here, so the
    scheduler never sees values).  ``empty`` marks a value window that
    contains no eigenvalues -- nothing to launch.
    """
    request: SolveRequest
    d: Any
    e: Any
    batch: int
    n: int
    route: Any
    il: int = 0
    k: int = 0
    empty: bool = False
    single: bool = False   # caller passed 1-D arrays: unwrap on the way out
    # Equilibration factor applied to (d, e) at normalization time (an
    # exact power of two; 1.0 for almost all traffic).  The solve runs in
    # scaled space and the finalizer multiplies eigenvalues by 1/scale on
    # the way out -- both exact, so scaled solves lose no accuracy.
    # Deliberately NOT part of the route key: scale is per-problem data,
    # and differently-scaled requests still coalesce into one flush.
    scale: float = 1.0

    @property
    def return_boundary(self) -> bool:
        return bool(getattr(self.route, "return_boundary", False))


def _as_host(x):
    """asarray that never moves data: jax arrays stay on device (the sync
    path's inputs usually already live there), everything else becomes
    numpy -- so service submissions of host data cost no device round
    trip until their flush stages the coalesced batch."""
    import jax
    return x if isinstance(x, jax.Array) else np.asarray(x)


def _normalize(req: SolveRequest):
    """Validate kind/method/input and normalize d, e to stacked (B, n)
    arrays; the guarded front door.

    All structural problems -- bad shapes, non-float dtypes, NaN/Inf
    entries, a nonsensical deadline -- raise HERE, host-side at route
    time, as ValueError subclasses (:class:`guard.InvalidInputError` for
    input poison), so the serving scheduler fails a malformed request's
    own future before it can join (and poison) a coalesced flush.
    Pathologically scaled inputs are equilibrated by an exact power of
    two (returned as ``scale``; 1.0 -- with the input arrays untouched --
    for in-range traffic).
    """
    if req.kind not in KINDS:
        raise ValueError(f"unknown kind {req.kind!r}; choose from {KINDS}")
    if req.method not in METHODS:
        raise ValueError(
            f"unknown method {req.method!r}; choose from {METHODS}")
    if req.deadline_ms is not None:
        deadline = float(req.deadline_ms)
        if not (deadline > 0.0) or not np.isfinite(deadline):
            raise _guard.InvalidInputError(
                f"deadline_ms must be a positive finite budget, got "
                f"{req.deadline_ms!r}", field="deadline_ms")
    d = _as_host(req.d)
    e = _as_host(req.e)
    dtype = req.knobs.get("dtype")
    if dtype is None and req.knobs.get("precision") == "mixed":
        dtype = np.float64   # mixed certifies / returns in f64
    if dtype is not None:
        d = d.astype(dtype)
        e = e.astype(dtype)
    if e.dtype != d.dtype:
        e = e.astype(d.dtype)
    single = d.ndim == 1
    if req.kind == "full" and not single:
        raise ValueError(
            f"kind='full' expects 1-D d, got shape {d.shape}")
    if req.kind in ("batch", "slq") and single:
        raise ValueError(
            f"kind={req.kind!r} expects stacked (B, n) d, got 1-D")
    if single:
        d = d[None, :]
        e = e[None, :] if e.ndim == 1 else e
    # Same contract (and message) as br_dc._as_batch, without forcing a
    # device transfer at submit time.
    if (d.ndim != 2 or e.ndim != 2 or e.shape[0] != d.shape[0]
            or e.shape[1] != max(d.shape[1] - 1, 0)):
        raise ValueError(
            f"batched solve expects d (B, n) and e (B, n-1); "
            f"got {d.shape} / {e.shape}")
    _guard.validate_problem(d, e, name="request")
    d, e, scale = _guard.equilibrate(d, e)
    return d, e, single, scale


def _solve_knobs(req: SolveRequest) -> dict:
    kw = {k: v for k, v in req.knobs.items() if k != "return_boundary"}
    return kw


def route_request(req: SolveRequest) -> RoutedRequest:
    """Resolve a request to its (batch-unresolved) compile-cache key.

    Pure with respect to the plan cache; raises on malformed requests --
    the serving scheduler turns that into a failed future without
    touching flushmates.
    """
    from repro.core import plan as _plan
    d, e, single, scale = _normalize(req)
    B, n = d.shape
    kw = _solve_knobs(req)

    if req.method != "br" and (req.return_boundary or req.kind == "slq"):
        # Boundary rows are BR selected-row state; silently returning a
        # result without them would let a caller believe the flag took
        # effect (the old per-method signatures raised TypeError too).
        raise TypeError(
            "return_boundary (and kind='slq') require method='br'; "
            f"got method={req.method!r}")

    if req.kind == "range" or req.method == "bisect":
        range_kw = {k: v for k, v in kw.items()
                    if k in ("maxiter", "polish")}
        unknown = set(kw) - {"maxiter", "polish", "dtype"}
        if unknown:
            raise TypeError(
                f"{'range' if req.kind == 'range' else 'bisect'} requests "
                f"accept knobs (maxiter, polish, dtype); "
                f"got unexpected {sorted(unknown)}")
        if req.kind == "range":
            il, k, empty = _resolve_window(req, d, e, B, n, single, scale)
        else:
            il, k, empty = 0, n, False   # full-spectrum bisect reference
        route = None
        if not empty:
            route = _plan.resolve_range_route(n, k, dtype=d.dtype,
                                              **range_kw)
        return RoutedRequest(request=req, d=d, e=e, batch=B, n=n,
                             route=route, il=il, k=k, empty=empty,
                             single=single, scale=scale)

    if req.method == "br" and n > 1:
        return_boundary = req.return_boundary or req.kind == "slq"
        if req.kind == "full":
            # Single (possibly padded) leaf trees return their boundary
            # rows for free -- mirror eigvalsh_tridiagonal_br's contract
            # that L == 0 always yields (blo, bhi).
            from repro.core.br_dc import _tree_shape
            leaf = kw.get("leaf", 32)
            return_boundary = return_boundary or _tree_shape(n, leaf)[1] == 0
        route = _plan.resolve_solve_route(
            n, return_boundary=return_boundary, dtype=d.dtype,
            certify=req.certify,
            **{k: v for k, v in kw.items() if k != "dtype"})
        return RoutedRequest(request=req, d=d, e=e, batch=B, n=n,
                             route=route, single=single, scale=scale)

    # Baselines (and the n == 1 short circuits): direct, uncoalescable.
    return RoutedRequest(request=req, d=d, e=e, batch=B, n=n, route=None,
                         single=single, scale=scale)


def _resolve_window(req: SolveRequest, d, e, B: int, n: int, single: bool,
                    scale: float = 1.0):
    """Turn a range request's selection into an index window (il, k)."""
    from repro.core.bisect import _validate_index_range, sturm_count
    if req.select == "i":
        if req.il is None or req.iu is None:
            raise ValueError("select='i' requires il and iu")
        il, iu = _validate_index_range(n, req.il, req.iu)
        return il, iu - il + 1, False
    if req.select == "v":
        if req.vl is None or req.vu is None:
            raise ValueError("select='v' requires vl and vu")
        if not (float(req.vl) < float(req.vu)):
            raise ValueError(
                f"select='v' requires vl < vu; got ({req.vl}, {req.vu})")
        if not single:
            raise ValueError(
                "select='v' supports single problems only (the number of "
                "eigenvalues in (vl, vu] differs per problem); loop or "
                "use select='i'")
        # Two Sturm counts turn the value window into an index window
        # (one tiny host sync; the sliced solve then reuses the same
        # bucketed executables as any select='i' request).  (d, e) are
        # already equilibrated, so the window endpoints scale by the same
        # exact power of two: count(scale*v; scaled T) == count(v; T).
        shifts = jnp.asarray([req.vl, req.vu], d.dtype)
        if scale != 1.0:
            shifts = shifts * jnp.asarray(scale, d.dtype)
        bounds = sturm_count(d[0], e[0], shifts)
        c_lo, c_hi = int(bounds[0]), int(bounds[1])
        if c_hi <= c_lo:
            return 0, 0, True
        return c_lo, c_hi - c_lo, False
    raise ValueError(f"select must be 'i' or 'v', got {req.select!r}")


def _native_knobs(req: SolveRequest) -> dict:
    """Solver knobs for a degradation-ladder native re-solve: strip the
    knobs that name the stage being escalated AWAY from (precision /
    refine_tol) or that a single-problem recovery solve must not inherit
    (mesh topology, halo compression -- the re-solve is the ladder's
    independent second opinion, so it runs the classic single-device
    path)."""
    drop = ("precision", "refine_tol", "mesh", "compress_halo",
            "return_boundary")
    kw = {k: v for k, v in req.knobs.items() if k not in drop}
    kw["mesh"] = None
    return kw


def _bisect_lanes(routed: RoutedRequest, lam_h: np.ndarray,
                  mask: np.ndarray) -> None:
    """Final ladder rung: re-solve the masked eigenvalue lanes by Sturm
    bisection against the (scaled) inputs, scattering into ``lam_h``.

    Bisection brackets every target with exact integer counts, so its
    results are certified by construction -- and it runs eagerly through
    ``bisect._slice_targets`` without touching the plan cache or the
    fault-instrumented launch path, which is what guarantees the ladder
    terminates even under a persistent launch-fault schedule.
    """
    from repro.core import bisect as _bis
    for b in np.nonzero(mask.any(axis=1))[0]:
        idx = np.nonzero(mask[b])[0].astype(np.int32)
        d_b = jnp.asarray(routed.d[int(b)])[None, :]
        e_b = jnp.asarray(routed.e[int(b)])[None, :]
        vals = _bis._slice_targets(d_b, e_b, jnp.asarray(idx[None, :]))
        lam_h[b, idx] = np.asarray(vals)[0]


def _resolve_native_rows(routed: RoutedRequest, prob: np.ndarray,
                         lam_h, blo_h, bhi_h) -> np.ndarray:
    """Ladder rung: full native re-solve of the masked problems (the only
    rung that can regenerate boundary rows).  Returns the mask of
    problems that were successfully re-solved; failures (e.g. a
    persistent injected launch fault) are left for the next rung."""
    kw = _native_knobs(routed.request)
    if blo_h is not None:
        kw["return_boundary"] = True
    done = np.zeros_like(prob)
    for b in np.nonzero(prob)[0]:
        try:
            lamb, lob, hib = _solve_direct_single(
                routed.d[int(b)], routed.e[int(b)], "br", kw)
            lam_h[b] = np.asarray(lamb)
            if blo_h is not None and lob is not None:
                blo_h[b] = np.asarray(lob)
                bhi_h[b] = np.asarray(hib)
            done[b] = True
        except Exception:
            continue
    return done


def _finalize_lanes(routed: RoutedRequest, lam, blo, bhi, *,
                    cert=None, check_finite: bool = True):
    """The graceful-degradation ladder + inverse equilibration.

    Shared by the sync ``execute_request`` and the serve engine's demux,
    so both paths escalate identically (and deterministically) -- a
    request gets the same answer whether it ran alone or in a flush.

    lam/blo/bhi are the solve's stacked (B, n) outputs in SCALED space;
    ``cert`` is an optional host (B, n) certificate mask from
    ``certify_spectrum``.  Ladder, applied per-lane where possible:

      1. non-finite outputs: full native re-solve of the affected
         problems when the stage was mixed (escalate precision) or when
         boundary rows are owed (bisection cannot produce rows);
      2. lanes still bad, and any certificate misses: per-lane Sturm
         bisection -- certified by construction, never launches through
         the fault-instrumented plan path;
      3. still bad (rows owed but unrecoverable): CertificationError.

    Every escalation is recorded in the SOLVE_COUNTER degradation gauge
    and the process-wide ``guard.DEGRADATIONS`` counter, and reported in
    the returned diagnostics.  Returns (lam, blo, bhi, diagnostics).
    """
    from repro.core import br_dc as _br
    req = routed.request
    mixed = getattr(routed.route, "precision", "native") == "mixed"
    planned = routed.route is not None
    stage = "mixed" if mixed else ("native" if planned else req.method)
    rows = blo is not None
    escalations: list = []
    cert_h = None if cert is None else np.asarray(cert).copy()
    first_sweep_certified = (None if cert_h is None
                             else int(cert_h.sum()))

    def record(frm: str, to: str, lanes: int) -> None:
        _br.SOLVE_COUNTER.record_degradation(frm, to, lanes)
        _guard.DEGRADATIONS.increment()
        escalations.append({"from": frm, "to": to, "lanes": int(lanes)})

    if check_finite:
        lam_h = np.asarray(lam)
        bad = ~np.isfinite(lam_h)
        if rows:
            bad |= ~np.isfinite(np.asarray(blo)).all(axis=1, keepdims=True)
            bad |= ~np.isfinite(np.asarray(bhi)).all(axis=1, keepdims=True)
        if bad.any():
            lam_h = lam_h.copy()
            blo_h = np.asarray(blo).copy() if rows else None
            bhi_h = np.asarray(bhi).copy() if rows else None
            at = stage
            if mixed or rows:
                done = _resolve_native_rows(routed, bad.any(axis=1),
                                            lam_h, blo_h, bhi_h)
                if done.any():
                    record(stage, "native", int(bad[done].sum()))
                    at = "native"
                bad = ~np.isfinite(lam_h)
                if rows:
                    bad |= ~np.isfinite(blo_h).all(axis=1, keepdims=True)
                    bad |= ~np.isfinite(bhi_h).all(axis=1, keepdims=True)
            if bad.any():
                if rows:
                    raise _guard.CertificationError(
                        f"degradation ladder exhausted: {int(bad.sum())} "
                        f"non-finite output lanes remain and the request "
                        f"owes boundary rows, which bisection cannot "
                        f"produce")
                record(at, "bisect", int(bad.sum()))
                _bisect_lanes(routed, lam_h, bad)
                if cert_h is not None:
                    cert_h[bad] = True   # count-verified by construction
                still = ~np.isfinite(lam_h)
                if still.any():
                    raise _guard.CertificationError(
                        f"degradation ladder exhausted: {int(still.sum())} "
                        f"lanes non-finite even after Sturm bisection")
            # Re-certify lanes repaired by a native re-solve (bisected
            # lanes are already accounted above).
            if cert_h is not None and not cert_h.all():
                from repro.core import bisect as _bis
                unchecked = (~cert_h).any(axis=1)
                for b in np.nonzero(unchecked)[0]:
                    c = _bis.certify_spectrum(
                        routed.d[int(b)], routed.e[int(b)], lam_h[b],
                        tol=getattr(routed.route, "refine_tol", 0.0)
                        or None or _bis.DEFAULT_REFINE_TOL)
                    cert_h[b] = np.asarray(c.certified)
            lam, blo, bhi = lam_h, blo_h, bhi_h

    if cert_h is not None and not cert_h.all():
        miss = ~cert_h
        lam_h = np.asarray(lam).copy()
        record(stage, "bisect", int(miss.sum()))
        _bisect_lanes(routed, lam_h, miss)
        lam = lam_h

    if routed.scale != 1.0:
        # Exact inverse of the equilibration factor (a power of two), so
        # the multiply introduces no rounding.
        inv = np.asarray(lam).dtype.type(1.0 / routed.scale)
        lam = lam * inv

    diag = None
    if escalations or cert_h is not None or routed.scale != 1.0:
        diag = {}
        if cert_h is not None:
            diag["certified"] = first_sweep_certified
            diag["lanes"] = int(np.asarray(cert_h).size)
        if escalations:
            diag["escalations"] = tuple(escalations)
        if routed.scale != 1.0:
            diag["equilibration_scale"] = routed.scale
    return lam, blo, bhi, diag


def _solve_direct_single(d, e, method: str, kw: dict):
    """One problem through the non-plan paths (moved from core.api)."""
    from repro.core import baselines as _bl
    from repro.core.br_dc import eigvalsh_tridiagonal_br
    from repro.core.sterf import eigvalsh_tridiagonal_sterf
    if method == "br":
        res = eigvalsh_tridiagonal_br(d, e, **kw)
        return res.eigenvalues, res.blo, res.bhi
    if method == "sterf":
        return eigvalsh_tridiagonal_sterf(d, e, **kw), None, None
    if method == "lazy":
        return _bl.eigvalsh_tridiagonal_lazy(d, e, **kw), None, None
    if method == "full":
        return _bl.eigvalsh_tridiagonal_full_discard(d, e, **kw), None, None
    if method == "eigh":
        from repro.core.tridiag import dense_from_tridiag
        return jnp.linalg.eigvalsh(dense_from_tridiag(d, e)), None, None
    raise ValueError(f"unknown method {method!r}; choose from {METHODS}")


def execute_request(req: SolveRequest | RoutedRequest) -> SolveResult:
    """Execute a (routed) request synchronously.

    This is the single launch path: the sync API wraps it, and the
    serving engine's flush is the same plan execution over a coalesced
    batch -- which is why service results are bit-for-bit the sync ones.
    """
    routed = route_request(req) if isinstance(req, SolveRequest) else req
    req = routed.request
    single = routed.single

    if routed.empty:
        lam = jnp.zeros((0,), routed.d.dtype)
        return SolveResult(eigenvalues=lam if single else lam[None, :],
                           kind=req.kind, method=req.method)

    from repro.core import plan as _plan
    if isinstance(routed.route, _plan.PlanKey):
        plan = _plan.plan_for_route(routed.route, routed.batch)
        res = plan.execute(routed.d, routed.e)
        cert = None
        if routed.route.certify:
            from repro.core import bisect as _bis
            cert = _bis.certify_spectrum(
                routed.d, routed.e, res.eigenvalues,
                tol=routed.route.refine_tol).certified
        # Output finiteness is only checked when something already forces
        # a host round trip (certification, the mixed pipeline's host-
        # driven refinement) or when the chaos harness is live: the
        # steady-state native path keeps its async dispatch, and the
        # front-door guard already rejected input poison, so a non-finite
        # native output means a device fault -- which certify=True exists
        # to catch.
        check = (routed.route.certify or _faults.faults_enabled()
                 or routed.route.precision == "mixed")
        lam, blo, bhi, diag = _finalize_lanes(
            routed, res.eigenvalues, res.blo, res.bhi, cert=cert,
            check_finite=check)
        if single:
            return SolveResult(
                eigenvalues=lam[0],
                blo=None if blo is None else blo[0],
                bhi=None if bhi is None else bhi[0],
                kind=req.kind, method=req.method, diagnostics=diag)
        return SolveResult(eigenvalues=lam, blo=blo, bhi=bhi,
                           kind=req.kind, method=req.method,
                           diagnostics=diag)
    if isinstance(routed.route, _plan.RangePlanKey):
        plan = _plan.range_plan_for_route(routed.route, routed.batch)
        lam = plan.execute(routed.d, routed.e, routed.il, routed.k)
        diag = None
        if routed.scale != 1.0:
            inv = np.dtype(routed.d.dtype).type(1.0 / routed.scale)
            lam = lam * inv
            diag = {"equilibration_scale": routed.scale}
        if req.certify:
            # Sturm bisection IS a certificate: every returned value is
            # enclosed by exact integer counts, so the sweep would be
            # redundant work -- report the tally without launching it.
            diag = dict(diag or ())
            diag.update(certified=int(routed.batch * routed.k),
                        lanes=int(routed.batch * routed.k))
        return SolveResult(eigenvalues=lam[0] if single else lam,
                           kind=req.kind, method=req.method,
                           diagnostics=diag)

    # Direct path: baselines and n == 1 short circuits, one problem at a
    # time (these methods exist to model per-problem quadratic state).
    kw = _solve_knobs(req)
    if req.return_boundary and req.method == "br":
        kw["return_boundary"] = True
    outs = [_solve_direct_single(routed.d[b], routed.e[b], req.method, kw)
            for b in range(routed.batch)]
    lam = jnp.stack([o[0] for o in outs])
    blo = (jnp.stack([o[1] for o in outs])
           if outs and outs[0][1] is not None else None)
    bhi = (jnp.stack([o[2] for o in outs])
           if outs and outs[0][2] is not None else None)
    diag = None
    if req.certify or routed.scale != 1.0 or _faults.faults_enabled():
        cert = None
        if req.certify:
            from repro.core import bisect as _bis
            cert = _bis.certify_spectrum(routed.d, routed.e, lam).certified
        lam, blo, bhi, diag = _finalize_lanes(routed, lam, blo, bhi,
                                              cert=cert)
    if single:
        lam = lam[0]
        blo = None if blo is None else blo[0]
        bhi = None if bhi is None else bhi[0]
    return SolveResult(eigenvalues=lam, blo=blo, bhi=bhi, kind=req.kind,
                       method=req.method, diagnostics=diag)
