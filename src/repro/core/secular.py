"""Secular equation solver for diagonal-plus-rank-one eigenproblems.

Solves for the roots of

    g(lam) = 1 + rho * sum_i z2_i / (d_i - lam) = 0

where ``d`` holds ``kprime`` *active* poles sorted ascending in its prefix
(entries at index >= kprime are deflated/padding and carry ``z2 == 0``).

Roots interlace the active poles:  d_0 < lam_0 < d_1 < ... < lam_{K'-1} <
d_{K'-1} + rho * sum(z2).  Every root is represented in the paper's compact
delta form ``lam_j = d[origin_j] + tau_j`` (Section 4.1 of the paper:
"origin pole + offset tau") so that denominators ``delta_i = (d_i -
d_origin) - tau`` never suffer catastrophic cancellation near the pole.

The iteration is a safeguarded fixed-weight (two-pole rational
interpolation) scheme in the spirit of LAPACK's DLAED4, with a bisection
bracket that guarantees convergence within the fixed iteration budget
(bisection alone contracts the bracket by 2^-niter; the rational step is
superlinear once close).  A fixed budget keeps the whole solver jit- and
vmap-compatible (no per-root early exit), which is the TPU/XLA adaptation
of the paper's per-root CUDA loops.

Memory: all evaluations are chunked over roots -- peak temporary is
O(chunk * K), never O(K^2).  This is the JAX realization of the paper's
"stream each secular vector column" contract.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# The ONE secular iteration budget, shared by every entry point (core
# solvers, kernel dispatchers, merge_level, the plan cache key).  The
# safeguarded middle-way step is quadratically convergent once bracketed
# and each iteration also halves the bisection bracket, so 16 iterations
# deliver <= 2^-16 of the gap width even in the pure-bisection worst case
# and ~machine-precision residuals in practice (the accuracy benchmarks
# hold at 8*eps*||T|| across all paper families).  Raising it buys
# nothing measurable at float64; lowering below ~12 starts to show on
# clustered spectra.  Historically ``secular_solve`` defaulted to 40
# while the merge tree passed 16 -- one knob now, one value.
#
# A 16-step budget is only sufficient together with the pole-hugging
# initial guess in ``_solve_chunk``: roots whose origin weight is tiny
# but above the deflation threshold (Wilkinson-type spectra produce
# them at padded sizes) otherwise enter a geometric "double tau each
# step" crawl that needs ~30 iterations -- the reason LAPACK's DLAED4
# carries MAXIT = 30.  The model guess starts such roots on their own
# magnitude, restoring quadratic convergence inside the budget (found
# by the cross-method conformance sweep; pinned by its n = 17..25
# Wilkinson points).
DEFAULT_NITER = 16

# The f32-aware budget for single-precision trees (the mixed-precision
# pipeline and explicit dtype=float32 solves).  The safeguarded iteration
# hits the f32 accuracy floor (~eps_f32 * ||T|| residuals) by ~8-10
# steps: measured across the conformance families at n = 4096, the tree's
# max error against the f64 solve is IDENTICAL at niter in {8, 10, 16}
# (the floor, not the budget, binds), while each extra iteration still
# pays a full streamed secular sweep.  10 keeps two safety steps over the
# observed floor; the f64 rationale above (and its Wilkinson crawl
# guard) does not shrink, so DEFAULT_NITER stays 16 for f64 routes.
DEFAULT_NITER_F32 = 10


def _pad_len(k: int, chunk: int) -> int:
    return ((k + chunk - 1) // chunk) * chunk


def _eval_g(tau, d_shift, z2, rho, active_mask):
    """g(tau), g'(tau) split by pole side, for a batch of roots.

    tau: (C,); d_shift: (C, K) = d_i - d_origin; z2: (K,); rho scalar.
    Returns (g, w_lo, w_hi) where w_lo/w_hi are the derivative parts from
    poles at/below vs above the gap's left pole (the 'middle way' split).
    """
    delta = d_shift - tau[:, None]  # (C, K)
    # Guard exact pole hits (only possible for inactive/zero-weight terms:
    # tau stays strictly inside the pole-free bracket for active terms).
    safe = jnp.where(active_mask & (delta != 0.0), delta, 1.0)
    w = jnp.where(active_mask, z2[None, :], 0.0)
    g = 1.0 + rho * jnp.sum(w / safe, axis=-1)
    dterms = w / (safe * safe)
    return g, dterms


def _solve_chunk(jc, d, z2, rho, kprime, niter):
    """Solve a chunk of secular roots (safeguarded DLAED4 'middle way').

    jc: (C,) int32 root indices (may exceed K-1 for tail padding).
    d:  (K,) poles, active prefix sorted ascending.
    z2: (K,) squared weights (zero at deflated/padded entries).
    Returns (origin (C,) int32, tau (C,)).
    """
    K = d.shape[0]
    dtype = d.dtype
    jc_safe = jnp.minimum(jc, K - 1)
    active_root = jc < kprime
    is_last = jc == (kprime - 1)

    sum_z2 = jnp.sum(z2)
    span = rho * sum_z2  # upper bound on lam_max - d_max

    d_j = d[jc_safe]
    jnext = jnp.minimum(jc_safe + 1, K - 1)
    d_next_pole = d[jnext]
    # Right end of the gap: next active pole, or d_j + span for the last root.
    gap_hi = jnp.where(is_last, d_j + span, d_next_pole)
    mid_lam = 0.5 * (d_j + gap_hi)

    active_mask = (jnp.arange(K) < kprime)[None, :]

    # f(mid) decides which gap endpoint becomes the origin pole and gives
    # the first bracket halving for free.
    delta_mid = d[None, :] - mid_lam[:, None]
    safe = jnp.where(active_mask & (delta_mid != 0.0), delta_mid, 1.0)
    w = jnp.where(active_mask, z2[None, :], 0.0)
    f_mid = 1.0 + rho * jnp.sum(w / safe, axis=-1)

    use_left = (f_mid > 0.0) | is_last
    origin = jnp.where(use_left, jc_safe, jnext).astype(jnp.int32)
    d_org = d[origin]
    tau_mid = mid_lam - d_org

    # Bracket in tau (relative to the origin pole), refined by f(mid).
    lo = jnp.where(use_left,
                   jnp.zeros_like(tau_mid),
                   tau_mid)
    hi = jnp.where(use_left,
                   jnp.where(is_last & (f_mid <= 0.0), span, tau_mid),
                   jnp.zeros_like(tau_mid))
    lo = jnp.where(is_last & (f_mid <= 0.0), tau_mid, lo)

    # Near poles: gap endpoints for interior roots; for the last root the
    # origin pole and its lower neighbour (LAPACK DLAED4's I=N branch).
    n_lo = jnp.where(is_last, jnp.maximum(jc_safe - 1, 0), jc_safe)
    n_hi = jnp.where(is_last, jc_safe, jnext)
    p_lo = d[n_lo] - d_org
    p_hi = d[n_hi] - d_org
    # Derivative side split: poles with index <= n_lo attach to p_lo.
    side_lo = (jnp.arange(K)[None, :] <= n_lo[:, None]) & active_mask

    d_shift = d[None, :] - d_org[:, None]  # (C, K)

    # ---- pole-hugging guess (origin-dominant 3-term model) --------------
    # Write g(tau) = r(tau) - c / tau with c = rho * z2_org and
    # r(tau) = 1 + rho * sum_{i != org} z2_i / (d_i - d_org - tau), and
    # linearize r at the origin pole: r0 + r0' tau - c / tau = 0, i.e.
    #
    #     tau_m = (-r0 +- sqrt(r0^2 + 4 r0' c)) / (2 r0')
    #
    # (sign by which side of the pole the root lies).  This matters
    # exactly when the origin weight is tiny-but-not-deflated (z2_org ~
    # eps^2): the root then hugs its pole at |tau*| ~ sqrt(c / r0') --
    # many orders of magnitude inside the gap -- and the value-matched
    # quadratic guess below can undershoot it by decades, after which
    # the safeguarded rational steps merely double tau per iteration
    # (the near-double-root crawl that forces LAPACK's DLAED4 to carry
    # MAXIT = 30).  tau_m is immune to that failure: the discriminant
    # rides on 4 r0' c, which cancellation noise in r0 (absolute error
    # ~ eps * sum|terms|) cannot corrupt.  The guess is only *preferred*
    # when it lands farther from the pole than the quadratic guess and
    # still inside the safeguard bracket, so well-conditioned roots keep
    # their value-matched guess and identical iteration behavior.
    mask_rest = (active_mask
                 & (jnp.arange(K)[None, :] != origin[:, None])
                 & (d_shift != 0.0))
    dsafe = jnp.where(mask_rest, d_shift, 1.0)
    terms0 = jnp.where(mask_rest, z2[None, :] / dsafe, 0.0)
    r0 = 1.0 + rho * jnp.sum(terms0, axis=-1)
    rp0 = rho * jnp.sum(terms0 / dsafe, axis=-1)
    c_org = rho * z2[jnp.minimum(origin, K - 1)]
    sq_h = jnp.sqrt(jnp.maximum(r0 * r0 + 4.0 * rp0 * c_org, 0.0))
    tau_m = jnp.where(use_left, -r0 + sq_h, -(r0 + sq_h)) \
        / jnp.where(rp0 > 0.0, 2.0 * rp0, 1.0)
    valid_m = (rp0 > 0.0) & jnp.isfinite(tau_m)

    # ---- initial guess: value-matching 2-pole quadratic at tau_mid ------
    A_lo = rho * z2[n_lo]
    A_hi = rho * z2[n_hi]
    c0 = f_mid - A_lo / (p_lo - tau_mid) - A_hi / (p_hi - tau_mid)
    qb = -(c0 * (p_lo + p_hi) + A_lo + A_hi)
    qc = c0 * p_lo * p_hi + A_lo * p_hi + A_hi * p_lo
    disc0 = jnp.maximum(qb * qb - 4.0 * c0 * qc, 0.0)
    sq0 = jnp.sqrt(disc0)
    qq0 = -0.5 * (qb + jnp.where(qb >= 0.0, 1.0, -1.0) * sq0)
    g1 = qq0 / jnp.where(c0 == 0.0, 1.0, c0)
    g2 = qc / jnp.where(qq0 == 0.0, 1.0, qq0)
    g1 = jnp.where(c0 != 0.0, g1, jnp.inf)
    g2 = jnp.where(qq0 != 0.0, g2, jnp.inf)
    in1 = jnp.isfinite(g1) & (g1 > lo) & (g1 < hi)
    in2 = jnp.isfinite(g2) & (g2 > lo) & (g2 < hi)
    tau0 = jnp.where(in1, g1, jnp.where(in2, g2, 0.5 * (lo + hi)))
    use_m = (valid_m & (tau_m > lo) & (tau_m < hi)
             & (jnp.abs(tau_m) > jnp.abs(tau0)))
    tau0 = jnp.where(use_m, tau_m, tau0)

    # ---- safeguarded middle-way iteration (DLAED4) -----------------------
    def body(_, state):
        tau, lo, hi, best_tau, best_g = state
        g, dterms = _eval_g(tau, d_shift, z2, rho, active_mask)
        w_lo = rho * jnp.sum(jnp.where(side_lo, dterms, 0.0), axis=-1)
        w_hi = rho * jnp.sum(jnp.where(~side_lo, dterms, 0.0), axis=-1)
        gp = w_lo + w_hi

        better = jnp.abs(g) < best_g
        best_tau = jnp.where(better, tau, best_tau)
        best_g = jnp.where(better, jnp.abs(g), best_g)

        hi = jnp.where(g > 0.0, tau, hi)
        lo = jnp.where(g <= 0.0, tau, lo)

        D_lo = p_lo - tau
        D_hi = p_hi - tau
        C = g - D_lo * w_lo - D_hi * w_hi
        A = (D_lo + D_hi) * g - D_lo * D_hi * gp
        B = D_lo * D_hi * g
        disc = jnp.maximum(A * A - 4.0 * B * C, 0.0)
        sq = jnp.sqrt(disc)
        eta_neg = (A - sq) / jnp.where(C == 0.0, 1.0, 2.0 * C)
        eta_pos = 2.0 * B / jnp.where(A + sq == 0.0, 1.0, A + sq)
        eta = jnp.where(A <= 0.0, eta_neg, eta_pos)
        eta_lin = B / jnp.where(A == 0.0, 1.0, A)
        eta = jnp.where(C == 0.0, jnp.where(A != 0.0, eta_lin, -g / jnp.maximum(gp, jnp.finfo(dtype).tiny)), eta)
        # eta must move against the sign of g (g increasing in tau).
        newton = -g / jnp.maximum(gp, jnp.finfo(dtype).tiny)
        eta = jnp.where(g * eta >= 0.0, newton, eta)

        cand = tau + eta
        inb = jnp.isfinite(cand) & (cand > lo) & (cand < hi)
        tau_next = jnp.where(inb, cand, 0.5 * (lo + hi))
        # Freeze once converged exactly.
        tau_next = jnp.where(g == 0.0, tau, tau_next)
        return tau_next, lo, hi, best_tau, best_g

    big = jnp.full_like(tau0, jnp.inf)
    tau, lo, hi, best_tau, best_g = jax.lax.fori_loop(
        0, niter, body, (tau0, lo, hi, tau0, big))
    # Final evaluation so the last tau competes with the best seen.
    g_fin, _ = _eval_g(tau, d_shift, z2, rho, active_mask)
    tau = jnp.where(jnp.abs(g_fin) < best_g, tau, best_tau)

    # Exact closed form when only one active pole remains.
    tau = jnp.where(active_root & (kprime == 1), rho * z2[0], tau)
    origin = jnp.where(active_root & (kprime == 1), 0, origin)

    tau = jnp.where(active_root, tau, jnp.zeros_like(tau))
    origin = jnp.where(active_root, origin, jc_safe.astype(jnp.int32))
    return origin.astype(jnp.int32), tau.astype(dtype)


def secular_solve(d, z2, rho, kprime, *, niter: int = DEFAULT_NITER,
                  chunk: int = 128, dense: bool = False):
    """Find all K eigenvalues of diag(d) + rho * z z^T in compact delta form.

    Args:
      d: (K,) poles; the first ``kprime`` entries are active & sorted
        ascending, the rest are deflated values (already eigenvalues).
      z2: (K,) squared secular weights; exactly zero outside the active set.
      rho: positive scalar.
      kprime: traced int32 -- number of active (non-deflated) poles.
      niter: fixed safeguarded-iteration budget (see ``DEFAULT_NITER`` for
        the accuracy-vs-iterations tradeoff).
      chunk: roots per streamed chunk (memory = O(chunk * K)).
      dense: solve every root in one vectorized batch (no streaming loop;
        memory O(K^2)).  Per-root math is elementwise so results are
        bit-identical to the chunked path -- this is the small-K fast path
        used by the size-adaptive level dispatch (chunked ``lax.map``
        serializes under vmap exactly where K is small and batch is large).

    Returns:
      (origin, tau): int32 (K,) and float (K,).  Eigenvalue j is
      ``d[origin[j]] + tau[j]``.  Deflated j get (j, 0) -- i.e. pass-through.
    """
    K = d.shape[0]
    if dense:
        jc = jnp.arange(K, dtype=jnp.int32)
        return _solve_chunk(jc, d, z2, rho, kprime, niter)
    C = min(chunk, K)
    Kp = _pad_len(K, C)
    idx = jnp.arange(Kp, dtype=jnp.int32).reshape(-1, C)

    fn = functools.partial(_solve_chunk, d=d, z2=z2, rho=rho,
                           kprime=kprime, niter=niter)
    origin, tau = jax.lax.map(lambda j: fn(j), idx)
    return origin.reshape(-1)[:K], tau.reshape(-1)[:K]


def secular_solve_window(d, z2, rho, kprime, start, nroots: int, *,
                         niter: int = DEFAULT_NITER, chunk: int = 128,
                         dense: bool = False):
    """Solve a contiguous window of ``nroots`` secular roots.

    The root-sharding primitive of the distributed conquer phase: each
    device of the solver mesh solves roots ``[start, start + nroots)`` of
    a cooperative merge and the windows are all-gathered back into the
    full (origin, tau) arrays.  ``start`` may be traced (it is the device
    index times the window width inside a shard_map body); ``nroots`` is
    static.  Per-root arithmetic is exactly :func:`_solve_chunk`'s --
    every root's iteration depends only on its own index plus the full
    (d, z2) pole state, so a window solve is bit-identical to the same
    roots of a full :func:`secular_solve` regardless of how either call
    tiles the root axis.

    Returns (origin (nroots,) int32, tau (nroots,)).
    """
    start = jnp.asarray(start, jnp.int32)
    if dense or nroots <= chunk:
        jc = start + jnp.arange(nroots, dtype=jnp.int32)
        return _solve_chunk(jc, d, z2, rho, kprime, niter)
    C = min(chunk, nroots)
    Kp = _pad_len(nroots, C)
    idx = start + jnp.arange(Kp, dtype=jnp.int32).reshape(-1, C)
    fn = functools.partial(_solve_chunk, d=d, z2=z2, rho=rho,
                           kprime=kprime, niter=niter)
    origin, tau = jax.lax.map(lambda j: fn(j), idx)
    return origin.reshape(-1)[:nroots], tau.reshape(-1)[:nroots]


def secular_solve_window_batched(d, z2, rho, kprime, start, nroots: int, *,
                                 niter: int = DEFAULT_NITER,
                                 chunk: int = 128, dense: bool = False):
    """Problem-batched window solve: d, z2 (B, K); rho, kprime (B,);
    ``start`` scalar (the same window of every problem in the batch --
    the cooperative level's layout).  Returns (origin (B, nroots) int32,
    tau (B, nroots))."""
    fn = functools.partial(secular_solve_window, nroots=nroots, niter=niter,
                           chunk=chunk, dense=dense)
    return jax.vmap(fn, in_axes=(0, 0, 0, 0, None))(d, z2, rho, kprime,
                                                    start)


def secular_solve_batched(d, z2, rho, kprime, *, niter: int = DEFAULT_NITER,
                          chunk: int = 128, dense: bool = False):
    """Problem-batched secular solve: one launch for B independent merges.

    d, z2: (B, K); rho, kprime: (B,).  The chunked single-problem path is
    rank-polymorphic under vmap (``lax.map``/``fori_loop`` batch their
    bodies), so the batched form is the same streamed kernel with every
    chunk evaluation B-wide -- per-problem results are bit-identical to
    the unbatched call.  Returns (origin (B, K) int32, tau (B, K)).
    """
    fn = functools.partial(secular_solve, niter=niter, chunk=chunk,
                           dense=dense)
    return jax.vmap(fn)(d, z2, rho, kprime)


def secular_postpass_batched(R, d, z, origin, tau, kprime, rho, *,
                             use_zhat: bool = True, chunk: int = 128,
                             dense: bool = False):
    """Problem-batched fused post-pass (see :func:`secular_postpass`).

    R: (B, r, K); d, z, origin, tau: (B, K); kprime, rho: (B,).
    Returns (zhat (B, K), rows (B, r, K)).
    """
    fn = functools.partial(secular_postpass, use_zhat=use_zhat, chunk=chunk,
                           dense=dense)
    return jax.vmap(fn)(R, d, z, origin, tau, kprime, rho)


def secular_eigenvalues(d, origin, tau):
    """Materialize eigenvalues from compact delta representation."""
    return d[origin] + tau


def zhat_reconstruct(d, z, origin, tau, kprime, rho, *, chunk: int = 128):
    """Gu-Eisenstat stable weight reconstruction (LAPACK DLAED3 analogue).

    Recomputes |zhat_i| such that the poles ``d`` with weights ``zhat`` have
    *exactly* the computed roots, which keeps the streamed secular vectors
    (and therefore the propagated boundary rows) numerically orthogonal.

      zhat_i^2 = prod_j (lam_j - d_i) / [rho * prod_{j != i} (d_j - d_i)]

    computed in log space, streaming over j so peak memory is O(chunk * K).
    Inactive entries pass through unchanged.
    """
    K = d.shape[0]
    dtype = d.dtype
    d_org = d[origin]  # (K,)
    active = jnp.arange(K) < kprime

    C = min(chunk, K)
    Kp = _pad_len(K, C)
    idx = jnp.arange(Kp, dtype=jnp.int32).reshape(-1, C)
    tiny = jnp.finfo(dtype).tiny

    def chunk_fn(ic):
        ic_safe = jnp.minimum(ic, K - 1)
        d_i = d[ic_safe]  # (C,)
        # lam_j - d_i via the compact representation: (d_org_j - d_i) + tau_j
        lam_diff = (d_org[None, :] - d_i[:, None]) + tau[None, :]  # (C, K)
        pole_diff = d[None, :] - d_i[:, None]
        jmask = active[None, :]
        selfmask = jnp.arange(K)[None, :] == ic_safe[:, None]
        log_num = jnp.sum(
            jnp.where(jmask, jnp.log(jnp.maximum(jnp.abs(lam_diff), tiny)), 0.0),
            axis=-1)
        log_den = jnp.sum(
            jnp.where(jmask & ~selfmask,
                      jnp.log(jnp.maximum(jnp.abs(pole_diff), tiny)), 0.0),
            axis=-1)
        z2 = jnp.exp(log_num - log_den) / rho
        return z2

    z2hat = jax.lax.map(chunk_fn, idx).reshape(-1)[:K]
    zhat = jnp.sign(z) * jnp.sqrt(jnp.maximum(z2hat, 0.0))
    return jnp.where(active, zhat, z).astype(dtype)


def boundary_rows_update(R, d, z, origin, tau, kprime, *, chunk: int = 128):
    """Selected-row update: R_parent[:, j] = R_child @ yhat_j (paper Eq. in 4.1).

    For each active root j the normalized secular eigenvector is

        y_j(i) = (z_i / ((d_i - d_origin_j) - tau_j)) / ||.||

    and the parent rows are streamed dot products -- the K x K secular
    eigenvector block is never materialized (chunked: O(r * K + chunk * K)).
    Deflated columns pass through.

    Args:
      R: (r, K) selected child rows (r == 2 for BR; r == K for the
        full-vector / lazy baselines which reuse this routine).
    Returns: (r, K) updated rows.
    """
    r, K = R.shape
    dtype = R.dtype
    d_org = d[origin]
    active_i = (jnp.arange(K) < kprime)

    C = min(chunk, K)
    Kp = _pad_len(K, C)
    idx = jnp.arange(Kp, dtype=jnp.int32).reshape(-1, C)

    def chunk_fn(jc):
        jc_safe = jnp.minimum(jc, K - 1)
        do = d_org[jc_safe]
        tj = tau[jc_safe]
        delta = (d[None, :] - do[:, None]) - tj[:, None]  # (C, K)
        safe = jnp.where(active_i[None, :] & (delta != 0.0), delta, 1.0)
        y = jnp.where(active_i[None, :], z[None, :] / safe, 0.0)  # (C, K)
        nrm = jnp.sqrt(jnp.sum(y * y, axis=-1))
        nrm = jnp.where(nrm > 0.0, nrm, 1.0)
        cols = (R @ y.T) / nrm[None, :]  # (r, C)
        return cols

    cols = jax.lax.map(chunk_fn, idx)             # (nchunk, r, C)
    cols = jnp.moveaxis(cols, 1, 0).reshape(r, -1)[:, :K]
    active_j = (jnp.arange(K) < kprime)[None, :]
    return jnp.where(active_j, cols, R).astype(dtype)


def _postpass_tile(ic, d, z, d_org, tau, kprime, rho, use_zhat):
    """One fused (C, K) delta tile: rows = poles ``ic``, columns = all roots.

    The tile ``lam_diff[c, j] = (d_org_j - d_i) + tau_j`` is formed ONCE and
    serves both reductions:

      * row-reduction over j -> Gu-Eisenstat weight zhat_i for the tile's
        poles (DLAED3's ratio-product form: the full root range is resident
        in the tile, so the numerator/denominator factors pair up as
        interlaced ratios (lam_j - d_i)/(d_j - d_i) and zhat finalizes
        inside the tile with plain products -- no log/exp.  Deflation
        guarantees pole separation > tol, which bounds the partial
        products; this is LAPACK's own unscaled formulation, and it is
        what makes the fused pass decisively cheaper than the two-pass
        log-space pipeline),
      * the tile's poles' additive contribution to EVERY root column of the
        selected-row update, using ``delta = -lam_diff`` and the freshly
        reconstructed weights.

    Returns (zhat_c (C,), y (C, K)) where y holds the *unnormalized* secular
    eigenvector entries y_j(i) = w_i / ((d_i - d_org_j) - tau_j); column
    norms are accumulated by the caller across tiles.
    """
    K = d.shape[0]
    dtype = d.dtype
    active_j = (jnp.arange(K) < kprime)[None, :]

    ic_safe = jnp.minimum(ic, K - 1)
    # valid poles: active AND not tail padding (padded ic duplicate pole
    # K-1 and must contribute nothing; ic >= K implies ic >= kprime).
    valid_i = ic < kprime
    d_i = d[ic_safe]
    z_i = z[ic_safe]

    lam_diff = (d_org[None, :] - d_i[:, None]) + tau[None, :]      # (C, K)

    if use_zhat:
        pole_diff = d[None, :] - d_i[:, None]
        selfmask = jnp.arange(K)[None, :] == ic_safe[:, None]
        ok = active_j & ~selfmask
        ratio = jnp.where(ok, lam_diff / jnp.where(ok, pole_diff, 1.0), 1.0)
        prod = jnp.prod(ratio, axis=-1)
        self_term = (d_org[ic_safe] - d_i) + tau[ic_safe]   # lam_i - d_i
        z2hat = jnp.abs(prod * self_term) / rho
        zhat_c = jnp.sign(z_i) * jnp.sqrt(z2hat)
        zhat_c = jnp.where(valid_i, zhat_c, z_i).astype(dtype)
        w = jnp.where(valid_i, zhat_c, 0.0)
    else:
        zhat_c = z_i
        w = jnp.where(valid_i, z_i, 0.0)

    delta = -lam_diff                         # (d_i - d_org_j) - tau_j
    safe = jnp.where(valid_i[:, None] & (delta != 0.0), delta, 1.0)
    y = jnp.where(valid_i[:, None], w[:, None] / safe, 0.0)        # (C, K)
    return zhat_c, y


def secular_postpass(R, d, z, origin, tau, kprime, rho, *,
                     use_zhat: bool = True, chunk: int = 128,
                     dense: bool = False):
    """Fused conquer post-pass: weight reconstruction + selected-row update.

    Replaces the two independent streamed passes ``zhat_reconstruct`` +
    ``boundary_rows_update`` with a single sweep over the delta structure
    ``(d_i - d_org_j) - tau_j``: each (chunk, K) tile is materialized once
    and feeds both the Gu-Eisenstat weights and the r-row update (the merge
    is bandwidth-bound, so halving the streamed traffic over the delta
    structure is the paper's Section 4.1 lever).

    The key reorganization vs the two-pass form: the sweep is chunked over
    POLES (not roots).  A pole chunk's zhat only needs its own tile rows
    (full root range, resident), so the reconstructed weights are final
    within the tile and immediately usable for that chunk's additive
    contribution to every root column; per-column norms accumulate across
    chunks and are applied once at the end.

    Args:
      R: (r, K) selected child rows.  dense: single (K, K) vectorized tile
      (no scan -- the small-K path that stays parallel under vmap).

    Returns:
      (zhat, rows): reconstructed weights (== z when use_zhat=False or
      deflated) and the updated selected rows, matching the two-pass
      ``zhat_reconstruct`` + ``boundary_rows_update`` composition to
      rounding (the fused pass reconstructs weights in DLAED3's
      ratio-product arithmetic, the two-pass form in log space).
    """
    r, K = R.shape
    dtype = R.dtype

    d_org = d[jnp.minimum(origin, K - 1)]
    active_j = (jnp.arange(K) < kprime)[None, :]

    if dense:
        ic = jnp.arange(K, dtype=jnp.int32)
        zhat, y = _postpass_tile(ic, d, z, d_org, tau, kprime, rho,
                                 use_zhat)
        cols = R @ y                                      # (r, K)
        nrm2 = jnp.sum(y * y, axis=0)
    else:
        C = min(chunk, K)
        Kp = _pad_len(K, C)
        idx = jnp.arange(Kp, dtype=jnp.int32).reshape(-1, C)

        def step(carry, ic):
            cols_acc, nrm2_acc = carry
            zhat_c, y = _postpass_tile(ic, d, z, d_org, tau, kprime,
                                       rho, use_zhat)
            Rc = jnp.take(R, jnp.minimum(ic, K - 1), axis=1)   # (r, C)
            cols_acc = cols_acc + Rc @ y
            nrm2_acc = nrm2_acc + jnp.sum(y * y, axis=0)
            return (cols_acc, nrm2_acc), zhat_c

        init = (jnp.zeros((r, K), dtype), jnp.zeros((K,), dtype))
        (cols, nrm2), zhat_chunks = jax.lax.scan(step, init, idx)
        zhat = zhat_chunks.reshape(-1)[:K]

    nrm = jnp.sqrt(nrm2)
    cols = cols / jnp.where(nrm > 0.0, nrm, 1.0)[None, :]
    rows = jnp.where(active_j, cols, R).astype(dtype)
    zhat = jnp.where(active_j[0], zhat, z).astype(dtype)
    return zhat, rows


def secular_merge_resident(d, z, R, rho, kprime, *,
                           niter: int = DEFAULT_NITER,
                           use_zhat: bool = True):
    """Single-pass resident merge: dense secular solve + fused post-pass.

    XLA realization of the VMEM-resident merge kernel contract: the root
    solve and the conquer post-pass are one traced region, so the
    converged (origin, tau) flow straight into the zhat/row-update
    reductions with no intermediate dispatch boundary (on the Pallas
    backend this is literally one kernel launch; here it is one fused
    XLA computation).  Everything is dense -- the caller gates on K being
    at or below the residency threshold.

    Args:
      d: (K,) poles (active prefix sorted ascending); z: (K,) signed
        secular weights (zero at deflated entries); R: (r, K) selected
        rows; rho scalar > 0; kprime traced int32.

    Returns:
      (origin (K,) int32, tau (K,), zhat (K,), rows (r, K)).
    """
    origin, tau = secular_solve(d, z * z, rho, kprime, niter=niter,
                                dense=True)
    zhat, rows = secular_postpass(R, d, z, origin, tau, kprime, rho,
                                  use_zhat=use_zhat, dense=True)
    return origin, tau, zhat, rows


def secular_merge_resident_batched(d, z, R, rho, kprime, *,
                                   niter: int = DEFAULT_NITER,
                                   use_zhat: bool = True):
    """Problem-batched resident merge (see :func:`secular_merge_resident`).

    d, z: (B, K); R: (B, r, K); rho, kprime: (B,).  Returns
    (origin (B, K) int32, tau (B, K), zhat (B, K), rows (B, r, K)).
    """
    fn = functools.partial(secular_merge_resident, niter=niter,
                           use_zhat=use_zhat)
    return jax.vmap(fn)(d, z, R, rho, kprime)
