"""Secular equation solver for diagonal-plus-rank-one eigenproblems.

Solves for the roots of

    g(lam) = 1 + rho * sum_i z2_i / (d_i - lam) = 0

where ``d`` holds ``kprime`` *active* poles sorted ascending in its prefix
(entries at index >= kprime are deflated/padding and carry ``z2 == 0``).

Roots interlace the active poles:  d_0 < lam_0 < d_1 < ... < lam_{K'-1} <
d_{K'-1} + rho * sum(z2).  Every root is represented in the paper's compact
delta form ``lam_j = d[origin_j] + tau_j`` (Section 4.1 of the paper:
"origin pole + offset tau") so that denominators ``delta_i = (d_i -
d_origin) - tau`` never suffer catastrophic cancellation near the pole.

The iteration is a safeguarded fixed-weight (two-pole rational
interpolation) scheme in the spirit of LAPACK's DLAED4, with a bisection
bracket that guarantees convergence within the fixed iteration budget
(bisection alone contracts the bracket by 2^-niter; the rational step is
superlinear once close).  A fixed budget keeps the whole solver jit- and
vmap-compatible (no per-root early exit), which is the TPU/XLA adaptation
of the paper's per-root CUDA loops.

Memory: all evaluations are chunked over roots -- peak temporary is
O(chunk * K), never O(K^2).  This is the JAX realization of the paper's
"stream each secular vector column" contract.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _pad_len(k: int, chunk: int) -> int:
    return ((k + chunk - 1) // chunk) * chunk


def _eval_g(tau, d_shift, z2, rho, active_mask):
    """g(tau), g'(tau) split by pole side, for a batch of roots.

    tau: (C,); d_shift: (C, K) = d_i - d_origin; z2: (K,); rho scalar.
    Returns (g, w_lo, w_hi) where w_lo/w_hi are the derivative parts from
    poles at/below vs above the gap's left pole (the 'middle way' split).
    """
    delta = d_shift - tau[:, None]  # (C, K)
    # Guard exact pole hits (only possible for inactive/zero-weight terms:
    # tau stays strictly inside the pole-free bracket for active terms).
    safe = jnp.where(active_mask & (delta != 0.0), delta, 1.0)
    w = jnp.where(active_mask, z2[None, :], 0.0)
    g = 1.0 + rho * jnp.sum(w / safe, axis=-1)
    dterms = w / (safe * safe)
    return g, dterms


def _solve_chunk(jc, d, z2, rho, kprime, niter):
    """Solve a chunk of secular roots (safeguarded DLAED4 'middle way').

    jc: (C,) int32 root indices (may exceed K-1 for tail padding).
    d:  (K,) poles, active prefix sorted ascending.
    z2: (K,) squared weights (zero at deflated/padded entries).
    Returns (origin (C,) int32, tau (C,)).
    """
    K = d.shape[0]
    dtype = d.dtype
    jc_safe = jnp.minimum(jc, K - 1)
    active_root = jc < kprime
    is_last = jc == (kprime - 1)

    sum_z2 = jnp.sum(z2)
    span = rho * sum_z2  # upper bound on lam_max - d_max

    d_j = d[jc_safe]
    jnext = jnp.minimum(jc_safe + 1, K - 1)
    d_next_pole = d[jnext]
    # Right end of the gap: next active pole, or d_j + span for the last root.
    gap_hi = jnp.where(is_last, d_j + span, d_next_pole)
    mid_lam = 0.5 * (d_j + gap_hi)

    active_mask = (jnp.arange(K) < kprime)[None, :]

    # f(mid) decides which gap endpoint becomes the origin pole and gives
    # the first bracket halving for free.
    delta_mid = d[None, :] - mid_lam[:, None]
    safe = jnp.where(active_mask & (delta_mid != 0.0), delta_mid, 1.0)
    w = jnp.where(active_mask, z2[None, :], 0.0)
    f_mid = 1.0 + rho * jnp.sum(w / safe, axis=-1)

    use_left = (f_mid > 0.0) | is_last
    origin = jnp.where(use_left, jc_safe, jnext).astype(jnp.int32)
    d_org = d[origin]
    tau_mid = mid_lam - d_org

    # Bracket in tau (relative to the origin pole), refined by f(mid).
    lo = jnp.where(use_left,
                   jnp.zeros_like(tau_mid),
                   tau_mid)
    hi = jnp.where(use_left,
                   jnp.where(is_last & (f_mid <= 0.0), span, tau_mid),
                   jnp.zeros_like(tau_mid))
    lo = jnp.where(is_last & (f_mid <= 0.0), tau_mid, lo)

    # Near poles: gap endpoints for interior roots; for the last root the
    # origin pole and its lower neighbour (LAPACK DLAED4's I=N branch).
    n_lo = jnp.where(is_last, jnp.maximum(jc_safe - 1, 0), jc_safe)
    n_hi = jnp.where(is_last, jc_safe, jnext)
    p_lo = d[n_lo] - d_org
    p_hi = d[n_hi] - d_org
    # Derivative side split: poles with index <= n_lo attach to p_lo.
    side_lo = (jnp.arange(K)[None, :] <= n_lo[:, None]) & active_mask

    d_shift = d[None, :] - d_org[:, None]  # (C, K)

    # ---- initial guess: value-matching 2-pole quadratic at tau_mid ------
    A_lo = rho * z2[n_lo]
    A_hi = rho * z2[n_hi]
    c0 = f_mid - A_lo / (p_lo - tau_mid) - A_hi / (p_hi - tau_mid)
    qb = -(c0 * (p_lo + p_hi) + A_lo + A_hi)
    qc = c0 * p_lo * p_hi + A_lo * p_hi + A_hi * p_lo
    disc0 = jnp.maximum(qb * qb - 4.0 * c0 * qc, 0.0)
    sq0 = jnp.sqrt(disc0)
    qq0 = -0.5 * (qb + jnp.where(qb >= 0.0, 1.0, -1.0) * sq0)
    g1 = qq0 / jnp.where(c0 == 0.0, 1.0, c0)
    g2 = qc / jnp.where(qq0 == 0.0, 1.0, qq0)
    g1 = jnp.where(c0 != 0.0, g1, jnp.inf)
    g2 = jnp.where(qq0 != 0.0, g2, jnp.inf)
    in1 = jnp.isfinite(g1) & (g1 > lo) & (g1 < hi)
    in2 = jnp.isfinite(g2) & (g2 > lo) & (g2 < hi)
    tau0 = jnp.where(in1, g1, jnp.where(in2, g2, 0.5 * (lo + hi)))

    # ---- safeguarded middle-way iteration (DLAED4) -----------------------
    def body(_, state):
        tau, lo, hi, best_tau, best_g = state
        g, dterms = _eval_g(tau, d_shift, z2, rho, active_mask)
        w_lo = rho * jnp.sum(jnp.where(side_lo, dterms, 0.0), axis=-1)
        w_hi = rho * jnp.sum(jnp.where(~side_lo, dterms, 0.0), axis=-1)
        gp = w_lo + w_hi

        better = jnp.abs(g) < best_g
        best_tau = jnp.where(better, tau, best_tau)
        best_g = jnp.where(better, jnp.abs(g), best_g)

        hi = jnp.where(g > 0.0, tau, hi)
        lo = jnp.where(g <= 0.0, tau, lo)

        D_lo = p_lo - tau
        D_hi = p_hi - tau
        C = g - D_lo * w_lo - D_hi * w_hi
        A = (D_lo + D_hi) * g - D_lo * D_hi * gp
        B = D_lo * D_hi * g
        disc = jnp.maximum(A * A - 4.0 * B * C, 0.0)
        sq = jnp.sqrt(disc)
        eta_neg = (A - sq) / jnp.where(C == 0.0, 1.0, 2.0 * C)
        eta_pos = 2.0 * B / jnp.where(A + sq == 0.0, 1.0, A + sq)
        eta = jnp.where(A <= 0.0, eta_neg, eta_pos)
        eta_lin = B / jnp.where(A == 0.0, 1.0, A)
        eta = jnp.where(C == 0.0, jnp.where(A != 0.0, eta_lin, -g / jnp.maximum(gp, jnp.finfo(dtype).tiny)), eta)
        # eta must move against the sign of g (g increasing in tau).
        newton = -g / jnp.maximum(gp, jnp.finfo(dtype).tiny)
        eta = jnp.where(g * eta >= 0.0, newton, eta)

        cand = tau + eta
        inb = jnp.isfinite(cand) & (cand > lo) & (cand < hi)
        tau_next = jnp.where(inb, cand, 0.5 * (lo + hi))
        # Freeze once converged exactly.
        tau_next = jnp.where(g == 0.0, tau, tau_next)
        return tau_next, lo, hi, best_tau, best_g

    big = jnp.full_like(tau0, jnp.inf)
    tau, lo, hi, best_tau, best_g = jax.lax.fori_loop(
        0, niter, body, (tau0, lo, hi, tau0, big))
    # Final evaluation so the last tau competes with the best seen.
    g_fin, _ = _eval_g(tau, d_shift, z2, rho, active_mask)
    tau = jnp.where(jnp.abs(g_fin) < best_g, tau, best_tau)

    # Exact closed form when only one active pole remains.
    tau = jnp.where(active_root & (kprime == 1), rho * z2[0], tau)
    origin = jnp.where(active_root & (kprime == 1), 0, origin)

    tau = jnp.where(active_root, tau, jnp.zeros_like(tau))
    origin = jnp.where(active_root, origin, jc_safe.astype(jnp.int32))
    return origin.astype(jnp.int32), tau.astype(dtype)


def secular_solve(d, z2, rho, kprime, *, niter: int = 40, chunk: int = 128):
    """Find all K eigenvalues of diag(d) + rho * z z^T in compact delta form.

    Args:
      d: (K,) poles; the first ``kprime`` entries are active & sorted
        ascending, the rest are deflated values (already eigenvalues).
      z2: (K,) squared secular weights; exactly zero outside the active set.
      rho: positive scalar.
      kprime: traced int32 -- number of active (non-deflated) poles.
      niter: fixed safeguarded-iteration budget.
      chunk: roots per streamed chunk (memory = O(chunk * K)).

    Returns:
      (origin, tau): int32 (K,) and float (K,).  Eigenvalue j is
      ``d[origin[j]] + tau[j]``.  Deflated j get (j, 0) -- i.e. pass-through.
    """
    K = d.shape[0]
    C = min(chunk, K)
    Kp = _pad_len(K, C)
    idx = jnp.arange(Kp, dtype=jnp.int32).reshape(-1, C)

    fn = functools.partial(_solve_chunk, d=d, z2=z2, rho=rho,
                           kprime=kprime, niter=niter)
    origin, tau = jax.lax.map(lambda j: fn(j), idx)
    return origin.reshape(-1)[:K], tau.reshape(-1)[:K]


def secular_eigenvalues(d, origin, tau):
    """Materialize eigenvalues from compact delta representation."""
    return d[origin] + tau


def zhat_reconstruct(d, z, origin, tau, kprime, rho, *, chunk: int = 128):
    """Gu-Eisenstat stable weight reconstruction (LAPACK DLAED3 analogue).

    Recomputes |zhat_i| such that the poles ``d`` with weights ``zhat`` have
    *exactly* the computed roots, which keeps the streamed secular vectors
    (and therefore the propagated boundary rows) numerically orthogonal.

      zhat_i^2 = prod_j (lam_j - d_i) / [rho * prod_{j != i} (d_j - d_i)]

    computed in log space, streaming over j so peak memory is O(chunk * K).
    Inactive entries pass through unchanged.
    """
    K = d.shape[0]
    dtype = d.dtype
    d_org = d[origin]  # (K,)
    active = jnp.arange(K) < kprime

    C = min(chunk, K)
    Kp = _pad_len(K, C)
    idx = jnp.arange(Kp, dtype=jnp.int32).reshape(-1, C)
    tiny = jnp.finfo(dtype).tiny

    def chunk_fn(ic):
        ic_safe = jnp.minimum(ic, K - 1)
        d_i = d[ic_safe]  # (C,)
        # lam_j - d_i via the compact representation: (d_org_j - d_i) + tau_j
        lam_diff = (d_org[None, :] - d_i[:, None]) + tau[None, :]  # (C, K)
        pole_diff = d[None, :] - d_i[:, None]
        jmask = active[None, :]
        selfmask = jnp.arange(K)[None, :] == ic_safe[:, None]
        log_num = jnp.sum(
            jnp.where(jmask, jnp.log(jnp.maximum(jnp.abs(lam_diff), tiny)), 0.0),
            axis=-1)
        log_den = jnp.sum(
            jnp.where(jmask & ~selfmask,
                      jnp.log(jnp.maximum(jnp.abs(pole_diff), tiny)), 0.0),
            axis=-1)
        z2 = jnp.exp(log_num - log_den) / rho
        return z2

    z2hat = jax.lax.map(chunk_fn, idx).reshape(-1)[:K]
    zhat = jnp.sign(z) * jnp.sqrt(jnp.maximum(z2hat, 0.0))
    return jnp.where(active, zhat, z).astype(dtype)


def boundary_rows_update(R, d, z, origin, tau, kprime, *, chunk: int = 128):
    """Selected-row update: R_parent[:, j] = R_child @ yhat_j (paper Eq. in 4.1).

    For each active root j the normalized secular eigenvector is

        y_j(i) = (z_i / ((d_i - d_origin_j) - tau_j)) / ||.||

    and the parent rows are streamed dot products -- the K x K secular
    eigenvector block is never materialized (chunked: O(r * K + chunk * K)).
    Deflated columns pass through.

    Args:
      R: (r, K) selected child rows (r == 2 for BR; r == K for the
        full-vector / lazy baselines which reuse this routine).
    Returns: (r, K) updated rows.
    """
    r, K = R.shape
    dtype = R.dtype
    d_org = d[origin]
    active_i = (jnp.arange(K) < kprime)

    C = min(chunk, K)
    Kp = _pad_len(K, C)
    idx = jnp.arange(Kp, dtype=jnp.int32).reshape(-1, C)

    def chunk_fn(jc):
        jc_safe = jnp.minimum(jc, K - 1)
        do = d_org[jc_safe]
        tj = tau[jc_safe]
        delta = (d[None, :] - do[:, None]) - tj[:, None]  # (C, K)
        safe = jnp.where(active_i[None, :] & (delta != 0.0), delta, 1.0)
        y = jnp.where(active_i[None, :], z[None, :] / safe, 0.0)  # (C, K)
        nrm = jnp.sqrt(jnp.sum(y * y, axis=-1))
        nrm = jnp.where(nrm > 0.0, nrm, 1.0)
        cols = (R @ y.T) / nrm[None, :]  # (r, C)
        return cols

    cols = jax.lax.map(chunk_fn, idx)             # (nchunk, r, C)
    cols = jnp.moveaxis(cols, 1, 0).reshape(r, -1)[:, :K]
    active_j = (jnp.arange(K) < kprime)[None, :]
    return jnp.where(active_j, cols, R).astype(dtype)
