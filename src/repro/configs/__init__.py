"""Architecture registry: one module per assigned architecture.

    from repro.configs import get_config, get_smoke_config, ARCHS
    cfg = get_config("qwen3-0.6b")
"""

from __future__ import annotations

import importlib

ARCHS = (
    "whisper-small",
    "llama4-maverick-400b-a17b",
    "dbrx-132b",
    "minicpm3-4b",
    "deepseek-67b",
    "qwen3-0.6b",
    "qwen2-1.5b",
    "qwen2-vl-72b",
    "zamba2-7b",
    "mamba2-130m",
)

_MODULES = {a: a.replace("-", "_").replace(".", "p") for a in ARCHS}


def _load(arch: str):
    if arch not in _MODULES:
        raise ValueError(f"unknown arch {arch!r}; choose from {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str):
    return _load(arch).config()


def get_smoke_config(arch: str):
    return _load(arch).smoke_config()


from repro.configs.shapes import SHAPES, SMOKE_SHAPES, ShapeSpec, shape_applicable  # noqa: E402

__all__ = ["ARCHS", "SHAPES", "SMOKE_SHAPES", "ShapeSpec", "get_config",
           "get_smoke_config", "shape_applicable"]
