"""qwen3-0.6b [dense]: 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936 -- qk_norm, GQA.  [hf:Qwen/Qwen3-8B family; hf]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", family="dense",
        num_layers=28, d_model=1024, num_heads=16, num_kv_heads=8,
        d_ff=3072, vocab_size=151936, head_dim=128,
        attention="gqa", qk_norm=True, rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        attention="gqa", qk_norm=True,
        param_dtype="float32", compute_dtype="float32",
    )
