"""Assigned input-shape set (identical for every LM arch; see DESIGN.md).

    train_4k     seq 4,096   global_batch 256   -> train_step
    prefill_32k  seq 32,768  global_batch 32    -> serve_prefill
    decode_32k   seq 32,768  global_batch 128   -> serve_step (1 new token,
                                                   KV cache of seq_len)
    long_500k    seq 524,288 global_batch 1     -> serve_step; SSM/hybrid only

Reduced variants (same structure, tiny dims) feed the per-arch smoke tests.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

SMOKE_SHAPES = {
    "train": ShapeSpec("smoke_train", 64, 2, "train"),
    "prefill": ShapeSpec("smoke_prefill", 64, 2, "prefill"),
    "decode": ShapeSpec("smoke_decode", 64, 2, "decode"),
}


def shape_applicable(cfg, shape: ShapeSpec) -> bool:
    """long_500k requires sub-quadratic context state (skip rule)."""
    if shape.name == "long_500k":
        return cfg.supports_long_context
    return True
