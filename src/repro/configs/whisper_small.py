"""whisper-small [audio]: 12L d_model=768 12H (kv=12) d_ff=3072
vocab=51865 -- encoder-decoder, conv frontend (STUB: input_specs()
provides precomputed frame embeddings).  [arXiv:2212.04356; unverified]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="audio",
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
        d_ff=3072, vocab_size=51865,
        attention="gqa", rope_style="none",       # whisper uses learned/sinusoidal pos
        encoder_layers=12, encoder_seq_len=1500,
        frontend="audio_stub", norm_eps=1e-5, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        attention="gqa", rope_style="none",
        encoder_layers=2, encoder_seq_len=32,
        frontend="audio_stub", norm_eps=1e-5,
        param_dtype="float32", compute_dtype="float32",
    )
