"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 -- M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]

Backbone only: the vision tower is a STUB -- input_specs() provides
precomputed patch embeddings; M-RoPE consumes (t, h, w) position ids
(all equal for text-only cells)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", family="vlm",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=29568, vocab_size=152064,
        attention="gqa", qkv_bias=True,
        rope_style="mrope", mrope_sections=(16, 24, 24), rope_theta=1e6,
        frontend="vision_stub", tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        attention="gqa", qkv_bias=True,
        rope_style="mrope", mrope_sections=(2, 3, 3),
        frontend="vision_stub", tie_embeddings=False,
        param_dtype="float32", compute_dtype="float32",
    )
