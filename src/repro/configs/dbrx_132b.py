"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16e top-4 -- fine-grained.  [hf:databricks/dbrx-base;
unverified]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=10752, vocab_size=100352,
        attention="gqa", rope_theta=5e5,
        moe_num_experts=16, moe_top_k=4, moe_d_ff=10752,
        tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=256,
        attention="gqa",
        moe_num_experts=4, moe_top_k=2, moe_d_ff=96,
        tie_embeddings=False,
        param_dtype="float32", compute_dtype="float32",
    )
