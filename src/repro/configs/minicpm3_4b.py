"""minicpm3-4b [dense]: 62L d_model=2560 40H d_ff=6400 vocab=73448 -- MLA
(multi-head latent attention).  [hf:openbmb/MiniCPM3-4B; hf]

MLA ranks follow the published MiniCPM3 config: q_lora_rank=768,
kv_lora_rank=256, qk_nope=64, qk_rope=32, v_head=64."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b", family="dense",
        num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
        d_ff=6400, vocab_size=73448, head_dim=96,
        attention="mla",
        mla_q_lora_rank=768, mla_kv_lora_rank=256,
        mla_qk_nope_dim=64, mla_qk_rope_dim=32, mla_v_head_dim=64,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=24,
        attention="mla",
        mla_q_lora_rank=32, mla_kv_lora_rank=16,
        mla_qk_nope_dim=16, mla_qk_rope_dim=8, mla_v_head_dim=16,
        param_dtype="float32", compute_dtype="float32",
    )
