"""mamba2-130m [ssm]: 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128 -- SSD (state-space duality).  [arXiv:2405.21060; unverified]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", family="ssm",
        num_layers=24, d_model=768, num_heads=24, num_kv_heads=24,
        d_ff=0, vocab_size=50280,
        attention="none", rope_style="none",
        ssm_state_dim=128, ssm_num_heads=24, ssm_head_dim=64,
        ssm_conv_width=4, ssm_chunk=128, ssm_expand=2,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=256,
        attention="none", rope_style="none",
        ssm_state_dim=16, ssm_num_heads=4, ssm_head_dim=32,
        ssm_conv_width=4, ssm_chunk=16, ssm_expand=2,
        param_dtype="float32", compute_dtype="float32",
    )
