"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1 -- MoE, early fusion (modality
fusion is upstream of the backbone; text backbone modeled here).
[hf:meta-llama/Llama-4 family; unverified]

Shared always-on expert per llama4; dry-run pairs this config with
Adafactor + full remat (see configs in launch/dryrun.py) to fit HBM.
"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
        d_ff=8192, vocab_size=202048, head_dim=128,
        attention="gqa", rope_theta=5e5,
        moe_num_experts=128, moe_top_k=1, moe_d_ff=8192,
        moe_shared_expert=True, tie_embeddings=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, head_dim=16,
        attention="gqa",
        moe_num_experts=8, moe_top_k=1, moe_d_ff=128,
        moe_shared_expert=True, tie_embeddings=False,
        param_dtype="float32", compute_dtype="float32",
    )
