"""zamba2-7b [hybrid]: 81L d_model=3584 32H (kv=32) d_ff=14336
vocab=32000, ssm_state=64 -- Mamba2 blocks + SHARED attention block
(same parameters applied at every 6th position; the per-site LoRA
specialization of the released model is omitted -- noted in DESIGN.md).
[arXiv:2411.15242; unverified]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
        d_ff=14336, vocab_size=32000,
        attention="gqa", rope_theta=10000.0,
        ssm_state_dim=64, ssm_num_heads=56, ssm_head_dim=128,
        ssm_conv_width=4, ssm_chunk=128, ssm_expand=2,
        hybrid_attn_every=6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        num_layers=7, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, head_dim=16,
        attention="gqa",
        ssm_state_dim=16, ssm_num_heads=4, ssm_head_dim=32,
        ssm_conv_width=4, ssm_chunk=16, ssm_expand=2,
        hybrid_attn_every=3,
        param_dtype="float32", compute_dtype="float32",
    )
