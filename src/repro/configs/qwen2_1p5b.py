"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 -- GQA, QKV bias.  [arXiv:2407.10671; hf]"""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense",
        num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
        d_ff=8960, vocab_size=151936,
        attention="gqa", qkv_bias=True, rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-smoke", family="dense",
        num_layers=2, d_model=48, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=256,
        attention="gqa", qkv_bias=True,
        param_dtype="float32", compute_dtype="float32",
    )
