"""Lanczos tridiagonalization of pytree-valued linear operators.

This is the bridge between the training system and the paper's eigensolver:
m Lanczos steps against the (sharded) Hessian/GGN reduce the curvature
operator to a symmetric tridiagonal (alpha, beta) -- exactly the input the
BR boundary-row D&C solver consumes.  The matvec runs under whatever pjit
sharding the training step uses, so the reduction is distributed while the
tridiagonal solve is replicated (it is O(m) data).

Full reorthogonalization is optional (m is small; 2m pytree dots).
"""

from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def _dot(a, b):
    leaves = jax.tree.map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)),
        a, b)
    return sum(jax.tree.leaves(leaves))


def _axpy(alpha, x, y):
    return jax.tree.map(
        lambda xi, yi: alpha * xi.astype(jnp.float32) + yi.astype(jnp.float32),
        x, y)


def _scale(alpha, x):
    return jax.tree.map(lambda xi: alpha * xi.astype(jnp.float32), x)


def lanczos_tridiag(matvec: Callable, probe, num_steps: int, *,
                    full_reorth: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run `num_steps` Lanczos iterations from `probe` (a pytree).

    Returns (alpha (m,), beta (m-1,)) of the Krylov tridiagonal.  Python
    loop (m is small and each step is a full distributed matvec); call
    under jit for fusion if desired.
    """
    nrm = jnp.sqrt(_dot(probe, probe))
    v = _scale(1.0 / nrm, probe)
    v_prev = jax.tree.map(jnp.zeros_like, v)
    basis = [v] if full_reorth else None

    alphas, betas = [], []
    beta = jnp.asarray(0.0, jnp.float32)
    for step in range(num_steps):
        w = matvec(v)
        alpha = _dot(w, v)
        w = _axpy(-alpha, v, w)
        w = _axpy(-beta, v_prev, w)
        if full_reorth:
            for u in basis:
                w = _axpy(-_dot(w, u), u, w)
        beta = jnp.sqrt(jnp.maximum(_dot(w, w), 0.0))
        alphas.append(alpha)
        if step < num_steps - 1:
            betas.append(beta)
            v_prev = v
            v = _scale(1.0 / jnp.maximum(beta, 1e-30), w)
            if full_reorth:
                basis.append(v)
    return jnp.stack(alphas), (jnp.stack(betas) if betas
                               else jnp.zeros((0,), jnp.float32))


def lanczos_tridiag_batch(matvec: Callable, probes, num_steps: int, *,
                          full_reorth: bool = True
                          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Batched Lanczos: all probes advance together, one matvec batch/step.

    ``probes`` is a pytree whose leaves carry a leading probe axis (P,
    ...); the distributed ``matvec`` is vmapped over that axis, so every
    Lanczos step issues ONE batched operator application for the whole
    probe set instead of P sequential ones.  ``matvec`` must therefore
    be jax-traceable (pure jnp/lax ops) -- a callable that round-trips
    through numpy/scipy worked with the old eager per-probe loop but
    will fail under vmap tracing; wrap such operators with
    ``jax.pure_callback`` or fall back to looping ``lanczos_tridiag``.
    Returns (alpha (P, m), beta (P, m-1)) -- exactly the (B, n)/(B, n-1)
    layout the batched BR eigensolver consumes, with no host round-trip
    in between.
    """
    return jax.vmap(
        lambda p: lanczos_tridiag(matvec, p, num_steps,
                                  full_reorth=full_reorth))(probes)
