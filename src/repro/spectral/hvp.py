"""Curvature-operator matvecs over the training state.

make_hvp:  v -> H v       (Hessian of the loss wrt params, via jvp-of-grad;
                           exact, one extra fwd+bwd per matvec)
make_gnvp: v -> G v       (Gauss-Newton: J^T (J v) through the loss head --
                           PSD, the usual choice for optimizer governance)

Both close over (params, batch) and inherit their sharding: under pjit the
matvec is as distributed as the train step itself.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def make_hvp(loss_of_params: Callable, params) -> Callable:
    grad_fn = jax.grad(loss_of_params)

    def hvp(v):
        return jax.jvp(grad_fn, (params,), (v,))[1]

    return hvp


def make_gnvp(logits_of_params: Callable, loss_of_logits: Callable,
              params) -> Callable:
    """Gauss-Newton vector product: J^T H_out J v."""

    def gnvp(v):
        logits, jv = jax.jvp(logits_of_params, (params,), (v,))
        h_out = jax.grad(
            lambda lg: jnp.vdot(jax.grad(loss_of_logits)(lg), jv))(logits)
        _, vjp_fn = jax.vjp(logits_of_params, params)
        return vjp_fn(h_out)[0]

    return gnvp
