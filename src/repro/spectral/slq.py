"""Stochastic Lanczos Quadrature on top of the batched BR eigensolver.

The Gauss-quadrature rule for a Lanczos tridiagonal T_m needs exactly
(eigenvalues of T_m, squared *first components* of its eigenvectors).
That first-component vector is blo(Q) -- literally the paper's boundary-row
state.  BR therefore computes the SLQ rule natively, values + one boundary
row, with O(m) memory per probe: the training-framework consumer and the
paper's algorithm meet in the same data structure.

Execution shape: the whole probe set runs as ONE batched pipeline --
vmapped Lanczos (one batched matvec per step) feeding a single batched
device solve through the plan/executor core (``eigvalsh_tridiagonal_batch``),
with one host transfer at the very end.  No per-probe Python loop, no
per-probe ``np.asarray`` round-trips, and exactly one device solve for
any ``num_probes`` (asserted in tests via ``SOLVE_COUNTER``).

Usage inside the trainer (see train loop / examples):

    est = slq_spectrum(hvp, params_like, rng, num_probes=4, num_steps=64)
    gov_scale = governor(est.lam_max)
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bisect import eigvalsh_tridiagonal_range
from repro.core.br_dc import eigvalsh_tridiagonal_batch
from repro.spectral.lanczos import lanczos_tridiag_batch


@dataclasses.dataclass
class SpectralEstimate:
    nodes: np.ndarray       # (probes, m) Ritz values (quadrature nodes)
    weights: np.ndarray     # (probes, m) Gauss weights = blo(Q_T)^2
    lam_max: float
    lam_min: float
    trace_est: float        # dim * mean_k sum_i w_i lam_i

    def density(self, grid, sigma=None):
        """Smoothed spectral density on `grid` (Gaussian kernel).

        One broadcasted (grid, probes*m) evaluation -- interpreter time is
        O(1) in the number of nodes, not O(probes * m * grid) Python
        iterations.
        """
        lo, hi = float(np.min(self.nodes)), float(np.max(self.nodes))
        sigma = sigma or max((hi - lo) / 100.0, 1e-12)
        grid = np.asarray(grid, np.float64)
        lam = np.asarray(self.nodes, np.float64).reshape(-1)
        w = np.asarray(self.weights, np.float64).reshape(-1)
        dens = np.sum(
            w[None, :] * np.exp(-0.5 * ((grid[:, None] - lam[None, :])
                                        / sigma) ** 2),
            axis=1)
        dens /= (self.nodes.shape[0] * np.sqrt(2 * np.pi) * sigma)
        return dens


def _rademacher_like(rng, tree):
    leaves, tdef = jax.tree.flatten(tree)
    keys = jax.random.split(rng, len(leaves))
    probes = [jax.random.rademacher(k, l.shape, jnp.float32)
              for k, l in zip(keys, leaves)]
    return tdef.unflatten(probes)


def slq_spectrum(matvec: Callable, params_like, rng, *, num_probes: int = 4,
                 num_steps: int = 32, leaf: int = 8,
                 client=None) -> SpectralEstimate:
    """Estimate the operator spectrum via SLQ with batched BR as the
    tridiagonal eigensolver (values + boundary rows -> nodes + weights).

    All ``num_probes`` Krylov tridiagonals are solved in one batched
    device solve; the solve dtype is float64 when x64 is enabled (the
    library's accuracy regime), matching the historical per-probe path.
    ``matvec`` must be jax-traceable (it runs under vmap across probes;
    see :func:`repro.spectral.lanczos.lanczos_tridiag_batch`).

    ``client`` (a :class:`repro.serve.EigensolverClient`) submits the
    probe set as ONE ``kind="slq"`` service request instead of launching
    directly: the solve coalesces with whatever other traffic shares the
    bucket, and the result is bit-for-bit the direct path's (same plan,
    same executable -- pinned in tests/test_serve.py).
    """
    dim = sum(x.size for x in jax.tree.leaves(params_like))
    probes = [_rademacher_like(jax.random.fold_in(rng, k), params_like)
              for k in range(num_probes)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *probes)

    alpha, beta = lanczos_tridiag_batch(matvec, stacked, num_steps)
    solve_dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    alpha = alpha.astype(solve_dtype)
    beta = beta.astype(solve_dtype)
    if client is not None:
        from repro.core.request import SolveRequest
        res = client.submit(SolveRequest(
            d=alpha, e=beta, kind="slq", knobs={"leaf": leaf})).result()
    else:
        res = eigvalsh_tridiagonal_batch(alpha, beta, leaf=leaf,
                                         return_boundary=True)

    nodes = np.asarray(res.eigenvalues)          # single host transfer
    weights = np.asarray(res.blo) ** 2           # Gauss weights
    trace = dim * float(np.mean(np.sum(weights * nodes, axis=1)))
    return SpectralEstimate(
        nodes=nodes, weights=weights,
        lam_max=float(np.max(nodes)), lam_min=float(np.min(nodes)),
        trace_est=trace)


def spectral_edges(matvec: Callable, params_like, rng, *,
                   num_probes: int = 1, num_steps: int = 16, k: int = 1):
    """k smallest + k largest Ritz values per probe via spectrum slicing.

    The extremal-edge estimate is the canonical k << n workload: the
    density/trace machinery of :func:`slq_spectrum` needs every node and
    its Gauss weight, but lam_min/lam_max monitoring needs only the edge
    Ritz values -- so this path solves exactly 2k eigenvalues of each
    Krylov tridiagonal through ``eigvalsh_tridiagonal_range`` (two
    batched sliced solves, no boundary rows, no full conquer) instead of
    running the complete BR merge tree.  Returns (lo, hi) numpy arrays
    of shape (num_probes, k), ascending along k.
    """
    probes = [_rademacher_like(jax.random.fold_in(rng, j), params_like)
              for j in range(num_probes)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *probes)
    alpha, beta = lanczos_tridiag_batch(matvec, stacked, num_steps)
    solve_dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    alpha = alpha.astype(solve_dtype)
    beta = beta.astype(solve_dtype)
    m = alpha.shape[1]
    k = min(k, m)
    lo = eigvalsh_tridiagonal_range(alpha, beta, select="i", il=0, iu=k - 1)
    hi = eigvalsh_tridiagonal_range(alpha, beta, select="i", il=m - k,
                                    iu=m - 1)
    return np.asarray(lo), np.asarray(hi)


def sharpness(matvec: Callable, params_like, rng, *, num_steps: int = 16) -> float:
    """Cheap lam_max estimate (single probe, small m) -- a 1-eigenvalue
    sliced solve of the Krylov tridiagonal, not a full spectrum."""
    _, hi = spectral_edges(matvec, params_like, rng, num_probes=1,
                           num_steps=num_steps, k=1)
    return float(np.max(hi))
