"""Stochastic Lanczos Quadrature on top of the BR eigensolver.

The Gauss-quadrature rule for a Lanczos tridiagonal T_m needs exactly
(eigenvalues of T_m, squared *first components* of its eigenvectors).
That first-component vector is blo(Q) -- literally the paper's boundary-row
state.  BR therefore computes the SLQ rule natively, values + one boundary
row, with O(m) memory: the training-framework consumer and the paper's
algorithm meet in the same data structure.

Usage inside the trainer (see train loop / examples):

    est = slq_spectrum(hvp, params_like, rng, num_probes=4, num_steps=64)
    gov_scale = governor(est.lam_max)
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.br_dc import eigvalsh_tridiagonal_br
from repro.spectral.lanczos import lanczos_tridiag


@dataclasses.dataclass
class SpectralEstimate:
    nodes: np.ndarray       # (probes, m) Ritz values (quadrature nodes)
    weights: np.ndarray     # (probes, m) Gauss weights = blo(Q_T)^2
    lam_max: float
    lam_min: float
    trace_est: float        # dim * mean_k sum_i w_i lam_i

    def density(self, grid, sigma=None):
        """Smoothed spectral density on `grid` (Gaussian kernel)."""
        lo, hi = float(np.min(self.nodes)), float(np.max(self.nodes))
        sigma = sigma or max((hi - lo) / 100.0, 1e-12)
        dens = np.zeros_like(grid, dtype=np.float64)
        for k in range(self.nodes.shape[0]):
            for lam, w in zip(self.nodes[k], self.weights[k]):
                dens += w * np.exp(-0.5 * ((grid - lam) / sigma) ** 2)
        dens /= (self.nodes.shape[0] * np.sqrt(2 * np.pi) * sigma)
        return dens


def _rademacher_like(rng, tree):
    leaves, tdef = jax.tree.flatten(tree)
    keys = jax.random.split(rng, len(leaves))
    probes = [jax.random.rademacher(k, l.shape, jnp.float32)
              for k, l in zip(keys, leaves)]
    return tdef.unflatten(probes)


def slq_spectrum(matvec: Callable, params_like, rng, *, num_probes: int = 4,
                 num_steps: int = 32, leaf: int = 8) -> SpectralEstimate:
    """Estimate the operator spectrum via SLQ with BR as the tridiagonal
    eigensolver (values + boundary row -> nodes + weights)."""
    dim = sum(x.size for x in jax.tree.leaves(params_like))
    nodes, weights = [], []
    for k in range(num_probes):
        probe = _rademacher_like(jax.random.fold_in(rng, k), params_like)
        alpha, beta = lanczos_tridiag(matvec, probe, num_steps)
        res = eigvalsh_tridiagonal_br(
            np.asarray(alpha, np.float64), np.asarray(beta, np.float64),
            leaf=leaf, return_boundary=True)
        nodes.append(np.asarray(res.eigenvalues))
        weights.append(np.asarray(res.blo) ** 2)   # Gauss weights
    nodes = np.stack(nodes)
    weights = np.stack(weights)
    trace = dim * float(np.mean(np.sum(weights * nodes, axis=1)))
    return SpectralEstimate(
        nodes=nodes, weights=weights,
        lam_max=float(np.max(nodes)), lam_min=float(np.min(nodes)),
        trace_est=trace)


def sharpness(matvec: Callable, params_like, rng, *, num_steps: int = 16) -> float:
    """Cheap lam_max estimate (single probe, small m)."""
    est = slq_spectrum(matvec, params_like, rng, num_probes=1,
                       num_steps=num_steps)
    return est.lam_max
