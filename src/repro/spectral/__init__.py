from repro.spectral.lanczos import lanczos_tridiag, lanczos_tridiag_batch
from repro.spectral.hvp import make_hvp, make_gnvp
from repro.spectral.slq import (SpectralEstimate, slq_spectrum, sharpness,
                                spectral_edges)

__all__ = ["SpectralEstimate", "lanczos_tridiag", "lanczos_tridiag_batch",
           "make_gnvp", "make_hvp", "sharpness", "slq_spectrum",
           "spectral_edges"]
