"""Fault-tolerant checkpointing: atomic, checksummed, elastic.

Design (mirrors what production JAX trainers do, without orbax):

  * **Atomic**: write into `step_<k>.tmp/`, fsync, then rename -- a crash
    mid-write never corrupts the latest valid checkpoint.
  * **Checksummed**: every leaf gets a CRC32 recorded in manifest.json;
    restore verifies before handing arrays to the trainer.
  * **Keep-N**: bounded disk use; the newest `keep` checkpoints survive.
  * **Auto-resume**: `latest_step()` scans for the newest *valid* manifest
    (a torn checkpoint is skipped, the previous one restores).
  * **Elastic reshard-on-load**: leaves are saved as full logical arrays
    plus the logical PartitionSpec tree; restore takes the *current* mesh
    and re-applies NamedSharding -- a job checkpointed on (2,16,16) can
    resume on (16,16) or (4,16,16) unchanged.  (Per-host sharded I/O would
    slot in at `_gather`/`_put`; single-process here.)
"""

from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save_tree(directory: str, step: int, tree: Any, *, meta: Optional[dict] = None,
              keep: int = 3) -> str:
    """Atomically save a pytree checkpoint.  Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest = {"step": step, "meta": meta or {}, "leaves": {}}
    for key, leaf in _flatten_with_paths(tree):
        arr = np.asarray(leaf)
        fn = key.replace("/", "__") + ".npy"
        path = os.path.join(tmp, fn)
        np.save(path, arr)
        manifest["leaves"][key] = {
            "file": fn,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    # keep-N garbage collection
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)
    return final


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    while steps:
        s = steps[-1]
        try:
            with open(os.path.join(directory, f"step_{s:08d}",
                                   "manifest.json")) as f:
                json.load(f)
            return s
        except Exception:
            steps.pop()   # torn manifest: fall back to previous
    return None


def restore_tree(directory: str, step: int, like: Any, *,
                 shardings: Any = None, verify: bool = True) -> Any:
    """Restore a pytree saved by save_tree.

    `like` supplies the tree structure (values ignored).  If `shardings`
    (matching pytree of NamedSharding) is given, each leaf is device_put
    with it -- this is the elastic reshard-on-load path.
    """
    base = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(base, "manifest.json")) as f:
        manifest = json.load(f)

    keys = [k for k, _ in _flatten_with_paths(like)]
    shard_leaves = (_flatten_with_paths(shardings) if shardings is not None
                    else [(k, None) for k in keys])
    shard_map = {k: s for k, s in shard_leaves}

    leaves = []
    for key in keys:
        entry = manifest["leaves"][key]
        arr = np.load(os.path.join(base, entry["file"]))
        if verify:
            crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
            if crc != entry["crc32"]:
                raise IOError(f"checksum mismatch for {key} in {base}")
        sh = shard_map.get(key)
        leaves.append(jax.device_put(arr, sh) if sh is not None else arr)

    tdef = jax.tree.structure(like)
    return tdef.unflatten(leaves), manifest["meta"]


class CheckpointManager:
    """Step-driven wrapper: save every `period`, auto-resume from latest."""

    def __init__(self, directory: str, *, period: int = 100, keep: int = 3):
        self.directory = directory
        self.period = period
        self.keep = keep

    def maybe_save(self, step: int, tree: Any, meta: Optional[dict] = None):
        if step % self.period == 0:
            return save_tree(self.directory, step, tree, meta=meta,
                             keep=self.keep)
        return None

    def resume(self, like: Any, shardings: Any = None):
        """Returns (tree, meta, step) or (None, None, 0) if fresh."""
        step = latest_step(self.directory)
        if step is None:
            return None, None, 0
        tree, meta = restore_tree(self.directory, step, like,
                                  shardings=shardings)
        return tree, meta, step
