"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state -- required because the dry-run forces 512 host
devices via XLA_FLAGS before first jax init, while tests/benches must see
a single device.
"""

from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) single-pod or (2, 16, 16) two-pod production mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def mesh_shape_for(devices: int, *, model_parallel: int = 16,
                   pods: int = 1):
    """Pure factorization behind :func:`make_mesh_for` -- returns
    ``(shape, axis_names)`` without touching jax device state, so the
    awkward-count behavior is unit-testable on any box.

    Hardened for awkward counts: `model` is the largest divisor of
    `devices` not exceeding `model_parallel` (odd / non-power-of-two
    counts land on a real factorization instead of halving past valid
    divisors or dividing by zero), the pod axis only materializes when
    it divides the remainder, and impossible inputs raise instead of
    deriving a degenerate mesh.
    """
    if devices < 1:
        raise ValueError(f"devices must be >= 1, got {devices}")
    if model_parallel < 1:
        raise ValueError(
            f"model_parallel must be >= 1, got {model_parallel}")
    if pods < 1:
        raise ValueError(f"pods must be >= 1, got {pods}")
    model = max(m for m in range(1, min(model_parallel, devices) + 1)
                if devices % m == 0)
    rest = devices // model
    pod = pods if pods > 1 and rest % pods == 0 else 1
    data = rest // pod
    if pod > 1:
        return (pod, data, model), ("pod", "data", "model")
    return (data, model), ("data", "model")


def make_mesh_for(devices: int, *, model_parallel: int = 16,
                  pods: int = 1):
    """Elastic variant: build the best (pod, data, model) mesh for an
    arbitrary device count (restart-on-fewer-hosts path).  See
    :func:`mesh_shape_for` for the factorization rules."""
    shape, axes = mesh_shape_for(devices, model_parallel=model_parallel,
                                 pods=pods)
    return make_mesh(shape, axes)


def make_solver_mesh(shards: int):
    """1-D mesh for the distributed-conquer eigensolver: `shards` devices
    on a single axis named `dist.sharding.SOLVER_AXIS`.

    The D&C tree pairs nodes, so the shard count must be a power of two;
    and the devices must already be visible -- forcing host devices after
    first jax init silently does nothing, so a shortfall here raises
    with the fix spelled out rather than falling back to one device.
    """
    import jax

    from repro.dist.sharding import SOLVER_AXIS

    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    if shards & (shards - 1):
        raise ValueError(
            f"shards must be a power of two (the D&C tree pairs "
            f"nodes), got {shards}")
    avail = jax.device_count()
    if shards > avail:
        raise ValueError(
            f"solver mesh needs {shards} devices but only {avail} "
            f"visible; force host devices before first jax init "
            f"(XLA_FLAGS=--xla_force_host_platform_device_count={shards}, "
            f"or run.py --mesh {shards})")
    return make_mesh((shards,), (SOLVER_AXIS,))


def describe(mesh) -> str:
    return " x ".join(f"{k}={v}" for k, v in mesh.shape.items())
