"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state -- required because the dry-run forces 512 host
devices via XLA_FLAGS before first jax init, while tests/benches must see
a single device.
"""

from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) single-pod or (2, 16, 16) two-pod production mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_for(devices: int, *, model_parallel: int = 16,
                  pods: int = 1):
    """Elastic variant: build the best (pod, data, model) mesh for an
    arbitrary device count (restart-on-fewer-hosts path)."""
    model = min(model_parallel, devices)
    while devices % model:
        model //= 2
    rest = devices // model
    pod = pods if rest % pods == 0 else 1
    data = rest // pod
    if pod > 1:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))


def describe(mesh) -> str:
    return " x ".join(f"{k}={v}" for k, v in mesh.shape.items())
