"""Roofline term extraction from compiled XLA artifacts.

Sources:
  * compiled.cost_analysis(): per-device HLO FLOPs + bytes accessed
    (the module is post-SPMD-partitioning, so numbers are per chip).
  * HLO text parse: per-device collective bytes, summed over the operand
    shapes of every all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute instruction.

Roofline terms (TPU v5e constants):
    compute    = flops_per_chip / 197e12           [s]
    memory     = bytes_per_chip / 819e9            [s]
    collective = coll_bytes_per_chip / 50e9        [s]

(Equivalent to the total/(chips*rate) formulation since all quantities are
per-chip from the partitioned module.)
"""

from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link (per chip, per the assignment)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_GROUP_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\]"
                            r"(T\(([0-9,]+)\))?")


def _crosses_pod(line: str, pod_stride: int) -> bool:
    """Does this collective's replica group span pods?  Pods are contiguous
    device-id blocks of `pod_stride` (512-mesh: pod = id // 256)."""
    m = _GROUP_RE.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x]
        return len({i // pod_stride for i in ids}) > 1
    m = _GROUP_IOTA_RE.search(line)
    if m:
        ngroups, gsize = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        total = 1
        for d in dims:
            total *= d
        # iota-form groups: contiguous reshape (optionally transposed).
        # Without a transpose, group g holds ids [g*gsize, (g+1)*gsize) --
        # crosses pods iff gsize > pod_stride.  With a transpose the groups
        # stride across the fastest dims; conservatively flag as crossing
        # when the strided span exceeds a pod.
        if m.group(4) is None:
            return gsize > pod_stride
        return total > pod_stride
    return False


def collective_bytes(hlo_text: str, pod_stride: int = 256) -> Dict[str, int]:
    """Per-collective-kind operand bytes from (partitioned) HLO text.

    Also classifies bytes into intra-pod vs cross-pod by replica group
    (cross-pod = the slow links; the quantity pipeline/compression target).
    """
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    out["cross_pod"] = 0
    out["intra_pod"] = 0
    for line in hlo_text.splitlines():
        for kind in _COLLECTIVES:
            token = f" {kind}("
            idx = line.find(token)
            if idx < 0:
                # start-form async collectives: e.g. all-gather-start(
                token = f" {kind}-start("
                idx = line.find(token)
                if idx < 0:
                    continue
            # shapes inside the parens are the operands
            inner = line[idx + len(token):]
            depth = 1
            end = 0
            for end, ch in enumerate(inner):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
            operands = inner[:end]
            shapes = _SHAPE_RE.findall(operands)
            if not shapes:
                # fall back to the result shape (before the '=')
                shapes = _SHAPE_RE.findall(line[:idx])
            nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
            out[kind] += nbytes
            out["count"] += 1
            if _crosses_pod(line, pod_stride):
                out["cross_pod"] += nbytes
            else:
                out["intra_pod"] += nbytes
            break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: float) -> dict:
    compute_t = flops / PEAK_FLOPS
    memory_t = bytes_accessed / HBM_BW
    coll_t = coll_bytes / LINK_BW
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    dominant = max(terms, key=terms.get)
    bound = max(compute_t, memory_t, coll_t)
    terms.update({
        "dominant": dominant.replace("_s", ""),
        "bound_s": bound,
        "roofline_fraction": compute_t / bound if bound > 0 else 0.0,
    })
    return terms


def analyze_compiled(compiled) -> dict:
    """All roofline inputs from one jax compiled object (per-chip)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jax: one dict per device
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes": int(ma.argument_size_in_bytes
                              + ma.output_size_in_bytes
                              + ma.temp_size_in_bytes
                              - ma.alias_size_in_bytes),
        }
    return {
        "flops_per_chip": flops,
        "bytes_per_chip": bytes_acc,
        "collectives": coll,
        "memory": mem,
        "roofline": roofline_terms(flops, bytes_acc, coll["total"]),
    }
