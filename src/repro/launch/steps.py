"""jit-able step functions with their sharding contracts.

Factories close over (cfg, optimizer) and return pure functions suitable
for jax.jit with explicit in/out shardings:

  train_step(params, opt_state, batch)          -> (params, opt_state, metrics)
  serve_prefill(params, tokens[, frames])       -> (logits, caches)
  serve_step(params, caches, tokens, pos)       -> (logits, caches)

All parameters/optimizer state are donated by the trainer; metrics are
replicated scalars.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models.config import ModelConfig


def make_train_step(cfg: ModelConfig, optimizer, *, remat: bool = True,
                    grad_clip: Optional[float] = 1.0):
    def train_step(params, opt_state, batch, lr_scale=1.0):
        def loss_of(p):
            loss, metrics = tf.loss_fn(p, cfg, batch, remat=remat)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_of, has_aux=True)(params)

        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        if grad_clip is not None:
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
            grads = jax.tree.map(
                lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                grads)

        new_params, new_opt = optimizer.apply(params, grads, opt_state,
                                              lr_scale=lr_scale)
        out_metrics = {"loss": loss.astype(jnp.float32),
                       "ce": metrics["ce"].astype(jnp.float32),
                       "grad_norm": gnorm}
        return new_params, new_opt, out_metrics

    return train_step


def make_train_step_compressed(cfg: ModelConfig, optimizer, mesh, *,
                               remat: bool = True,
                               grad_clip: Optional[float] = 1.0):
    """Training step with int8 error-feedback gradient compression on the
    cross-pod axis (dist/compression.py).

    The loss/backward runs inside shard_map mapped over 'pod' only (data
    and model axes stay automatic, so FSDP/TP sharding is unchanged): each
    pod reduces its gradient intra-pod in f32, then pods exchange int8
    quantized gradients (1 B/elem on the slow inter-pod links instead of
    ~2x4 B/elem for a ring all-reduce) with an error-feedback residual
    carried in the optimizer loop.
    """
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.dist.compression import (CompressionState,
                                        compressed_cross_pod_mean)
    from repro.models import transformer as tf_mod

    def train_step(params, opt_state, batch, err_state, lr_scale=1.0):
        def pod_local(p, b, err):
            from repro.dist.sharding import set_manual_axes

            def loss_of(pp):
                loss, metrics = tf_mod.loss_fn(pp, cfg, b, remat=remat)
                return loss, metrics

            # 'pod' is Manual inside this shard_map: activation sharding
            # constraints must only mention the auto axes (trace-time flag).
            set_manual_axes({"pod"})
            try:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_of, has_aux=True)(p)
            finally:
                set_manual_axes(set())
            grads, new_state = compressed_cross_pod_mean(
                grads, CompressionState(err), "pod")
            loss = jax.lax.pmean(loss, "pod")
            ce = jax.lax.pmean(metrics["ce"], "pod")
            return loss, ce, grads, new_state.error

        batch_specs = jax.tree.map(lambda _: P("pod"), batch)
        rep = jax.tree.map(lambda _: P(), params)
        err_specs = jax.tree.map(lambda _: P(), err_state)
        from repro.compat import shard_map
        loss, ce, grads, new_err = shard_map(
            pod_local, mesh=mesh,
            in_specs=(rep, batch_specs, err_specs),
            out_specs=(P(), P(), rep, err_specs),
            axis_names={"pod"}, check_vma=False)(params, batch, err_state)

        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        if grad_clip is not None:
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
            grads = jax.tree.map(
                lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                grads)
        new_params, new_opt = optimizer.apply(params, grads, opt_state,
                                              lr_scale=lr_scale)
        metrics = {"loss": loss.astype(jnp.float32),
                   "ce": ce.astype(jnp.float32), "grad_norm": gnorm}
        return new_params, new_opt, metrics, new_err

    return train_step


def make_serve_prefill(cfg: ModelConfig, max_seq: int):
    def serve_prefill(params, tokens, frames=None):
        return tf.prefill(params, cfg, tokens, max_seq,
                          encoder_input=frames)
    return serve_prefill


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, caches, tokens, pos):
        return tf.decode_step(params, cfg, tokens, caches, pos)
    return serve_step
