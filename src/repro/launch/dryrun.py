import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds abstract parameter / optimizer / input
trees (jax.eval_shape -- nothing is allocated), attaches the production
shardings from dist/sharding.py, lowers the step function on the requested
mesh, compiles it, and records memory_analysis / cost_analysis / parsed
collective bytes into reports/dryrun/<cell>.json.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --all

The 512 placeholder host devices exist ONLY here (the env var above must
precede any jax import); smoke tests and benchmarks see 1 device.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.data.pipeline import synthetic_batch_specs
from repro.dist.sharding import (batch_sharding, cache_shardings,
                                 logical_param_specs, param_shardings)
from repro.launch import hlo_analysis
from repro.launch.mesh import describe, make_production_mesh
from repro.launch.steps import (make_serve_prefill, make_serve_step,
                                make_train_step)
from repro.models import transformer as tf
from repro.optim.optimizers import adafactor, adamw

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")

# Large cells use Adafactor (factored second moment) to fit HBM; see
# DESIGN.md / EXPERIMENTS.md for the accounting.
_BIG_ARCHS = {"llama4-maverick-400b-a17b", "dbrx-132b", "deepseek-67b",
              "qwen2-vl-72b", "zamba2-7b"}


def _optimizer_for(arch: str):
    if arch in _BIG_ARCHS:
        return adafactor(lr=1e-3)
    return adamw(lr=3e-4)


def _abstract(fn, *args, **kw):
    # Close over everything (configs, SDS pytrees): eval_shape of a thunk.
    return jax.eval_shape(lambda: fn(*args, **kw))


def build_cell(arch: str, shape_name: str, mesh, layer_override=None,
               variant: str = "base"):
    """Returns (fn, arg_shapes, in_shardings, out_shardings, meta).

    variant: perf-iteration step functions (EXPERIMENTS.md section Perf):
      base       -- the paper-faithful production configuration
      sp         -- Megatron sequence-parallel residual stream (P7)
      compressed -- int8 error-feedback cross-pod gradient reduction (P6)
      pipeline   -- GPipe pipeline over the 'pod' axis (P8)
    """
    import dataclasses
    cfg = get_config(arch)
    if layer_override is not None:
        repl = {"num_layers": layer_override, "scan_unroll": True}
        if cfg.is_encdec:
            repl["encoder_layers"] = layer_override
        cfg = dataclasses.replace(cfg, **repl)
    shape = SHAPES[shape_name]
    rng = jax.random.PRNGKey(0)

    params_s = _abstract(tf.init_model, rng, cfg)
    p_sh = param_shardings(params_s, mesh)

    if shape.kind == "train":
        opt = _optimizer_for(arch)
        opt_s = _abstract(opt.init, params_s)
        # optimizer state mirrors param sharding where shapes match; let
        # scalar counts replicate and factored stats follow params' specs.
        o_sh = _opt_shardings(opt_s, params_s, p_sh, mesh)
        batch_s = synthetic_batch_specs(cfg, shape)
        b_sh = {k: batch_sharding(mesh, shape.global_batch, v.ndim)
                for k, v in batch_s.items()}
        if variant == "compressed":
            from repro.launch.steps import make_train_step_compressed
            err_s = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32),
                params_s)
            e_sh = p_sh
            step = make_train_step_compressed(cfg, opt, mesh, remat=True)
            in_shardings = (p_sh, o_sh, b_sh, e_sh)
            out_shardings = (p_sh, o_sh, None, e_sh)
            args = (params_s, opt_s, batch_s, err_s)
        elif variant == "pipeline":
            from repro.launch.pipeline import make_pipelined_train_step
            n_stages = mesh.shape.get("pod", 2)

            # Stage ownership: shard the layer-stack dim over 'pod' so each
            # pod holds (and reduces gradients for) only its own stage --
            # this is what removes the cross-pod grad all-reduce.
            def _stage_shard(sh_tree, like_tree):
                def fix(sh, like):
                    if like.ndim >= 1 and like.shape[0] == cfg.num_layers \
                            and cfg.num_layers % n_stages == 0:
                        spec = list(sh.spec) + [None] * (like.ndim - len(sh.spec))
                        spec[0] = "pod"
                        return NamedSharding(mesh, P(*spec))
                    return sh
                return jax.tree.map(fix, sh_tree, like_tree)

            p_sh = {**p_sh, "layers": _stage_shard(p_sh["layers"],
                                                   params_s["layers"])}
            o_sh = _opt_shardings(opt_s, params_s, p_sh, mesh)
            step = make_pipelined_train_step(cfg, opt, n_stages=n_stages,
                                             n_micro=4, remat=True)
            # batch over 'data' only: 'pod' is the stage axis here.
            b_sh = {k: NamedSharding(mesh, P(("data",) if v.ndim else None,
                                             *([None] * (v.ndim - 1))))
                    for k, v in batch_s.items()}
            in_shardings = (p_sh, o_sh, b_sh)
            out_shardings = (p_sh, o_sh, None)
            args = (params_s, opt_s, batch_s)
        else:
            step = make_train_step(cfg, opt, remat=True)
            in_shardings = (p_sh, o_sh, b_sh)
            out_shardings = (p_sh, o_sh, None)
            args = (params_s, opt_s, batch_s)
        fn = step
    elif shape.kind == "prefill":
        batch_s = synthetic_batch_specs(cfg, shape)
        tokens_s = batch_s["tokens"]
        b_sh = batch_sharding(mesh, shape.global_batch, 2)
        fn0 = make_serve_prefill(cfg, max_seq=shape.seq_len)
        if cfg.is_encdec:
            frames_s = batch_s["frames"]
            f_sh = batch_sharding(mesh, shape.global_batch, 3)
            args = (params_s, tokens_s, frames_s)
            in_shardings = (p_sh, b_sh, f_sh)
        else:
            args = (params_s, tokens_s)
            in_shardings = (p_sh, b_sh)
        out_shardings = None
        fn = fn0
    else:  # decode
        B = shape.global_batch
        cache_s = _abstract(tf.init_cache, params_s, cfg, B, shape.seq_len)
        if cfg.is_encdec:
            # cross-attn caches exist only after prefill; build their specs
            enc_kv = {
                "k": jax.ShapeDtypeStruct(
                    (B, cfg.encoder_seq_len, cfg.num_kv_heads, cfg.head_dim),
                    jnp.dtype(cfg.compute_dtype)),
                "v": jax.ShapeDtypeStruct(
                    (B, cfg.encoder_seq_len, cfg.num_kv_heads, cfg.head_dim),
                    jnp.dtype(cfg.compute_dtype)),
            }
            stack = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct((cfg.num_layers,) + l.shape,
                                               l.dtype), enc_kv)
            cache_s = {"self": cache_s["self"], "cross": stack}
        c_sh = cache_shardings(cache_s, cfg, mesh, B)
        tokens_s = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        t_sh = batch_sharding(mesh, B, 2)
        pos_s = jax.ShapeDtypeStruct((), jnp.int32)
        fn = make_serve_step(cfg)
        args = (params_s, cache_s, tokens_s, pos_s)
        in_shardings = (p_sh, c_sh, t_sh, NamedSharding(mesh, P()))
        out_shardings = (None, c_sh)

    meta = {"arch": arch, "shape": shape_name, "mesh": describe(mesh),
            "params": int(cfg.num_params()),
            "active_params": int(cfg.active_params()),
            "seq_len": shape.seq_len, "global_batch": shape.global_batch,
            "kind": shape.kind}
    return fn, args, in_shardings, out_shardings, meta


def _opt_shardings(opt_s, params_s, p_sh, mesh):
    """Optimizer-state shardings: match the parameter's sharding when the
    leaf shape equals the param shape (adam m/v); shard factored stats by
    their surviving dims; replicate scalars."""
    shape_to_sh = {}
    for pl, sl in zip(jax.tree.leaves(params_s), jax.tree.leaves(p_sh)):
        shape_to_sh.setdefault(pl.shape, sl)

    rep = NamedSharding(mesh, P())

    def pick(leaf):
        if leaf.shape in shape_to_sh:
            return shape_to_sh[leaf.shape]
        return rep

    return jax.tree.map(pick, opt_s)


def _compile_cell(arch, shape_name, mesh, layer_override=None,
                  variant="base"):
    fn, args, in_sh, out_sh, meta = build_cell(
        arch, shape_name, mesh, layer_override=layer_override,
        variant=variant)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        compiled = jitted.lower(*args).compile()
    return compiled, meta


def _calibrate_layers(arch, shape_name, mesh, cfg, variant="base") -> dict:
    """XLA's cost_analysis counts while-loop bodies ONCE, so the scanned
    layer stack is undercounted by ~L.  Compile two small *fully unrolled*
    variants (cfg.scan_unroll) -- unrolled graphs are counted completely --
    and extrapolate: metric(L) = fixed + L * per_layer.

    For the hybrid arch the unit is one (mamba-group + shared-attn) group.
    Returns per-step flops/bytes/collective-bytes corrected to the real L.
    """
    if cfg.family == "hybrid":
        unit = cfg.hybrid_attn_every
        l1, l2 = 2 * unit, 4 * unit
        n_units = cfg.num_layers / unit
        u1, u2 = 2, 4
    else:
        l1, l2 = 2, 4
        n_units = cfg.num_layers
        u1, u2 = 2, 4

    metrics = []
    for lo in (l1, l2):
        compiled, _ = _compile_cell(arch, shape_name, mesh, layer_override=lo,
                                    variant=variant)
        a = hlo_analysis.analyze_compiled(compiled)
        metrics.append((a["flops_per_chip"], a["bytes_per_chip"],
                        a["collectives"]["total"]))
    per_unit = [(m2 - m1) / (u2 - u1) for m1, m2 in zip(*metrics)]
    fixed = [m1 - u1 * d for m1, d in zip(metrics[0], per_unit)]
    corrected = [f + n_units * d for f, d in zip(fixed, per_unit)]
    return {
        "flops_per_chip": max(corrected[0], 0.0),
        "bytes_per_chip": max(corrected[1], 0.0),
        "collective_bytes": max(corrected[2], 0.0),
        "per_layer": {"flops": per_unit[0], "bytes": per_unit[1],
                      "collective": per_unit[2]},
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             report_dir: str = REPORT_DIR, calibrate: bool = True,
             variant: str = "base") -> dict:
    from repro.dist.sharding import set_activation_mesh, set_sequence_parallel
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_activation_mesh(mesh)
    set_sequence_parallel(variant == "sp")
    t0 = time.time()
    fn, args, in_sh, out_sh, meta = build_cell(arch, shape_name, mesh,
                                               variant=variant)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        analysis = hlo_analysis.analyze_compiled(compiled)

    report = {**meta, "multi_pod": multi_pod,
              "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
              **analysis}

    if calibrate:
        try:
            cfg = get_config(arch)
            cal = _calibrate_layers(arch, shape_name, mesh, cfg,
                                    variant=variant)
            report["calibrated"] = cal
            report["roofline_calibrated"] = hlo_analysis.roofline_terms(
                cal["flops_per_chip"], cal["bytes_per_chip"],
                cal["collective_bytes"])
        except Exception as e:  # calibration is best-effort
            report["calibration_error"] = repr(e)

    report["variant"] = variant
    os.makedirs(report_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    if variant != "base":
        tag += f"__{variant}"
    with open(os.path.join(report_dir, tag + ".json"), "w") as f:
        json.dump(report, f, indent=1)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="base",
                    choices=["base", "sp", "compressed", "pipeline"])
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--report-dir", default=REPORT_DIR)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            if not shape_applicable(cfg, SHAPES[shape_name]):
                print(f"SKIP {arch} x {shape_name} (long-context rule)")
                continue
            for mp in meshes:
                tag = (f"{arch} x {shape_name} x {'2pod' if mp else '1pod'}"
                       + (f" [{args.variant}]" if args.variant != "base" else ""))
                try:
                    rep = run_cell(arch, shape_name, mp, args.report_dir,
                                   calibrate=not args.no_calibrate,
                                   variant=args.variant)
                    r = rep["roofline"]
                    mem = rep["memory"].get("peak_bytes", 0) / 2**30
                    print(f"OK   {tag}: compile={rep['compile_s']:.0f}s "
                          f"peak={mem:.2f}GiB/chip "
                          f"dominant={r['dominant']} "
                          f"frac={r['roofline_fraction']:.2f}", flush=True)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"FAIL {tag}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES"); raise SystemExit(1)
    print("\nALL CELLS PASSED")


if __name__ == "__main__":
    main()
