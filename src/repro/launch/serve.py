"""Batched serving driver: prefill + greedy decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --batch 4 --prompt-len 32 --gen 16

Exercises the same serve_prefill/serve_step functions the dry-run lowers
for the decode_32k / long_500k cells.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.launch.steps import make_serve_prefill, make_serve_step
from repro.models import transformer as tf


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rng = jax.random.PRNGKey(args.seed)
    params = tf.init_model(rng, cfg)

    max_seq = args.prompt_len + args.gen
    if cfg.family == "hybrid" or cfg.family == "ssm":
        # chunked SSD wants seq % chunk == 0 at prefill
        pl = max(args.prompt_len - args.prompt_len % cfg.ssm_chunk,
                 cfg.ssm_chunk)
    else:
        pl = args.prompt_len
    tokens = jax.random.randint(rng, (args.batch, pl), 0, cfg.vocab_size)
    frames = None
    if cfg.is_encdec:
        frames = jax.random.normal(
            rng, (args.batch, cfg.encoder_seq_len, cfg.d_model))

    prefill = jax.jit(make_serve_prefill(cfg, max_seq))
    step = jax.jit(make_serve_step(cfg))

    t0 = time.time()
    if frames is not None:
        logits, caches = prefill(params, tokens, frames)
    else:
        logits, caches = prefill(params, tokens)
    nxt = jnp.argmax(logits[:, -1:], axis=-1)
    out = [nxt]
    t_prefill = time.time() - t0

    t0 = time.time()
    for i in range(args.gen - 1):
        logits, caches = step(params, caches, nxt,
                              jnp.asarray(pl + i, jnp.int32))
        nxt = jnp.argmax(logits, axis=-1)
        out.append(nxt)
    jax.block_until_ready(nxt)
    t_decode = time.time() - t0

    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] arch={cfg.name} prefill({pl} toks)={t_prefill:.2f}s "
          f"decode={t_decode:.2f}s ({tps:.1f} tok/s)")
    print(f"[serve] sample generated ids: {gen[0][:12].tolist()}")
    return gen


if __name__ == "__main__":
    main()
