"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 200 --batch 8 --seq 128 --smoke

Wires together every substrate layer: config -> model -> sharded train
step (pjit) -> data pipeline -> checkpoint manager (atomic, auto-resume)
-> watchdog + straggler monitor -> spectral governor (the paper's
eigenvalue-only workflow driving the LR).

On the CPU container this runs reduced configs end-to-end (--smoke); on a
TPU cluster the same driver runs the full configs against
make_production_mesh().
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS, get_config, get_smoke_config
from repro.data import DataPipeline, SyntheticTokens
from repro.dist.sharding import (batch_sharding, param_shardings,
                                 set_activation_mesh)
from repro.launch.mesh import make_mesh_for
from repro.launch.steps import make_train_step
from repro.models import transformer as tf
from repro.optim.optimizers import get_optimizer
from repro.optim.spectral_adapt import SpectralGovernor
from repro.runtime import StragglerMonitor, Watchdog
from repro.spectral import make_hvp, slq_spectrum


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor", "sgd"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--spectral-every", type=int, default=0,
                    help="probe curvature every N steps (0 = off)")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n_dev = len(jax.devices())
    mesh = make_mesh_for(n_dev) if n_dev > 1 else None
    if mesh is not None:
        set_activation_mesh(mesh)

    rng = jax.random.PRNGKey(args.seed)
    params = tf.init_model(rng, cfg)
    opt = get_optimizer(args.optimizer, lr=args.lr)
    opt_state = opt.init(params)

    step_fn = make_train_step(cfg, opt, remat=args.remat)
    if mesh is not None:
        p_sh = param_shardings(params, mesh)
        o_sh = jax.tree.map(
            lambda l: p_sh if False else None, opt_state)  # infer
        b_sh = batch_sharding(mesh, args.batch)
        jitted = jax.jit(step_fn, in_shardings=(p_sh, None, None),
                         donate_argnums=(0, 1))
        params = jax.device_put(params, p_sh)
    else:
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    # --- data -------------------------------------------------------------
    extra_fn = None
    if cfg.is_encdec:
        def extra_fn(step, shard, bsz):
            r = np.random.default_rng(np.random.SeedSequence([7, step, shard]))
            return {"frames": r.standard_normal(
                (bsz, cfg.encoder_seq_len, cfg.d_model)).astype(np.float32)}
    pipe = DataPipeline(
        SyntheticTokens(cfg.vocab_size, args.seq, seed=args.seed),
        global_batch=args.batch, extra_fn=extra_fn).start()

    # --- fault tolerance ---------------------------------------------------
    ckpt = CheckpointManager(args.ckpt_dir, period=args.ckpt_every)
    restored, meta, start_step = ckpt.resume((params, opt_state))
    if restored is not None:
        params, opt_state = restored
        print(f"[train] resumed from step {start_step}")
    watchdog = Watchdog(args.ckpt_dir + "/heartbeat.json",
                        timeout_s=600).start()
    straggler = StragglerMonitor()
    governor = SpectralGovernor(period=max(args.spectral_every, 1))

    it = iter(pipe)
    lr_scale = 1.0
    losses = []
    for step in range(start_step, args.steps):
        batch = next(it)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        t0 = time.time()
        params, opt_state, metrics = jitted(params, opt_state, batch,
                                            lr_scale)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        straggler.record(step, dt)
        watchdog.beat(step, loss=loss)
        losses.append(loss)

        if args.spectral_every and step and step % args.spectral_every == 0:
            # Eigenvalue-only curvature probe (paper's workflow): SLQ with
            # BR as the tridiagonal eigensolver.
            def loss_of(p):
                return tf.loss_fn(p, cfg, batch)[0]
            hvp = make_hvp(loss_of, params)
            est = slq_spectrum(hvp, params, jax.random.fold_in(rng, step),
                               num_probes=1, num_steps=8)
            lr_scale = governor.update(est.lam_max)
            print(f"[spectral] step={step} lam_max={est.lam_max:.3e} "
                  f"lr_scale={lr_scale:.3f}")

        ckpt.maybe_save(step + 1, (params, opt_state),
                        meta={"loss": loss})
        if step % args.log_every == 0:
            print(f"step={step:5d} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms",
                  flush=True)

    pipe.stop()
    watchdog.stop()
    print(f"[train] done. loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"straggler report: {straggler.report()}")
    return losses


if __name__ == "__main__":
    main()
