"""Pipeline parallelism over the 'pod' mesh axis (GPipe schedule, SPMD-native).

Motivation (EXPERIMENTS.md P8): on the 2x16x16 mesh, tensor/expert
collectives and gradient reductions that cross the pod boundary ride the
slow inter-pod links and dominate the roofline for the MoE training cells.
Pipelining the *layer* dimension across pods replaces all cross-pod tensor
traffic with one boundary-activation transfer per microbatch per step.

Realization without shard_map: the classic stage-stacked formulation --

    state  : (n_stages, micro_b, S, D)   with stage axis sharded over 'pod'
    step t : every stage applies its layer block to its resident
             microbatch (vmap over the stage axis = stage parallelism),
             then the buffer shifts by one stage (jnp.concatenate of a
             shifted slice -> XLA emits a collective-permute across pods).

GPipe schedule: T = n_micro + n_stages - 1 ticks; stage 0 injects
microbatch t while the last stage retires microbatch t-(n_stages-1).
Bubble fraction = (n_stages-1)/T.

Known simplification: MoE router aux-loss contributions from bubble ticks
(zero activations) are excluded by masking the collected outputs only; aux
is reported unmasked (documented; affects no dry-run metric).
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.dist.sharding import get_activation_mesh
from repro.models import layers as nn
from repro.models import transformer as tf
from repro.models.config import ModelConfig


def _constrain_stage(x):
    """Pin (stage, micro_batch, ...) to ('pod', dp)."""
    mesh = get_activation_mesh()
    if mesh is None or "pod" not in mesh.shape:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = [None] * x.ndim
    if x.shape[0] % mesh.shape["pod"] == 0:
        spec[0] = "pod"
    if x.ndim > 1 and "data" in mesh.shape and x.shape[1] % mesh.shape["data"] == 0:
        spec[1] = "data"
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def pipeline_forward(params, cfg: ModelConfig, tokens, *, n_stages: int,
                     n_micro: int, remat: bool = True):
    """Decoder-only forward with the layer stack pipelined over stages.

    Returns (logits, aux).  Requires num_layers % n_stages == 0 and
    batch % n_micro == 0.  Exactly equivalent to tf.forward (bubbles
    compute on zeros but their outputs are never collected).
    """
    from repro.dist.sharding import set_manual_axes

    B, S = tokens.shape
    assert cfg.num_layers % n_stages == 0, (cfg.num_layers, n_stages)
    assert B % n_micro == 0, (B, n_micro)
    per_stage = cfg.num_layers // n_stages
    mb = B // n_micro

    # Inside the pipeline, 'pod' is the STAGE axis, not a data-parallel
    # axis: activation constraints must only use 'data', otherwise the
    # microbatch reshape forces cross-pod regathers of the batch.
    set_manual_axes({"pod"})

    x = tf._embed(params, cfg, tokens)                  # (B, S, D)
    x = x.reshape(n_micro, mb, S, D := x.shape[-1])
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (mb, S))

    stage_params = jax.tree.map(
        lambda a: a.reshape((n_stages, per_stage) + a.shape[1:]),
        params["layers"])

    def stage_fn(sp, xs):
        def inner(carry, lp):
            xx, aux = carry
            xx, a = tf._attn_block(lp, cfg, xx, positions)
            return (xx, aux + a), None
        inner = jax.checkpoint(inner) if remat else inner
        (xs, aux), _ = jax.lax.scan(inner, (xs, jnp.asarray(0.0)), sp)
        return xs, aux

    zero_mb = jnp.zeros((mb, S, D), x.dtype)

    def tick(carry, t):
        state, outputs, aux = carry
        inject = jnp.where(t < n_micro,
                           x[jnp.minimum(t, n_micro - 1)], zero_mb)
        shifted = jnp.concatenate([inject[None], state[:-1]], axis=0)
        shifted = _constrain_stage(shifted)
        new_state, aux_t = jax.vmap(stage_fn)(stage_params, shifted)
        new_state = _constrain_stage(new_state)
        out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
        retired = jnp.where(t >= n_stages - 1, new_state[-1],
                            outputs[out_idx])
        outputs = outputs.at[out_idx].set(retired)
        return (new_state, outputs, aux + jnp.sum(aux_t)), None

    state0 = jnp.zeros((n_stages, mb, S, D), x.dtype)
    outputs0 = jnp.zeros((n_micro, mb, S, D), x.dtype)
    (state, outputs, aux), _ = jax.lax.scan(
        tick, (state0, outputs0, jnp.asarray(0.0)),
        jnp.arange(n_micro + n_stages - 1))

    x_out = outputs.reshape(B, S, D)
    logits = tf._unembed(params, cfg, x_out)
    set_manual_axes(set())
    return logits, aux


def pipeline_loss_fn(params, cfg: ModelConfig, batch, *, n_stages: int,
                     n_micro: int, remat: bool = True):
    logits, aux = pipeline_forward(params, cfg, batch["tokens"],
                                   n_stages=n_stages, n_micro=n_micro,
                                   remat=remat)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    ce = jnp.mean(lse - gold)
    return ce + aux, {"ce": ce}


def make_pipelined_train_step(cfg: ModelConfig, optimizer, *,
                              n_stages: int, n_micro: int,
                              remat: bool = True, grad_clip: float = 1.0):
    def train_step(params, opt_state, batch, lr_scale=1.0):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: pipeline_loss_fn(p, cfg, batch, n_stages=n_stages,
                                       n_micro=n_micro, remat=remat),
            has_aux=True)(params)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(
            lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)
        new_params, new_opt = optimizer.apply(params, grads, opt_state,
                                              lr_scale=lr_scale)
        return new_params, new_opt, {"loss": loss.astype(jnp.float32),
                                     "grad_norm": gnorm}
    return train_step
