"""Sharding rules for the (pod, data, model) production meshes.

Parameters carry *logical* axis names derived from their leaf name in the
param pytree (layers.py documents the layout convention, e.g. wq:
(d_model, heads, head_dim)).  `logical_param_specs` maps those logical
axes onto mesh axes:

    d_model-like dims  -> "data"   (FSDP: parameters sharded over the DP axis)
    heads / ffn / V    -> "model"  (tensor parallel)

Any dim whose size does not divide the mesh-axis extent is *pruned* to
replicated (`_prune`) -- sharding is a best-effort layout hint, never a
correctness requirement.

Activation constraints (`constrain_batch_acts`, `constrain_seq_model_acts`)
are trace-time switches: they no-op until `set_activation_mesh` installs a
mesh, so smoke tests and single-device benchmarks run the exact same model
code with zero sharding overhead.  Inside `shard_map` regions whose axes
are Manual, constraints must not mention those axes -- `set_manual_axes`
is the flag steps.py/pipeline.py flip around their mapped bodies.
"""

from __future__ import annotations

import math
from typing import Any, Iterable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Trace-time activation state
# ---------------------------------------------------------------------------

_ACTIVATION_MESH: Optional[Any] = None
_SEQUENCE_PARALLEL: bool = False
_MANUAL_AXES: frozenset = frozenset()


def set_activation_mesh(mesh) -> None:
    """Install (or clear, with None) the mesh used by activation constraints."""
    global _ACTIVATION_MESH
    _ACTIVATION_MESH = mesh


def get_activation_mesh():
    return _ACTIVATION_MESH


def set_sequence_parallel(enabled: bool) -> None:
    """Megatron-style sequence parallelism: the residual stream's seq dim is
    sharded over 'model' between blocks (variant "sp" in dryrun)."""
    global _SEQUENCE_PARALLEL
    _SEQUENCE_PARALLEL = bool(enabled)


def set_manual_axes(axes: Iterable[str]) -> None:
    """Mesh axes currently Manual (inside a shard_map body): activation
    constraints traced while this is set must not reference them."""
    global _MANUAL_AXES
    _MANUAL_AXES = frozenset(axes)


def model_axis_extent() -> int:
    """Extent of the tensor-parallel axis in the activation mesh (1 if unset)."""
    mesh = _ACTIVATION_MESH
    if mesh is None or "model" in _MANUAL_AXES:
        return 1
    return int(mesh.shape.get("model", 1))


def dp_axis_extent() -> int:
    """Product of the data-parallel-like extents ('pod' * 'data') visible to
    the current trace (Manual axes excluded).  1 on a single device."""
    mesh = _ACTIVATION_MESH
    if mesh is None:
        return 1
    ext = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape and ax not in _MANUAL_AXES:
            ext *= int(mesh.shape[ax])
    return ext


# ---------------------------------------------------------------------------
# Parameter sharding rules
# ---------------------------------------------------------------------------

def _extent(mesh, axis) -> int:
    """Mesh extent of a spec entry (a name or a tuple of names)."""
    names = axis if isinstance(axis, tuple) else (axis,)
    return math.prod(int(mesh.shape[a]) for a in names)


def _prune(axes, shape, mesh):
    """Drop (replace with None) any sharded dim whose size does not divide
    the mesh extent, or whose axis is absent from the mesh."""
    out = []
    for ax, dim in zip(axes, shape):
        if ax is None:
            out.append(None)
            continue
        names = ax if isinstance(ax, tuple) else (ax,)
        if any(a not in mesh.shape for a in names):
            out.append(None)
            continue
        out.append(ax if dim % _extent(mesh, ax) == 0 else None)
    return tuple(out)


# Trailing-dims rule per leaf name (layers.py layout convention).  Leaves
# may carry extra *leading* dims (the scanned layer axis, MoE expert axis);
# those replicate.  Unknown names replicate entirely.
_NAME_RULES = {
    # token embedding (V, D) / LM head (D, V)
    "embed": ("model", "data"),
    "head": ("data", "model"),
    # attention projections (d_model, heads, head_dim) / (H, hd, d_model)
    "wq": ("data", "model", None),
    "wk": ("data", "model", None),
    "wv": ("data", "model", None),
    "wo": ("model", None, "data"),
    # MLA low-rank factors
    "wq_a": ("data", "model"),
    "wq_b": ("data", "model", None),
    "wkv_a": ("data", "model"),
    "wk_b": ("data", "model", None),
    "wv_b": ("data", "model", None),
    # dense / MoE MLP (d, f) and (f, d); MoE adds a leading expert dim
    "w_gate": ("data", "model"),
    "w_up": ("data", "model"),
    "w_down": ("model", "data"),
    # mamba2 projections (d, proj) / (dn, d)
    "w_in": ("data", "model"),
    "w_out": ("model", "data"),
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            return key
    return ""


def logical_param_specs(params, mesh):
    """PartitionSpec pytree for a parameter pytree (shapes or arrays)."""
    def spec_for(path, leaf):
        rule = _NAME_RULES.get(_leaf_name(path))
        ndim = len(leaf.shape)
        if rule is None or ndim < len(rule):
            return P()
        axes = (None,) * (ndim - len(rule)) + tuple(rule)
        return P(*_prune(axes, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(spec_for, params)


def param_shardings(params, mesh):
    """NamedSharding pytree matching `logical_param_specs`."""
    return jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                        logical_param_specs(params, mesh),
                        is_leaf=lambda x: isinstance(x, P))


def _dp_axes(mesh, size: int):
    """Best data-parallel spec entry for a dim of `size`: ('pod','data'),
    'data', or None -- largest divisible combination wins."""
    cands = []
    if "pod" in mesh.shape and "data" in mesh.shape:
        cands.append(("pod", "data"))
    if "data" in mesh.shape:
        cands.append("data")
    if "pod" in mesh.shape:
        cands.append("pod")
    for c in cands:
        if size % _extent(mesh, c) == 0 and _extent(mesh, c) > 1:
            return c
    return None


def batch_sharding(mesh, global_batch: int, ndim: int = 2):
    """Batch-first sharding for input/token arrays: dim 0 over the DP axes
    (when divisible), everything else replicated."""
    spec = [None] * ndim
    if ndim:
        spec[0] = _dp_axes(mesh, global_batch)
    return NamedSharding(mesh, P(*spec))


def cache_shardings(cache, cfg, mesh, batch: int):
    """KV / SSM-state cache shardings: the batch dim (first dim of size
    `batch`, searching from the left) goes over the DP axes; a kv-heads dim
    (== cfg.num_kv_heads, right of batch) goes over 'model'.  Leaves with
    no recognizable batch dim replicate."""
    kv_heads = getattr(cfg, "num_kv_heads", 0)

    def spec_for(leaf):
        spec = [None] * len(leaf.shape)
        b_at = None
        for i, dim in enumerate(leaf.shape):
            if dim == batch and i <= 1:
                b_at = i
                spec[i] = _dp_axes(mesh, batch)
                break
        if b_at is not None and kv_heads and "model" in mesh.shape:
            for i in range(b_at + 1, len(leaf.shape)):
                if leaf.shape[i] == kv_heads and \
                        kv_heads % _extent(mesh, "model") == 0 and \
                        _extent(mesh, "model") > 1:
                    spec[i] = "model"
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(spec_for, cache)


# ---------------------------------------------------------------------------
# Activation constraints
# ---------------------------------------------------------------------------

def _constrain(x, spec):
    mesh = _ACTIVATION_MESH
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def _visible_dp_axes(mesh, size: int):
    names = tuple(a for a in ("pod", "data")
                  if a in mesh.shape and a not in _MANUAL_AXES)
    while names and size % _extent(mesh, names):
        names = names[1:]
    if not names or _extent(mesh, names) == 1:
        return None
    return names if len(names) > 1 else names[0]


def constrain_batch_acts(x):
    """Pin an activation's batch dim to the visible data-parallel axes.
    With sequence parallelism on, 3-D+ activations also pin seq->'model'."""
    mesh = _ACTIVATION_MESH
    if mesh is None:
        return x
    if _SEQUENCE_PARALLEL and x.ndim >= 3:
        return constrain_seq_model_acts(x)
    spec = [None] * x.ndim
    spec[0] = _visible_dp_axes(mesh, x.shape[0])
    return _constrain(x, spec)


# ---------------------------------------------------------------------------
# Solver-tree collectives (distributed conquer, core/br_dc.py)
# ---------------------------------------------------------------------------
#
# The eigensolver's 1-D mesh has a single axis named SOLVER_AXIS; each
# device owns one contiguous slice of the tridiagonal.  Because the
# conquer phase carries only O(n) state (eigenvalues + r boundary rows),
# every cross-device transfer below is linear in the slice size: a
# one-element halo in the divide step and a single all-gather of the
# per-shard (lam, rows) state at the subtree->cooperative transition.

SOLVER_AXIS = "shard"


def halo_from_left(x, size: int, axis_name: str = SOLVER_AXIS):
    """Shift `x` one shard to the right along the solver axis.

    ``size`` is the static axis extent (older jax has no lax.axis_size).
    Device p receives device p-1's value; device 0 receives zeros (the
    ppermute fill), which is exactly right for the divide step's
    left-edge coupling -- the global problem has no boundary left of
    shard 0.
    """
    perm = [(i, i + 1) for i in range(size - 1)]
    return jax.lax.ppermute(x, axis_name, perm)


def gather_lanes(x, axis_name: str = SOLVER_AXIS):
    """All-gather per-shard trailing lanes into global order.

    x: (B, k) per device -> (B, P * k), with shard p's lanes occupying
    columns [p*k, (p+1)*k) -- the global node order of the D&C tree,
    since shard-local nodes are contiguous in it.
    """
    g = jax.lax.all_gather(x, axis_name)            # (P, B, k)
    return jnp.moveaxis(g, 0, 1).reshape(x.shape[0], -1)


def gather_tree_state(lam_loc, rows_loc, axis_name: str = SOLVER_AXIS,
                      *, compress: bool = False):
    """Gather the O(n) subtree state into replicated node-major layout.

    lam_loc: (B, Np); rows_loc: (B, r, Np) -- one device's subtree root.
    Returns (lam (B, P, Np), rows (B, P, r, Np)) replicated on every
    device, the node axis ordered by shard index.

    With ``compress=True`` the boundary rows travel as int8 + one f32
    scale per (problem, slot) lane (`dist.compression.quantize_lanes`);
    eigenvalues always travel at full precision -- they seed the secular
    poles, where a quantization ulp would perturb every root.  The halo
    is a one-shot transfer, so the error-feedback residual the gradient
    path carries across steps has nowhere to accumulate here; the bias
    is bounded by a single quantization step.
    """
    from repro.dist import compression as _comp

    lam_g = jnp.moveaxis(jax.lax.all_gather(lam_loc, axis_name), 0, 1)
    if compress:
        q, scale = _comp.quantize_lanes(rows_loc)
        q_g, scale_g = jax.lax.all_gather((q, scale), axis_name)
        rows_g = _comp.dequantize_lanes(q_g, scale_g, rows_loc.dtype)
        rows_g = jnp.moveaxis(rows_g, 0, 1)
    else:
        rows_g = jnp.moveaxis(jax.lax.all_gather(rows_loc, axis_name), 0, 1)
    return lam_g, rows_g


def constrain_seq_model_acts(x):
    """(B, S, ...) activations: batch over DP axes, seq over 'model' --
    used when heads don't divide the TP extent (and for sequence-parallel
    residual streams)."""
    mesh = _ACTIVATION_MESH
    if mesh is None:
        return x
    spec = [None] * x.ndim
    spec[0] = _visible_dp_axes(mesh, x.shape[0])
    if x.ndim >= 2 and "model" in mesh.shape and "model" not in _MANUAL_AXES \
            and x.shape[1] % _extent(mesh, "model") == 0:
        spec[1] = "model"
    return _constrain(x, spec)
