"""int8 error-feedback gradient compression for the cross-pod axis.

Inter-pod links are an order of magnitude slower than intra-pod ICI, so
the cross-pod gradient reduction exchanges int8-quantized tensors (1 B/elem
on the wire plus one f32 scale per tensor) instead of raw f32.  The
quantization residual is *carried*, not dropped: each step adds the
previous step's residual back into the gradient before quantizing
(error feedback), so the compression bias stays bounded by one step's
quantization error instead of accumulating.

Here the dequantized values feed `lax.pmean` directly -- numerically
identical to wiring int8 payload + per-pod scale through the collective,
which is what a hardware backend would lower it to.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

_QMAX = 127.0


class CompressionState(NamedTuple):
    error: Any   # pytree matching the grads, f32 residual per tensor


def init_compression_state(grads) -> CompressionState:
    """Zero residual state shaped like the gradient pytree."""
    return CompressionState(
        jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def _compress_one(g, err, axis_name):
    g32 = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(g32)) / _QMAX
    scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(g32 / scale), -_QMAX, _QMAX).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    mean = jax.lax.pmean(deq, axis_name)
    return mean.astype(g.dtype), g32 - deq


def quantize_lanes(x):
    """Stateless int8 quantization over the last axis, one f32 scale per
    leading-dims lane.  Used by the solver's boundary-row halo
    (`dist.sharding.gather_tree_state`), where the transfer is one-shot
    and there is no next step to carry a residual into.

    The f32 staging here is *intentional*, not a weak-typing leak: the
    int8 payload carries < 8 bits of mantissa, so an f32 scale already
    over-represents it for every input dtype (f64 included), and
    ``dequantize_lanes`` restores the caller's dtype explicitly -- the
    sharded f32 (mixed-precision) tree round-trips without any silent
    f64 promotion (pinned by dtype asserts in tests/test_mixed.py)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32), axis=-1, keepdims=True) / _QMAX
    scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(x32 / scale), -_QMAX, _QMAX).astype(jnp.int8)
    return q, scale


def dequantize_lanes(q, scale, dtype=jnp.float32):
    """Inverse of `quantize_lanes` (up to the quantization error)."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_cross_pod_mean(grads, state: CompressionState, axis_name: str):
    """Mean of `grads` over `axis_name` via int8 + error feedback.

    Must be called inside a shard_map/pmap body where `axis_name` is a
    mapped axis.  Returns (mean_grads, new_state); `mean + new_state.error`
    reconstructs the local pre-quantization gradient exactly.
    """
    pairs = jax.tree.map(lambda g, e: _compress_one(g, e, axis_name),
                         grads, state.error)
    mean = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda p: p[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return mean, CompressionState(err)
