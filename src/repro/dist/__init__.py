"""repro.dist -- sharding rules and cross-pod gradient compression.

  sharding.py     -- logical parameter/activation sharding specs for the
                     (pod, data, model) production meshes, plus the
                     trace-time activation-constraint switches used by
                     models/ and launch/.
  compression.py  -- int8 error-feedback gradient compression for the
                     slow cross-pod links.
"""

from repro.dist.sharding import (
    batch_sharding,
    cache_shardings,
    constrain_batch_acts,
    constrain_seq_model_acts,
    dp_axis_extent,
    get_activation_mesh,
    logical_param_specs,
    model_axis_extent,
    param_shardings,
    set_activation_mesh,
    set_manual_axes,
    set_sequence_parallel,
)
from repro.dist.compression import (
    CompressionState,
    compressed_cross_pod_mean,
    init_compression_state,
)

__all__ = [
    "CompressionState", "batch_sharding", "cache_shardings",
    "compressed_cross_pod_mean", "constrain_batch_acts",
    "constrain_seq_model_acts", "dp_axis_extent", "get_activation_mesh",
    "init_compression_state", "logical_param_specs", "model_axis_extent",
    "param_shardings", "set_activation_mesh", "set_manual_axes",
    "set_sequence_parallel",
]
