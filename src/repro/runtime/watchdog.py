"""Hang detection for long-running training jobs.

The trainer beats once per step; a daemon thread checks the gap.  On a
multi-pod deployment the heartbeat file is on shared storage and an
external supervisor (or the other pods) restarts the hung worker -- here
the escalation hook is injectable (default: log loudly), and the heartbeat
file protocol is the real artifact.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Callable, Optional


class Watchdog:
    def __init__(self, heartbeat_path: str, *, timeout_s: float = 300.0,
                 check_every_s: float = 5.0,
                 on_hang: Optional[Callable[[float], None]] = None):
        self.path = heartbeat_path
        self.timeout_s = timeout_s
        self.check_every_s = check_every_s
        self.on_hang = on_hang or self._default_hang
        self._last_beat = time.monotonic()
        self._step = -1
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.hang_count = 0

    def _default_hang(self, silent_for: float):
        print(f"[watchdog] NO HEARTBEAT for {silent_for:.0f}s "
              f"(last step {self._step}) -- escalate/restart", flush=True)

    def beat(self, step: int, **info):
        self._last_beat = time.monotonic()
        self._step = step
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": time.time(), **info}, f)
        os.replace(tmp, self.path)

    def _loop(self):
        while not self._stop.wait(self.check_every_s):
            silent = time.monotonic() - self._last_beat
            if silent > self.timeout_s:
                self.hang_count += 1
                self.on_hang(silent)

    def start(self):
        if self._thread is None:
            self._thread = threading.Thread(target=self._loop, daemon=True)
            self._thread.start()
        return self

    def stop(self):
        self._stop.set()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
