from repro.runtime.watchdog import Watchdog
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.retry import retry_transient

__all__ = ["StragglerMonitor", "Watchdog", "retry_transient"]
