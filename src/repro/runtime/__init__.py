from repro.runtime.watchdog import Watchdog
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.retry import retry_transient
from repro.runtime.faults import (FaultSpec, InjectedDeterministicError,
                                  InjectedTransientError, configure_faults,
                                  fault_stats, faults_enabled, reset_faults)

__all__ = ["FaultSpec", "InjectedDeterministicError",
           "InjectedTransientError", "StragglerMonitor", "Watchdog",
           "configure_faults", "fault_stats", "faults_enabled",
           "reset_faults", "retry_transient"]
