"""Deterministic fault injection (chaos harness) for the serve/dist stack.

Chaos testing a numerical service only works if the chaos is
*reproducible*: a flaky test that injects faults at random times cannot
distinguish "the engine mishandled the fault" from "the schedule
changed".  This registry is therefore seeded and count-driven, never
wall-clock driven: each named SITE keeps a hit counter, and a configured
:class:`FaultSpec` fires exactly on the listed hit numbers of its site.
Re-running the same traffic against the same schedule injects the same
faults at the same points.

Instrumented sites (grep for the literal string to find the hook):

    ``plan.launch``   -- raises before the solve executor launches
                         (transient RuntimeError or deterministic
                         ValueError, per ``error=``) -- covers sync AND
                         serve traffic, single-device and sharded.
    ``plan.output``   -- NaN-poisons rows of the executor's eigenvalue
                         output (``lane``/``width``): the "device
                         returned garbage" scenario the degradation
                         ladder exists for.
    ``dist.halo``     -- corrupts one staged off-diagonal lane of a
                         sharded launch at a shard boundary (the halo
                         exchange delivering a damaged value).
    ``serve.launch``  -- raises inside the engine's flush launch.
    ``serve.stage``   -- delays flush staging by ``delay_s`` (trips the
                         watchdog / straggler monitors).

The fast path is one module-global boolean: with no schedule configured
every hook is ``if not _ACTIVE: return`` and the solver's behavior --
down to the bit pattern of its outputs -- is identical to a build
without the harness.  ``tests/test_chaos.py`` pins that equivalence.

Config is programmatic (:func:`configure_faults`) or operator-driven via
the ``REPRO_FAULTS`` environment variable (a JSON list of spec dicts),
so a chaos CI step or a staging deployment can script fault schedules
without code changes::

    REPRO_FAULTS='[{"site": "serve.launch", "kind": "error",
                    "times": [0], "error": "transient"}]'

State is reset by :func:`reset_faults` -- which
``repro.core.plan.clear_plan_cache`` calls, so chaos schedules can never
leak into neighboring tests.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Iterable, Mapping

KINDS = ("error", "nan", "delay", "corrupt")

# Module-global fast flag: every hook bails on one attribute read when no
# schedule is configured (the disabled path must cost nothing and change
# nothing).
_ACTIVE = False


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.

    site:    the instrumented hook name (see module docstring).
    kind:    "error" (raise), "nan" (poison output rows), "delay"
             (sleep), "corrupt" (damage one staged input value).
    times:   which hits of the site fire (0-based, deterministic); an
             empty tuple means every hit.
    error:   "transient" raises InjectedTransientError (a RuntimeError,
             so the retry/fallback machinery treats it as a real device
             fault); "deterministic" raises InjectedDeterministicError
             (a ValueError: retries must NOT fire).
    delay_s: sleep duration for kind="delay".
    lane:    first output row (kind="nan") / staged lane (kind="corrupt")
             to damage.
    width:   number of consecutive rows to poison (kind="nan").
    index:   column index to corrupt (kind="corrupt"; -1 = last).
    value:   the corrupted value (kind="corrupt").
    """
    site: str
    kind: str = "error"
    times: tuple = (0,)
    error: str = "transient"
    delay_s: float = 0.0
    lane: int = 0
    width: int = 1
    index: int = -1
    value: float = float("nan")

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"fault kind must be one of {KINDS}, "
                             f"got {self.kind!r}")
        if self.error not in ("transient", "deterministic"):
            raise ValueError(f"fault error class must be 'transient' or "
                             f"'deterministic', got {self.error!r}")
        object.__setattr__(self, "times", tuple(int(t) for t in self.times))


class InjectedTransientError(RuntimeError):
    """Injected stand-in for a transient device fault (preemption, flaky
    interconnect) -- a RuntimeError so ``retry_transient`` retries it."""


class InjectedDeterministicError(ValueError):
    """Injected stand-in for a deterministic failure -- a ValueError so
    the engine skips the (pointless) relaunch and falls straight back."""


class FaultInjector:
    """Thread-safe registry: schedule + per-site hit/fire counters."""

    def __init__(self):
        self._lock = threading.Lock()
        self._specs: dict[str, list[FaultSpec]] = {}
        self._hits: dict[str, int] = {}
        self._fired: dict[str, int] = {}

    def configure(self, specs: Iterable[FaultSpec | Mapping]) -> None:
        global _ACTIVE
        parsed = [s if isinstance(s, FaultSpec) else FaultSpec(**s)
                  for s in specs]
        with self._lock:
            self._specs.clear()
            self._hits.clear()
            self._fired.clear()
            for s in parsed:
                self._specs.setdefault(s.site, []).append(s)
            _ACTIVE = bool(self._specs)

    def reset(self) -> None:
        global _ACTIVE
        with self._lock:
            self._specs.clear()
            self._hits.clear()
            self._fired.clear()
            _ACTIVE = False

    def due(self, site: str) -> FaultSpec | None:
        """Count one hit of ``site``; return the spec scheduled for this
        hit (None otherwise).  At most one spec fires per hit (first
        configured wins)."""
        with self._lock:
            specs = self._specs.get(site)
            if not specs:
                return None
            hit = self._hits.get(site, 0)
            self._hits[site] = hit + 1
            for s in specs:
                if not s.times or hit in s.times:
                    self._fired[site] = self._fired.get(site, 0) + 1
                    return s
            return None

    def stats(self) -> dict:
        with self._lock:
            return {"active": _ACTIVE,
                    "sites": sorted(self._specs),
                    "hits": dict(self._hits),
                    "fired": dict(self._fired)}


INJECTOR = FaultInjector()


def faults_enabled() -> bool:
    return _ACTIVE


def configure_faults(specs=None) -> None:
    """Install a fault schedule.  ``specs`` is an iterable of
    :class:`FaultSpec` (or spec dicts); ``None`` reads the
    ``REPRO_FAULTS`` environment variable (JSON list, no-op if unset)."""
    if specs is None:
        raw = os.environ.get("REPRO_FAULTS", "")
        if not raw.strip():
            return
        specs = json.loads(raw)
    INJECTOR.configure(specs)


def reset_faults() -> None:
    INJECTOR.reset()


def fault_stats() -> dict:
    return INJECTOR.stats()


# ------------------------------------------------------------------ hooks
# Call sites use exactly these helpers; each is a no-op (one global read)
# when no schedule is installed.


def inject(site: str) -> None:
    """Raise / sleep if a fault is due at ``site`` (kinds error/delay)."""
    if not _ACTIVE:
        return
    spec = INJECTOR.due(site)
    if spec is None:
        return
    if spec.kind == "delay":
        time.sleep(spec.delay_s)
    elif spec.kind == "error":
        if spec.error == "transient":
            raise InjectedTransientError(
                f"injected transient fault at {site}")
        raise InjectedDeterministicError(
            f"injected deterministic fault at {site}")
    # nan/corrupt specs configured on an inject-only site do nothing.


def poison_rows(site: str, arr):
    """NaN-poison ``width`` rows of a (B, n) array if due (kind="nan")."""
    if not _ACTIVE:
        return arr
    spec = INJECTOR.due(site)
    if spec is None or spec.kind != "nan":
        return arr
    lo = spec.lane
    hi = min(lo + max(1, spec.width), arr.shape[0])
    if hasattr(arr, "at"):            # jax array
        return arr.at[lo:hi].set(spec.value)
    arr = arr.copy()
    arr[lo:hi] = spec.value
    return arr


def corrupt_entry(site: str, arr):
    """Damage one entry of a staged (B, m) input if due (kind="corrupt")."""
    if not _ACTIVE:
        return arr
    spec = INJECTOR.due(site)
    if spec is None or spec.kind != "corrupt":
        return arr
    lane = min(spec.lane, arr.shape[0] - 1)
    index = spec.index if spec.index >= 0 else arr.shape[-1] - 1
    index = min(index, arr.shape[-1] - 1)
    if hasattr(arr, "at"):
        return arr.at[lane, index].set(spec.value)
    arr = arr.copy()
    arr[lane, index] = spec.value
    return arr
