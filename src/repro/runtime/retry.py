"""Retry wrapper for transient failures (preemption, flaky interconnect).

Wraps a step-ish callable; on a transient exception it backs off, invokes
the optional recovery hook (e.g. restore-from-checkpoint), and retries.
Non-transient exceptions propagate immediately.
"""

from __future__ import annotations

import time
from typing import Callable, Iterable, Optional, Type

TRANSIENT_DEFAULT: tuple = (OSError, RuntimeError)


def retry_transient(fn: Callable, *, retries: int = 3, backoff_s: float = 1.0,
                    transient: Iterable[Type[BaseException]] = TRANSIENT_DEFAULT,
                    on_retry: Optional[Callable[[int, BaseException], None]] = None):
    transient = tuple(transient)

    def wrapped(*args, **kwargs):
        delay = backoff_s
        for attempt in range(retries + 1):
            try:
                return fn(*args, **kwargs)
            except transient as exc:
                if attempt == retries:
                    raise
                if on_retry:
                    on_retry(attempt, exc)
                time.sleep(delay)
                delay *= 2
        raise AssertionError("unreachable")

    return wrapped
