"""Straggler detection from per-step timing statistics.

At 1000+ nodes the slowest participant sets the synchronous step time; the
first mitigation is *measurement*.  A ring buffer of step durations flags
outliers against a robust (median/MAD) baseline; per-host timings (when
provided) identify *which* host lags.  Mitigation hooks:

  * report() feeds the job log / dashboard,
  * `on_straggler` can trigger data-shard re-balancing or host eviction
    (the trainer wires this; default logs).
"""

from __future__ import annotations

import collections
from typing import Callable, Optional

import numpy as np


class StragglerMonitor:
    def __init__(self, *, window: int = 64, threshold: float = 2.0,
                 on_straggler: Optional[Callable[[dict], None]] = None):
        self.window = window
        self.threshold = threshold
        self.times = collections.deque(maxlen=window)
        self.host_times: dict[int, collections.deque] = {}
        self.on_straggler = on_straggler
        self.events = []

    def record(self, step: int, duration_s: float,
               per_host: Optional[dict] = None):
        self.times.append(duration_s)
        if per_host:
            for host, t in per_host.items():
                self.host_times.setdefault(
                    host, collections.deque(maxlen=self.window)).append(t)
        if len(self.times) >= 8:
            med = float(np.median(self.times))
            mad = float(np.median(np.abs(np.asarray(self.times) - med)))
            limit = med + self.threshold * max(3 * mad, 0.1 * med)
            if duration_s > limit:
                event = {"step": step, "duration": duration_s,
                         "median": med, "limit": limit,
                         "slow_hosts": self._slow_hosts()}
                self.events.append(event)
                if self.on_straggler:
                    self.on_straggler(event)

    def _slow_hosts(self):
        out = []
        if not self.host_times:
            return out
        meds = {h: float(np.median(t)) for h, t in self.host_times.items()}
        overall = float(np.median(list(meds.values())))
        for h, m in meds.items():
            if m > self.threshold * overall:
                out.append(h)
        return out

    def report(self) -> dict:
        arr = np.asarray(self.times) if self.times else np.zeros(1)
        return {
            "steps_tracked": len(self.times),
            "median_s": float(np.median(arr)),
            "p95_s": float(np.percentile(arr, 95)),
            "events": len(self.events),
            "slow_hosts": self._slow_hosts(),
        }
