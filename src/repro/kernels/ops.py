"""Backend-dispatching jit wrappers for the eigensolver hot-spot kernels.

``backend``:
  * "xla"    -- chunked pure-JAX implementations (repro.core.secular);
                default on CPU hosts.
  * "pallas" -- Pallas kernels; compiled natively on TPU, `interpret=True`
                elsewhere (Python-level execution of the kernel body, used
                by the test suite to validate the TPU kernels on CPU).
  * "auto"   -- "pallas" on TPU, "xla" otherwise.

Size-adaptive dispatch: every op takes ``dense=`` -- when True (small K,
decided per merge-tree level by ``stream_threshold``) the op runs the
dense vectorized XLA path regardless of backend.  Small merges are
launch/loop-overhead-bound, not bandwidth-bound, and the chunked/streamed
formulations serialize under vmap exactly where K is small and the level
batch is large; the dense path stays fully batched.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import secular as _sec
from repro.core.secular import DEFAULT_NITER, DEFAULT_NITER_F32
from repro.kernels.secular_roots import (secular_solve_pallas,
                                         secular_solve_pallas_batch)
from repro.kernels.boundary_update import boundary_rows_update_pallas
from repro.kernels.fused_update import (secular_postpass_pallas,
                                        secular_postpass_pallas_batch)
from repro.kernels.resident_merge import (resident_merge_pallas,
                                          resident_merge_pallas_batch)
from repro.kernels.sturm_count import (DEFAULT_SHIFT_BLOCK,
                                       sturm_count_pallas_batch)
from repro.kernels.zhat import zhat_reconstruct_pallas

_BACKEND = "auto"


def set_backend(name: str) -> None:
    global _BACKEND
    if name not in ("auto", "xla", "pallas"):
        raise ValueError(name)
    _BACKEND = name


def resolve_backend(backend: str | None = None) -> str:
    b = backend or _BACKEND
    if b == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return b


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_niter(niter: int | None, dtype) -> int:
    """Resolve the per-dtype default secular iteration budget.

    ``niter=None`` picks the dtype's budget: f32 trees hit their accuracy
    floor earlier than f64 (DEFAULT_NITER_F32 vs DEFAULT_NITER -- see
    ``core.secular``), so the dispatchers below default their iteration
    count off the pole-array dtype.  An explicit niter always wins.
    """
    if niter is not None:
        return int(niter)
    return (DEFAULT_NITER_F32 if jnp.dtype(dtype) == jnp.dtype(jnp.float32)
            else DEFAULT_NITER)


def secular_solve(d, z2, rho, kprime, *, niter: int | None = None,
                  chunk: int = 256,
                  dense: bool = False, backend: str | None = None):
    niter = resolve_niter(niter, d.dtype)
    if dense:
        return _sec.secular_solve(d, z2, rho, kprime, niter=niter,
                                  dense=True)
    if resolve_backend(backend) == "pallas":
        return secular_solve_pallas(d, z2, rho, kprime, niter=niter,
                                    root_block=chunk, interpret=_interpret())
    return _sec.secular_solve(d, z2, rho, kprime, niter=niter, chunk=chunk)


def secular_postpass(R, d, z, origin, tau, kprime, rho, *,
                     use_zhat: bool = True, chunk: int = 256,
                     dense: bool = False, backend: str | None = None):
    """Fused zhat reconstruction + selected-row update: (zhat, rows)."""
    if dense:
        return _sec.secular_postpass(R, d, z, origin, tau, kprime, rho,
                                     use_zhat=use_zhat, dense=True)
    if resolve_backend(backend) == "pallas":
        return secular_postpass_pallas(R, d, z, origin, tau, kprime, rho,
                                       use_zhat=use_zhat, pole_block=chunk,
                                       interpret=_interpret())
    return _sec.secular_postpass(R, d, z, origin, tau, kprime, rho,
                                 use_zhat=use_zhat, chunk=chunk)


def secular_solve_batched(d, z2, rho, kprime, *, niter: int | None = None,
                          chunk: int = 256, dense: bool = False,
                          backend: str | None = None):
    """Problem-batched secular solve: d, z2 (B, K); rho, kprime (B,).

    Pallas backend maps problems onto a leading grid axis (one launch for
    the whole batch); XLA runs the chunked path vmapped over problems.
    Returns (origin (B, K) int32, tau (B, K)).
    """
    niter = resolve_niter(niter, d.dtype)
    if dense:
        return _sec.secular_solve_batched(d, z2, rho, kprime, niter=niter,
                                          dense=True)
    if resolve_backend(backend) == "pallas":
        return secular_solve_pallas_batch(d, z2, rho, kprime, niter=niter,
                                          root_block=chunk,
                                          interpret=_interpret())
    return _sec.secular_solve_batched(d, z2, rho, kprime, niter=niter,
                                      chunk=chunk)


def secular_postpass_batched(R, d, z, origin, tau, kprime, rho, *,
                             use_zhat: bool = True, chunk: int = 256,
                             dense: bool = False,
                             backend: str | None = None):
    """Problem-batched fused post-pass: R (B, r, K); kprime, rho (B,).

    Returns (zhat (B, K), rows (B, r, K)); see ``secular_postpass``.
    """
    if dense:
        return _sec.secular_postpass_batched(R, d, z, origin, tau, kprime,
                                             rho, use_zhat=use_zhat,
                                             dense=True)
    if resolve_backend(backend) == "pallas":
        return secular_postpass_pallas_batch(R, d, z, origin, tau, kprime,
                                             rho, use_zhat=use_zhat,
                                             pole_block=chunk,
                                             interpret=_interpret())
    return _sec.secular_postpass_batched(R, d, z, origin, tau, kprime, rho,
                                         use_zhat=use_zhat, chunk=chunk)


def secular_merge_resident(d, z, R, rho, kprime, *,
                           niter: int | None = None,
                           use_zhat: bool = True,
                           backend: str | None = None):
    """Single-launch resident merge: solve + fused post-pass in ONE dispatch.

    Returns (origin, tau, zhat, rows); see
    ``core.secular.secular_merge_resident``.  Pallas backend runs the
    VMEM-resident kernel (the (origin, tau) never round-trip HBM between
    the phases); XLA runs the dense fused composition as one traced
    region.  Callers gate on K <= resident_threshold.
    """
    niter = resolve_niter(niter, d.dtype)
    if resolve_backend(backend) == "pallas":
        return resident_merge_pallas(d, z, R, rho, kprime, niter=niter,
                                     use_zhat=use_zhat,
                                     interpret=_interpret())
    return _sec.secular_merge_resident(d, z, R, rho, kprime, niter=niter,
                                       use_zhat=use_zhat)


def secular_merge_resident_batched(d, z, R, rho, kprime, *,
                                   niter: int | None = None,
                                   use_zhat: bool = True,
                                   backend: str | None = None):
    """Problem-batched resident merge: d, z (B, K); R (B, r, K).

    One kernel launch for the whole merge level on the Pallas backend
    (problems on the grid axis, each fully VMEM-resident); one fused
    traced region vmapped over problems on XLA.  Returns
    (origin (B, K) int32, tau (B, K), zhat (B, K), rows (B, r, K)).
    """
    niter = resolve_niter(niter, d.dtype)
    if resolve_backend(backend) == "pallas":
        return resident_merge_pallas_batch(d, z, R, rho, kprime,
                                           niter=niter, use_zhat=use_zhat,
                                           interpret=_interpret())
    return _sec.secular_merge_resident_batched(d, z, R, rho, kprime,
                                               niter=niter,
                                               use_zhat=use_zhat)


def sturm_count_batched(d, e2, shifts, pivmin, *,
                        shift_block: int = DEFAULT_SHIFT_BLOCK,
                        backend: str | None = None):
    """Batched Sturm eigenvalue counts: d (B, n), e2 (B, n-1),
    shifts (B, S), pivmin (B, 1) -> (B, S) int32 (#eigenvalues <= shift).

    The bisection front end's per-iteration workhorse.  Pallas backend
    runs one kernel launch with a problems x shift-blocks grid (each
    step's pole rows VMEM-resident); XLA runs one fused scan over matrix
    rows carrying all B x S pivot lanes.  Integer-exact across backends.
    """
    from repro.core import bisect as _bis  # deferred: core imports ops
    if resolve_backend(backend) == "pallas":
        return sturm_count_pallas_batch(d, e2, shifts, pivmin,
                                        shift_block=shift_block,
                                        interpret=_interpret())
    return _bis.sturm_count_xla(d, e2, shifts, pivmin)


def boundary_rows_update(R, d, z, origin, tau, kprime, *, chunk: int = 256,
                         backend: str | None = None):
    if resolve_backend(backend) == "pallas":
        return boundary_rows_update_pallas(R, d, z, origin, tau, kprime,
                                           root_block=chunk,
                                           interpret=_interpret())
    return _sec.boundary_rows_update(R, d, z, origin, tau, kprime, chunk=chunk)


def zhat_reconstruct(d, z, origin, tau, kprime, rho, *, chunk: int = 256,
                     backend: str | None = None):
    if resolve_backend(backend) == "pallas":
        return zhat_reconstruct_pallas(d, z, origin, tau, kprime, rho,
                                       pole_block=chunk,
                                       interpret=_interpret())
    return _sec.zhat_reconstruct(d, z, origin, tau, kprime, rho, chunk=chunk)
