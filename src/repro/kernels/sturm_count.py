"""Pallas TPU kernel: batched Sturm-sequence eigenvalue counts.

The spectrum-slicing front end (core/bisect.py) spends its entire budget
evaluating #{eigenvalues <= shift} at batches of probe shifts -- one
sequential pivot recurrence per shift, embarrassingly parallel across
shifts and across problems.  Mapping:

  work axis                     TPU / Pallas
  ----------------------------  ------------------------------------------
  problems (B)                  grid axis 0 -- each step owns one
                                problem's (n,) d / e^2 rows, VMEM-resident
  probe shifts (S)              grid axis 1 in SHIFT_BLOCK-wide lanes; the
                                pivot recurrence runs once per block with
                                every lane carrying its own shift's pivot
  matrix rows (n)               sequential fori over the resident vectors
                                (the recurrence is a linear chain -- this
                                is the irreducible dependence)

VMEM budget per grid step: 2n + O(SHIFT_BLOCK) floats.  The count uses
LAPACK DSTEBZ's guarded negcount convention (pivots within ``pivmin`` of
zero are counted as negative), identical to the XLA scan in
``core.bisect.sturm_count_xla`` -- ref.py / tests assert exact integer
agreement across shapes, dtypes and degenerate (zero off-diagonal)
inputs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_SHIFT_BLOCK = 128


def _sturm_kernel(d_ref, e2_ref, shifts_ref, pivmin_ref, count_ref):
    # Blocks: d (1, n), e2 (1, n) (last entry is a zero pad -- the
    # recurrence reads e2[i-1] for i in [1, n)), shifts (1, C),
    # pivmin (1, 1); grid = (B, shift_blocks).
    d = d_ref[0]
    e2 = e2_ref[0]
    sig = shifts_ref[0]
    pivmin = pivmin_ref[0, 0]
    n = d.shape[0]

    q = d[0] - sig
    q = jnp.where(jnp.abs(q) < pivmin, -pivmin, q)
    cnt = (q <= 0.0).astype(jnp.int32)

    def body(i, carry):
        q, cnt = carry
        q = (d[i] - sig) - e2[i - 1] / q
        q = jnp.where(jnp.abs(q) < pivmin, -pivmin, q)
        return q, cnt + (q <= 0.0).astype(jnp.int32)

    q, cnt = jax.lax.fori_loop(1, n, body, (q, cnt))
    count_ref[0, :] = cnt


@functools.partial(jax.jit, static_argnames=("shift_block", "interpret"))
def sturm_count_pallas_batch(d, e2, shifts, pivmin, *,
                             shift_block: int = DEFAULT_SHIFT_BLOCK,
                             interpret: bool = False):
    """Batched Pallas Sturm counts: grid over problems x shift blocks.

    d: (B, n); e2: (B, n-1) squared off-diagonals; shifts: (B, S);
    pivmin: (B, 1) pivot floors.  One kernel launch counts every
    (problem, shift) pair -- the whole bisection front's per-iteration
    work.  Returns (B, S) int32 counts (eigenvalues <= shift).
    """
    B, n = d.shape
    S = shifts.shape[1]
    C = min(shift_block, S)
    nblk = (S + C - 1) // C
    Sp = nblk * C
    if Sp != S:
        # Pad lanes with the last shift: duplicated counts, sliced away.
        shifts = jnp.concatenate(
            [shifts, jnp.broadcast_to(shifts[:, -1:], (B, Sp - S))], axis=1)

    # Uniform (B, n) e2 layout; the pad column is never read (i <= n-1).
    e2p = jnp.zeros((B, n), d.dtype).at[:, : max(n - 1, 0)].set(e2)
    pivmin = jnp.asarray(pivmin, d.dtype).reshape(B, 1)

    counts = pl.pallas_call(
        _sturm_kernel,
        grid=(B, nblk),
        in_specs=[
            pl.BlockSpec((1, n), lambda b, i: (b, 0)),   # d, per problem
            pl.BlockSpec((1, n), lambda b, i: (b, 0)),   # e^2
            pl.BlockSpec((1, C), lambda b, i: (b, i)),   # shift lanes
            pl.BlockSpec((1, 1), lambda b, i: (b, 0)),   # pivot floor
        ],
        out_specs=pl.BlockSpec((1, C), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, Sp), jnp.int32),
        interpret=interpret,
    )(d, e2p, shifts, pivmin)
    return counts[:, :S]
