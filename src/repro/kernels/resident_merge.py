"""Pallas TPU kernel: VMEM-resident single-launch merge (solve + post-pass).

For merge levels with K at or below the residency threshold the two-launch
pipeline (``secular_roots`` kernel, HBM round-trip of (origin, tau), then
the fused post-pass kernel) is launch- and bandwidth-bound, not
compute-bound: every one of the root solve's fixed ``niter`` iterations
re-reads the (K,) pole/weight vectors, and the post-pass then reloads the
same structure from HBM a second time.  This kernel loads each problem's
pole/root tile ONCE, runs the full safeguarded middle-way iteration
on-chip, and flows the converged (origin, tau) straight into the
Gu-Eisenstat weight reconstruction and the selected-row update -- no HBM
round-trip between the phases, one kernel launch per merge level.

Grid mapping: one grid step per PROBLEM (the level batch W = B x nodes is
the major axis of the batched merge tree).  Within a step everything is
dense: K <= threshold guarantees the (K, K) delta tile fits VMEM
(~2 MiB at K = 512 f64), which is exactly the residency contract the
size-adaptive dispatch enforces -- large-K levels keep the streamed
two-launch path.

Math is identical to ``core.secular.secular_merge_resident`` (the dense
XLA composition): the DLAED4 middle-way iteration of
``kernels/secular_roots.py`` followed by the ratio-product DLAED3 post-pass
of ``kernels/fused_update.py``, specialized to the fully-resident case.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.secular import DEFAULT_NITER


def _resident_kernel(d_ref, z_ref, R_ref, rho_ref, kprime_ref,
                     origin_ref, tau_ref, zhat_ref, rows_ref, *,
                     niter, use_zhat):
    K = d_ref.shape[-1]
    r = R_ref.shape[-2]
    dtype = d_ref.dtype

    d = d_ref[0]
    z = z_ref[0]
    R = R_ref[0]
    rho = rho_ref[0, 0]
    kprime = kprime_ref[0, 0]
    z2 = z * z

    idxK = jax.lax.iota(jnp.int32, K)
    jc = idxK
    active_root = jc < kprime
    is_last = jc == (kprime - 1)
    active_pole = idxK < kprime
    zw = jnp.where(active_pole, z2, 0.0)
    sum_z2 = jnp.sum(zw)
    span = rho * sum_z2

    # ---- phase 1: dense safeguarded middle-way root solve ---------------
    d_j = d
    jnext = jnp.minimum(jc + 1, K - 1)
    gap_hi = jnp.where(is_last, d_j + span, d[jnext])
    mid_lam = 0.5 * (d_j + gap_hi)

    def g_at(lam):
        delta = d[None, :] - lam[:, None]                       # (K, K)
        ok = active_pole[None, :] & (delta != 0.0)
        return 1.0 + rho * jnp.sum(
            jnp.where(ok, zw[None, :] / jnp.where(ok, delta, 1.0), 0.0),
            axis=-1)

    f_mid = g_at(mid_lam)

    use_left = (f_mid > 0.0) | is_last
    origin = jnp.where(use_left, jc, jnext)
    d_org = d[origin]
    tau_mid = mid_lam - d_org

    lo = jnp.where(use_left, jnp.zeros_like(tau_mid), tau_mid)
    hi = jnp.where(use_left,
                   jnp.where(is_last & (f_mid <= 0.0), span, tau_mid),
                   jnp.zeros_like(tau_mid))
    lo = jnp.where(is_last & (f_mid <= 0.0), tau_mid, lo)

    n_lo = jnp.where(is_last, jnp.maximum(jc - 1, 0), jc)
    n_hi = jnp.where(is_last, jc, jnext)
    p_lo = d[n_lo] - d_org
    p_hi = d[n_hi] - d_org
    side_lo = (idxK[None, :] <= n_lo[:, None]) & active_pole[None, :]

    d_shift = d[None, :] - d_org[:, None]                       # (K, K)

    # Pole-hugging guess (mirrors core.secular._solve_chunk): linearized
    # origin-dominant model r0 + r0' tau - rho*z2_org/tau = 0, preferred
    # over the value-matched quadratic when it lands farther from the
    # origin pole -- kills the near-double-root geometric crawl.
    mask_rest = (active_pole[None, :]
                 & (idxK[None, :] != origin[:, None])
                 & (d_shift != 0.0))
    dsafe_h = jnp.where(mask_rest, d_shift, 1.0)
    terms0 = jnp.where(mask_rest, z2[None, :] / dsafe_h, 0.0)
    r0 = 1.0 + rho * jnp.sum(terms0, axis=-1)
    rp0 = rho * jnp.sum(terms0 / dsafe_h, axis=-1)
    c_org = rho * z2[origin]
    sq_h = jnp.sqrt(jnp.maximum(r0 * r0 + 4.0 * rp0 * c_org, 0.0))
    tau_m = jnp.where(use_left, -r0 + sq_h, -(r0 + sq_h)) \
        / jnp.where(rp0 > 0.0, 2.0 * rp0, 1.0)
    valid_m = (rp0 > 0.0) & jnp.isfinite(tau_m)

    # Initial guess: value-matching 2-pole quadratic at tau_mid.
    A_lo = rho * z2[n_lo]
    A_hi = rho * z2[n_hi]
    c0 = f_mid - A_lo / (p_lo - tau_mid) - A_hi / (p_hi - tau_mid)
    qb = -(c0 * (p_lo + p_hi) + A_lo + A_hi)
    qc = c0 * p_lo * p_hi + A_lo * p_hi + A_hi * p_lo
    sq0 = jnp.sqrt(jnp.maximum(qb * qb - 4.0 * c0 * qc, 0.0))
    qq0 = -0.5 * (qb + jnp.where(qb >= 0.0, 1.0, -1.0) * sq0)
    g1 = jnp.where(c0 != 0.0, qq0 / jnp.where(c0 == 0.0, 1.0, c0), jnp.inf)
    g2 = jnp.where(qq0 != 0.0, qc / jnp.where(qq0 == 0.0, 1.0, qq0), jnp.inf)
    in1 = jnp.isfinite(g1) & (g1 > lo) & (g1 < hi)
    in2 = jnp.isfinite(g2) & (g2 > lo) & (g2 < hi)
    tau0 = jnp.where(in1, g1, jnp.where(in2, g2, 0.5 * (lo + hi)))
    use_m = (valid_m & (tau_m > lo) & (tau_m < hi)
             & (jnp.abs(tau_m) > jnp.abs(tau0)))
    tau0 = jnp.where(use_m, tau_m, tau0)

    tiny = jnp.finfo(dtype).tiny

    def eval_g(tau):
        delta = d_shift - tau[:, None]                          # (K, K)
        ok = active_pole[None, :] & (delta != 0.0)
        safe = jnp.where(ok, delta, 1.0)
        terms = jnp.where(ok, zw[None, :] / safe, 0.0)
        dterms = terms / safe
        g = 1.0 + rho * jnp.sum(terms, axis=-1)
        w_lo = rho * jnp.sum(jnp.where(side_lo, dterms, 0.0), axis=-1)
        w_hi = rho * jnp.sum(jnp.where(side_lo, 0.0, dterms), axis=-1)
        return g, w_lo, w_hi

    def body(_, state):
        tau, lo, hi, best_tau, best_g = state
        g, w_lo, w_hi = eval_g(tau)
        gp = w_lo + w_hi

        better = jnp.abs(g) < best_g
        best_tau = jnp.where(better, tau, best_tau)
        best_g = jnp.where(better, jnp.abs(g), best_g)

        hi = jnp.where(g > 0.0, tau, hi)
        lo = jnp.where(g <= 0.0, tau, lo)

        D_lo = p_lo - tau
        D_hi = p_hi - tau
        Cc = g - D_lo * w_lo - D_hi * w_hi
        Aa = (D_lo + D_hi) * g - D_lo * D_hi * gp
        Bb = D_lo * D_hi * g
        sq = jnp.sqrt(jnp.maximum(Aa * Aa - 4.0 * Bb * Cc, 0.0))
        eta_neg = (Aa - sq) / jnp.where(Cc == 0.0, 1.0, 2.0 * Cc)
        eta_pos = 2.0 * Bb / jnp.where(Aa + sq == 0.0, 1.0, Aa + sq)
        eta = jnp.where(Aa <= 0.0, eta_neg, eta_pos)
        eta_lin = Bb / jnp.where(Aa == 0.0, 1.0, Aa)
        newton = -g / jnp.maximum(gp, tiny)
        eta = jnp.where(Cc == 0.0, jnp.where(Aa != 0.0, eta_lin, newton), eta)
        eta = jnp.where(g * eta >= 0.0, newton, eta)

        cand = tau + eta
        inb = jnp.isfinite(cand) & (cand > lo) & (cand < hi)
        tau_next = jnp.where(inb, cand, 0.5 * (lo + hi))
        tau_next = jnp.where(g == 0.0, tau, tau_next)
        return tau_next, lo, hi, best_tau, best_g

    big = jnp.full((K,), jnp.inf, dtype)
    tau, lo, hi, best_tau, best_g = jax.lax.fori_loop(
        0, niter, body, (tau0, lo, hi, tau0, big))
    g_fin, _, _ = eval_g(tau)
    tau = jnp.where(jnp.abs(g_fin) < best_g, tau, best_tau)

    tau = jnp.where(active_root & (kprime == 1), rho * z2[0], tau)
    origin = jnp.where(active_root & (kprime == 1), 0, origin)
    tau = jnp.where(active_root, tau, jnp.zeros_like(tau))
    origin = jnp.where(active_root, origin, jc)

    origin_ref[0, :] = origin.astype(jnp.int32)
    tau_ref[0, :] = tau.astype(dtype)

    # ---- phase 2: fused post-pass, (origin, tau) still on-chip ----------
    # The d_org gather and the (K, K) delta tile are REUSED from the solve
    # phase's register/VMEM state -- this is the HBM round-trip the
    # two-launch pipeline pays and this kernel exists to remove.
    d_org = d[origin]
    lam_diff = (d_org[None, :] - d[:, None]) + tau[None, :]     # (K_i, K_j)
    valid_i = active_pole                                       # poles axis

    if use_zhat:
        pole_diff = d[None, :] - d[:, None]
        selfmask = idxK[None, :] == idxK[:, None]
        ok = active_pole[None, :] & ~selfmask
        ratio = jnp.where(ok, lam_diff / jnp.where(ok, pole_diff, 1.0), 1.0)
        prod = jnp.prod(ratio, axis=-1)
        self_term = (d_org - d) + tau                           # lam_i - d_i
        z2hat = jnp.abs(prod * self_term) / rho
        zhat = jnp.sign(z) * jnp.sqrt(z2hat)
        zhat = jnp.where(valid_i, zhat, z).astype(dtype)
        w = jnp.where(valid_i, zhat, 0.0)
    else:
        zhat = z
        w = jnp.where(valid_i, z, 0.0)
    zhat_ref[0, :] = zhat

    delta = -lam_diff                         # (d_i - d_org_j) - tau_j
    ok = valid_i[:, None] & (delta != 0.0)
    y = jnp.where(ok, w[:, None] / jnp.where(ok, delta, 1.0), 0.0)  # (K, K)
    cols = jax.lax.dot_general(
        R, y, (((1,), (0,)), ((), ())), preferred_element_type=dtype)
    nrm = jnp.sqrt(jnp.sum(y * y, axis=0))
    cols = cols / jnp.where(nrm > 0.0, nrm, 1.0)[None, :]
    active_j = active_pole[None, :]
    rows_ref[0, :, :] = jnp.where(active_j, cols, R).astype(R.dtype)


@functools.partial(jax.jit, static_argnames=("niter", "use_zhat",
                                             "interpret"))
def resident_merge_pallas_batch(d, z, R, rho, kprime, *, niter: int = DEFAULT_NITER,
                                use_zhat: bool = True,
                                interpret: bool = False):
    """Problem-batched single-launch resident merge: grid = (B,).

    d, z: (B, K); R: (B, r, K); rho, kprime: (B,).  Each grid step owns
    one problem's fully-resident pole/root structure and emits its
    (origin, tau, zhat, rows) in one pass -- a whole batched merge
    level's solve + conquer is ONE kernel launch.  Same contract as
    ``core.secular.secular_merge_resident_batched``.

    Returns (origin (B, K) int32, tau (B, K), zhat (B, K), rows (B, r, K)).
    """
    B, r, K = R.shape
    rho_arr = jnp.asarray(rho, d.dtype).reshape(B, 1)
    kp_arr = jnp.asarray(kprime, jnp.int32).reshape(B, 1)

    kernel = functools.partial(_resident_kernel, niter=niter,
                               use_zhat=use_zhat)
    origin, tau, zhat, rows = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, K), lambda b: (b, 0)),      # d, per problem
            pl.BlockSpec((1, K), lambda b: (b, 0)),      # z
            pl.BlockSpec((1, r, K), lambda b: (b, 0, 0)),  # R
            pl.BlockSpec((1, 1), lambda b: (b, 0)),      # rho
            pl.BlockSpec((1, 1), lambda b: (b, 0)),      # kprime
        ],
        out_specs=[
            pl.BlockSpec((1, K), lambda b: (b, 0)),
            pl.BlockSpec((1, K), lambda b: (b, 0)),
            pl.BlockSpec((1, K), lambda b: (b, 0)),
            pl.BlockSpec((1, r, K), lambda b: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, K), jnp.int32),
            jax.ShapeDtypeStruct((B, K), d.dtype),
            jax.ShapeDtypeStruct((B, K), d.dtype),
            jax.ShapeDtypeStruct((B, r, K), R.dtype),
        ],
        interpret=interpret,
    )(d, z, R, rho_arr, kp_arr)
    return origin, tau, zhat, rows


def resident_merge_pallas(d, z, R, rho, kprime, *, niter: int = DEFAULT_NITER,
                          use_zhat: bool = True, interpret: bool = False):
    """Single-problem view of :func:`resident_merge_pallas_batch`."""
    origin, tau, zhat, rows = resident_merge_pallas_batch(
        d[None], z[None], R[None], jnp.asarray(rho, d.dtype)[None],
        jnp.asarray(kprime, jnp.int32)[None], niter=niter,
        use_zhat=use_zhat, interpret=interpret)
    return origin[0], tau[0], zhat[0], rows[0]
