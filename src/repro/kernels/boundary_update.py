"""Pallas TPU kernel: streamed selected-row (boundary-row) update.

The paper's key GPU kernel (Section 4.1): for every active secular root j,

    R_parent(:, j) = R_child @ y_j,
    y_j(i) = (z_i / ((d_i - d_org_j) - tau_j)) / ||.||

with R_child holding at most two selected rows -- "each column update is
reduced to two streamed dot products".  The dense K x K secular eigenvector
block Y is never materialized; that is precisely the O(n^2) -> O(n) claim.

TPU mapping: grid over root blocks; R (r, K), d, z, d_org, tau resident in
VMEM (all O(K)); the (ROOT_BLOCK, POLE_TILE) y-slab is the only 2-D
temporary.  The r x T @ T x C contraction per tile feeds the VPU (r = 2) --
the MXU is irrelevant at r = 2, which matches the paper's observation that
this kernel is bandwidth-, not FLOP-, bound.

Deflated columns (j >= kprime) pass through unchanged (paper: permutations
applied to metadata only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROOT_BLOCK = 128
DEFAULT_POLE_TILE = 1024


def _boundary_kernel(R_ref, d_ref, z_ref, dorg_ref, tau_ref, kprime_ref,
                     out_ref, *, pole_tile):
    r, K = R_ref.shape
    C = out_ref.shape[1]
    T = min(pole_tile, K)
    num_tiles = (K + T - 1) // T
    dtype = R_ref.dtype

    d = d_ref[...]
    z = z_ref[...]
    kprime = kprime_ref[0]

    i = pl.program_id(0)
    jc = i * C + jax.lax.iota(jnp.int32, C)
    jc_safe = jnp.minimum(jc, K - 1)
    active_j = jc < kprime

    d_org = dorg_ref[...][jc_safe]
    tau = tau_ref[...][jc_safe]

    def body(t, acc):
        cols_acc, nrm_acc = acc
        start = t * T
        dt = jax.lax.dynamic_slice(d, (start,), (T,))
        zt = jax.lax.dynamic_slice(z, (start,), (T,))
        Rt = jax.lax.dynamic_slice(R_ref[...], (jnp.zeros((), start.dtype), start), (r, T))
        it = start + jax.lax.iota(jnp.int32, T)
        delta = (dt[None, :] - d_org[:, None]) - tau[:, None]     # (C, T)
        ok = (it < kprime)[None, :] & (delta != 0.0)
        y = jnp.where(ok, zt[None, :] / jnp.where(ok, delta, 1.0), 0.0)
        nrm_acc = nrm_acc + jnp.sum(y * y, axis=-1)               # (C,)
        cols_acc = cols_acc + jax.lax.dot_general(
            Rt, y, (((1,), (1,)), ((), ())),
            preferred_element_type=dtype)                          # (r, C)
        return cols_acc, nrm_acc

    cols0 = jnp.zeros((r, C), dtype)
    nrm0 = jnp.zeros((C,), dtype)
    cols, nrm2 = jax.lax.fori_loop(0, num_tiles, body, (cols0, nrm0))
    nrm = jnp.sqrt(nrm2)
    cols = cols / jnp.where(nrm > 0.0, nrm, 1.0)[None, :]

    # Deflated columns pass through.
    Rsel = jax.lax.dynamic_slice(
        R_ref[...], (jnp.zeros((), jnp.int32), jnp.asarray(i * C, jnp.int32)),
        (r, C))
    out_ref[...] = jnp.where(active_j[None, :], cols, Rsel).astype(dtype)


@functools.partial(jax.jit, static_argnames=("root_block", "pole_tile",
                                             "interpret"))
def boundary_rows_update_pallas(R, d, z, origin, tau, kprime, *,
                                root_block: int = DEFAULT_ROOT_BLOCK,
                                pole_tile: int = DEFAULT_POLE_TILE,
                                interpret: bool = False):
    """Pallas streamed selected-row update.  Contract of core.secular.boundary_rows_update."""
    r, K = R.shape
    C = min(root_block, K)
    grid = ((K + C - 1) // C,)
    Kp = grid[0] * C
    if Kp != K:
        # Pad the column dimension so every block is full; padded columns
        # are inactive (j >= kprime) and sliced off below.
        R_p = jnp.pad(R, ((0, 0), (0, Kp - K)))
        d_p = jnp.pad(d, (0, Kp - K))
        z_p = jnp.pad(z, (0, Kp - K))
        org_p = jnp.pad(origin, (0, Kp - K))
        tau_p = jnp.pad(tau, (0, Kp - K))
    else:
        R_p, d_p, z_p, org_p, tau_p = R, d, z, origin, tau

    d_org = d_p[jnp.minimum(org_p, K - 1)]
    kp_arr = jnp.asarray(kprime, jnp.int32).reshape(1)

    kernel = functools.partial(_boundary_kernel, pole_tile=pole_tile)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r, Kp), lambda i: (0, 0)),  # R: 2 rows resident
            pl.BlockSpec((Kp,), lambda i: (0,)),      # d
            pl.BlockSpec((Kp,), lambda i: (0,)),      # z (or zhat)
            pl.BlockSpec((Kp,), lambda i: (0,)),      # d[origin]
            pl.BlockSpec((Kp,), lambda i: (0,)),      # tau
            pl.BlockSpec((1,), lambda i: (0,)),       # kprime
        ],
        out_specs=pl.BlockSpec((r, C), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((r, Kp), R.dtype),
        interpret=interpret,
    )(R_p, d_p, z_p, d_org, tau_p, kp_arr)
    return out[:, :K]
