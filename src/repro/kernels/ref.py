"""Pure-jnp oracles for the Pallas kernels.

Deliberately naive: each oracle materializes the full (K, K) intermediate
the kernels exist to avoid, so any streaming/tiling bug in the kernels
shows up as a mismatch.  Tests sweep shapes/dtypes and assert_allclose
kernel-vs-oracle.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def secular_roots_ref(d, z2, rho, kprime, *, niter: int = 100):
    """Dense-bracket bisection oracle (slow, unconditionally convergent).

    Operates in the same compact (origin, tau) representation; pure
    bisection with `niter` halvings, so its only error is ~2^-niter of the
    initial bracket -- independent of the kernels' rational iteration.
    Runs in numpy at float64 regardless of input dtype.
    """
    d = np.asarray(d, np.float64)
    z2 = np.asarray(z2, np.float64)
    rho = float(rho)
    kprime = int(kprime)
    K = d.shape[0]
    origin = np.arange(K, dtype=np.int32)
    tau = np.zeros(K)

    span = rho * float(np.sum(z2[:kprime]))

    def g(lam):
        return 1.0 + rho * np.sum(z2[:kprime] / (d[:kprime] - lam))

    for j in range(kprime):
        if kprime == 1:
            origin[0], tau[0] = 0, rho * z2[0]
            break
        is_last = j == kprime - 1
        gap_hi = d[j] + span if is_last else d[j + 1]
        lo_lam, hi_lam = d[j], gap_hi
        # strict interior bisection on g (increasing)
        for _ in range(niter):
            mid = 0.5 * (lo_lam + hi_lam)
            if g(mid) > 0:
                hi_lam = mid
            else:
                lo_lam = mid
        lam = 0.5 * (lo_lam + hi_lam)
        org = j if abs(lam - d[j]) <= abs(lam - gap_hi) or is_last else j + 1
        origin[j] = org
        tau[j] = lam - d[org]
    return jnp.asarray(origin), jnp.asarray(tau)


def boundary_rows_update_ref(R, d, z, origin, tau, kprime):
    """Materializes the full K x K secular eigenvector block Y (the thing
    the kernel must never do) and applies R @ Y densely."""
    K = d.shape[0]
    d_org = d[jnp.minimum(origin, K - 1)]
    active = jnp.arange(K) < kprime
    delta = (d[:, None] - d_org[None, :]) - tau[None, :]      # (K_i, K_j)
    ok = active[:, None] & (delta != 0.0)
    Y = jnp.where(ok, z[:, None] / jnp.where(ok, delta, 1.0), 0.0)
    nrm = jnp.sqrt(jnp.sum(Y * Y, axis=0))
    Y = Y / jnp.where(nrm > 0.0, nrm, 1.0)[None, :]
    # Deflated columns are identity pass-through.
    eye = jnp.eye(K, dtype=R.dtype)
    Y = jnp.where(active[None, :], Y, eye)
    return R @ Y


def secular_postpass_ref(R, d, z, origin, tau, kprime, rho, *,
                         use_zhat=True):
    """Dense oracle for the fused post-pass: materializes everything the
    fused kernel streams -- full zhat reconstruction followed by the dense
    K x K row update.  Returns (zhat, rows)."""
    zhat = zhat_reconstruct_ref(d, z, origin, tau, kprime, rho) if use_zhat \
        else z
    rows = boundary_rows_update_ref(R, d, zhat, origin, tau, kprime)
    return zhat, rows


def secular_roots_batch_ref(d, z2, rho, kprime, *, niter: int = 100):
    """Batched bisection oracle: a literal Python loop of single-problem
    oracles (the thing the batched kernels must match *and* beat)."""
    outs = [secular_roots_ref(d[b], z2[b], rho[b], kprime[b], niter=niter)
            for b in range(np.asarray(d).shape[0])]
    return (jnp.stack([o[0] for o in outs]),
            jnp.stack([o[1] for o in outs]))


def secular_postpass_batch_ref(R, d, z, origin, tau, kprime, rho, *,
                               use_zhat=True):
    """Batched dense oracle: loop of single-problem dense post-passes."""
    outs = [secular_postpass_ref(R[b], d[b], z[b], origin[b], tau[b],
                                 kprime[b], rho[b], use_zhat=use_zhat)
            for b in range(np.asarray(d).shape[0])]
    return (jnp.stack([o[0] for o in outs]),
            jnp.stack([o[1] for o in outs]))


def resident_merge_ref(d, z, R, rho, kprime, *, use_zhat=True,
                       niter: int = 100):
    """Dense oracle for the single-launch resident merge: bisection root
    solve followed by the dense post-pass -- materializes every
    intermediate the resident kernel keeps on-chip.  Returns
    (origin, tau, zhat, rows)."""
    origin, tau = secular_roots_ref(d, np.asarray(z) ** 2, rho, kprime,
                                    niter=niter)
    tau = jnp.asarray(tau, jnp.asarray(d).dtype)
    zhat, rows = secular_postpass_ref(R, d, z, origin, tau, kprime, rho,
                                      use_zhat=use_zhat)
    return origin, tau, zhat, rows


def resident_merge_batch_ref(d, z, R, rho, kprime, *, use_zhat=True,
                             niter: int = 100):
    """Batched resident-merge oracle: a literal loop of single-problem
    oracles."""
    outs = [resident_merge_ref(d[b], z[b], R[b], rho[b], kprime[b],
                               use_zhat=use_zhat, niter=niter)
            for b in range(np.asarray(d).shape[0])]
    return tuple(jnp.stack([o[i] for o in outs]) for i in range(4))


def sturm_count_ref(d, e2, shifts, pivmin):
    """Literal per-(problem, shift) Python-loop Sturm count oracle.

    The exact DSTEBZ negcount recurrence in scalar numpy float64 -- any
    vectorization/tiling bug in the batched kernel (lane mixing, pivot
    floor broadcast, pad-column reads) shows up as an integer mismatch.
    d: (B, n); e2: (B, n-1); shifts: (B, S); pivmin: (B, 1) or (B,).
    Returns (B, S) int32.
    """
    d = np.asarray(d, np.float64)
    e2 = np.asarray(e2, np.float64)
    shifts = np.asarray(shifts, np.float64)
    pivmin = np.asarray(pivmin, np.float64).reshape(d.shape[0])
    B, n = d.shape
    out = np.zeros(shifts.shape, np.int32)
    for b in range(B):
        for s in range(shifts.shape[1]):
            sig = shifts[b, s]
            q = d[b, 0] - sig
            if abs(q) < pivmin[b]:
                q = -pivmin[b]
            cnt = 1 if q <= 0.0 else 0
            for i in range(1, n):
                q = (d[b, i] - sig) - e2[b, i - 1] / q
                if abs(q) < pivmin[b]:
                    q = -pivmin[b]
                cnt += 1 if q <= 0.0 else 0
            out[b, s] = cnt
    return jnp.asarray(out)


def certify_ref(d, e, lam, tol):
    """Literal certification oracle for the mixed-precision pipeline.

    An approximate eigenvalue ``lam[b, j]`` is *certified* when the f64
    Sturm counts bracket the j-th true eigenvalue within ``tol[b]``:
    ``count(lam - tol) <= j`` and ``count(lam + tol) >= j + 1``, i.e. the
    interval (lam - tol, lam + tol] provably contains eigenvalue j.  This
    scalar-loop oracle (built on :func:`sturm_count_ref`) is what the
    vectorized 2N-lane certify sweep in ``core.bisect`` must agree with
    exactly -- certification is an integer predicate, so any disagreement
    is a bug, not roundoff.  d: (B, n); e: (B, n-1); lam: (B, n);
    tol: (B,) or (B, 1).  Returns (B, n) bool.
    """
    d = np.asarray(d, np.float64)
    e = np.asarray(e, np.float64)
    lam = np.asarray(lam, np.float64)
    tol = np.asarray(tol, np.float64).reshape(d.shape[0], 1)
    e2 = e * e
    safmin = np.finfo(np.float64).tiny
    pivmin = safmin * np.maximum(1.0, e2.max(axis=1, initial=0.0))
    j = np.arange(d.shape[1])[None, :]
    lo = np.asarray(sturm_count_ref(d, e2, lam - tol, pivmin))
    hi = np.asarray(sturm_count_ref(d, e2, lam + tol, pivmin))
    return jnp.asarray((lo <= j) & (hi >= j + 1))


def zhat_reconstruct_ref(d, z, origin, tau, kprime, rho):
    """Dense pairwise log-product oracle."""
    K = d.shape[0]
    d_org = d[jnp.minimum(origin, K - 1)]
    active = jnp.arange(K) < kprime
    tiny = jnp.finfo(d.dtype).tiny
    lam_diff = (d_org[None, :] - d[:, None]) + tau[None, :]   # (K_i, K_j)
    pole_diff = d[None, :] - d[:, None]
    jmask = active[None, :]
    selfmask = jnp.eye(K, dtype=bool)
    log_num = jnp.sum(
        jnp.where(jmask, jnp.log(jnp.maximum(jnp.abs(lam_diff), tiny)), 0.0), axis=1)
    log_den = jnp.sum(
        jnp.where(jmask & ~selfmask,
                  jnp.log(jnp.maximum(jnp.abs(pole_diff), tiny)), 0.0), axis=1)
    z2hat = jnp.exp(log_num - log_den) / rho
    zhat = jnp.sign(z) * jnp.sqrt(jnp.maximum(z2hat, 0.0))
    return jnp.where(active, zhat, z)
