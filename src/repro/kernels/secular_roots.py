"""Pallas TPU kernel: batched secular-equation root solve.

TPU adaptation of the paper's CUDA block-reduction root solver
(Section 4.1: "parallelize both across roots and across the pole
reductions inside each root").  Mapping:

  CUDA                          TPU / Pallas
  ----------------------------  ------------------------------------------
  one block per root batch      grid step per root block (ROOT_BLOCK)
  shared-mem pole staging       (K,) pole/weight vectors resident in VMEM
  warp reductions over poles    fori over POLE_TILE-sized (C, T) slabs on
                                the VPU, accumulating g / g' partial sums
  per-thread Newton state       per-lane root state (tau, lo, hi, best)

VMEM budget per grid step: 2K + O(ROOT_BLOCK * POLE_TILE) floats -- the
(C, K) broadcast that a naive formulation would materialize is never
formed; this is the same streaming contract as the XLA fallback in
repro.core.secular.

The root iteration is the safeguarded DLAED4 middle-way scheme, identical
in math to repro.core.secular._solve_chunk (ref.py / tests assert
agreement to ~machine precision across shapes, dtypes and deflation
patterns).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.secular import DEFAULT_NITER

DEFAULT_ROOT_BLOCK = 128
DEFAULT_POLE_TILE = 1024


def _secular_kernel(d_ref, z2_ref, rho_ref, kprime_ref,
                    origin_ref, tau_ref, *, niter, pole_tile,
                    batched=False):
    # ``batched``: refs carry a leading length-1 problem-block dim and the
    # grid is (B, root_blocks) -- one problem per grid row, so a whole
    # batch of independent merges runs as a single kernel launch.
    C = origin_ref.shape[-1]
    K = d_ref.shape[-1]
    T = min(pole_tile, K)
    num_tiles = (K + T - 1) // T
    dtype = d_ref.dtype

    if batched:
        d = d_ref[0]
        z2 = z2_ref[0]
        rho = rho_ref[0, 0]
        kprime = kprime_ref[0, 0]
        i = pl.program_id(1)
    else:
        d = d_ref[...]
        z2 = z2_ref[...]
        rho = rho_ref[0]
        kprime = kprime_ref[0]
        i = pl.program_id(0)
    jc = i * C + jax.lax.iota(jnp.int32, C)
    jc_safe = jnp.minimum(jc, K - 1)
    active_root = jc < kprime
    is_last = jc == (kprime - 1)

    idxK = jax.lax.iota(jnp.int32, K)
    active_pole = idxK < kprime
    zw = jnp.where(active_pole, z2, 0.0)
    sum_z2 = jnp.sum(zw)
    span = rho * sum_z2

    d_j = d[jc_safe]
    jnext = jnp.minimum(jc_safe + 1, K - 1)
    gap_hi = jnp.where(is_last, d_j + span, d[jnext])
    mid_lam = 0.5 * (d_j + gap_hi)

    def reduce_tiles(fn, init):
        """Accumulate fn(d_tile, zw_tile, idx_tile) over pole tiles."""
        def body(t, acc):
            start = t * T
            dt = jax.lax.dynamic_slice(d, (start,), (T,))
            zt = jax.lax.dynamic_slice(zw, (start,), (T,))
            it = start + jax.lax.iota(jnp.int32, T)
            return fn(acc, dt, zt, it)
        return jax.lax.fori_loop(0, num_tiles, body, init)

    # f(mid): one tiled sweep.
    def fmid_acc(acc, dt, zt, it):
        delta = dt[None, :] - mid_lam[:, None]
        ok = (it < kprime)[None, :] & (delta != 0.0)
        return acc + jnp.sum(jnp.where(ok, zt[None, :] / jnp.where(ok, delta, 1.0), 0.0), axis=-1)
    f_mid = 1.0 + rho * reduce_tiles(fmid_acc, jnp.zeros((C,), dtype))

    use_left = (f_mid > 0.0) | is_last
    origin = jnp.where(use_left, jc_safe, jnext)
    d_org = d[origin]
    tau_mid = mid_lam - d_org

    lo = jnp.where(use_left, jnp.zeros_like(tau_mid), tau_mid)
    hi = jnp.where(use_left,
                   jnp.where(is_last & (f_mid <= 0.0), span, tau_mid),
                   jnp.zeros_like(tau_mid))
    lo = jnp.where(is_last & (f_mid <= 0.0), tau_mid, lo)

    n_lo = jnp.where(is_last, jnp.maximum(jc_safe - 1, 0), jc_safe)
    n_hi = jnp.where(is_last, jc_safe, jnext)
    p_lo = d[n_lo] - d_org
    p_hi = d[n_hi] - d_org

    # Pole-hugging guess (mirrors core.secular._solve_chunk): linearized
    # origin-dominant model r0 + r0' tau - rho*z2_org/tau = 0, preferred
    # over the value-matched quadratic when it lands farther from the
    # origin pole -- kills the near-double-root geometric crawl.
    def rest_acc(acc, dt, zt, it):
        r_a, rp_a = acc
        delta = dt[None, :] - d_org[:, None]
        ok = ((it < kprime)[None, :] & (it[None, :] != origin[:, None])
              & (delta != 0.0))
        safe = jnp.where(ok, delta, 1.0)
        t0 = jnp.where(ok, zt[None, :] / safe, 0.0)
        return r_a + jnp.sum(t0, axis=-1), rp_a + jnp.sum(t0 / safe, axis=-1)

    zc = jnp.zeros((C,), dtype)
    r0s, rp0s = reduce_tiles(rest_acc, (zc, zc))
    r0 = 1.0 + rho * r0s
    rp0 = rho * rp0s
    c_org = rho * z2[origin]
    sq_h = jnp.sqrt(jnp.maximum(r0 * r0 + 4.0 * rp0 * c_org, 0.0))
    tau_m = jnp.where(use_left, -r0 + sq_h, -(r0 + sq_h)) \
        / jnp.where(rp0 > 0.0, 2.0 * rp0, 1.0)
    valid_m = (rp0 > 0.0) & jnp.isfinite(tau_m)

    # Initial guess: value-matching 2-pole quadratic at tau_mid.
    A_lo = rho * z2[n_lo]
    A_hi = rho * z2[n_hi]
    c0 = f_mid - A_lo / (p_lo - tau_mid) - A_hi / (p_hi - tau_mid)
    qb = -(c0 * (p_lo + p_hi) + A_lo + A_hi)
    qc = c0 * p_lo * p_hi + A_lo * p_hi + A_hi * p_lo
    sq0 = jnp.sqrt(jnp.maximum(qb * qb - 4.0 * c0 * qc, 0.0))
    qq0 = -0.5 * (qb + jnp.where(qb >= 0.0, 1.0, -1.0) * sq0)
    g1 = jnp.where(c0 != 0.0, qq0 / jnp.where(c0 == 0.0, 1.0, c0), jnp.inf)
    g2 = jnp.where(qq0 != 0.0, qc / jnp.where(qq0 == 0.0, 1.0, qq0), jnp.inf)
    in1 = jnp.isfinite(g1) & (g1 > lo) & (g1 < hi)
    in2 = jnp.isfinite(g2) & (g2 > lo) & (g2 < hi)
    tau0 = jnp.where(in1, g1, jnp.where(in2, g2, 0.5 * (lo + hi)))
    use_m = (valid_m & (tau_m > lo) & (tau_m < hi)
             & (jnp.abs(tau_m) > jnp.abs(tau0)))
    tau0 = jnp.where(use_m, tau_m, tau0)

    tiny = jnp.finfo(dtype).tiny

    def eval_g(tau):
        """Tiled g(tau) and side-split derivative sums."""
        def acc_fn(acc, dt, zt, it):
            g_a, wlo_a, whi_a = acc
            delta = (dt[None, :] - d_org[:, None]) - tau[:, None]  # (C, T)
            ok = (it < kprime)[None, :] & (delta != 0.0)
            safe = jnp.where(ok, delta, 1.0)
            terms = jnp.where(ok, zt[None, :] / safe, 0.0)
            dterms = terms / safe
            sl = it[None, :] <= n_lo[:, None]
            g_a = g_a + jnp.sum(terms, axis=-1)
            wlo_a = wlo_a + jnp.sum(jnp.where(sl, dterms, 0.0), axis=-1)
            whi_a = whi_a + jnp.sum(jnp.where(sl, 0.0, dterms), axis=-1)
            return g_a, wlo_a, whi_a
        z0 = jnp.zeros((C,), dtype)
        g_s, wlo_s, whi_s = reduce_tiles(acc_fn, (z0, z0, z0))
        return 1.0 + rho * g_s, rho * wlo_s, rho * whi_s

    def body(_, state):
        tau, lo, hi, best_tau, best_g = state
        g, w_lo, w_hi = eval_g(tau)
        gp = w_lo + w_hi

        better = jnp.abs(g) < best_g
        best_tau = jnp.where(better, tau, best_tau)
        best_g = jnp.where(better, jnp.abs(g), best_g)

        hi = jnp.where(g > 0.0, tau, hi)
        lo = jnp.where(g <= 0.0, tau, lo)

        D_lo = p_lo - tau
        D_hi = p_hi - tau
        Cc = g - D_lo * w_lo - D_hi * w_hi
        Aa = (D_lo + D_hi) * g - D_lo * D_hi * gp
        Bb = D_lo * D_hi * g
        sq = jnp.sqrt(jnp.maximum(Aa * Aa - 4.0 * Bb * Cc, 0.0))
        eta_neg = (Aa - sq) / jnp.where(Cc == 0.0, 1.0, 2.0 * Cc)
        eta_pos = 2.0 * Bb / jnp.where(Aa + sq == 0.0, 1.0, Aa + sq)
        eta = jnp.where(Aa <= 0.0, eta_neg, eta_pos)
        eta_lin = Bb / jnp.where(Aa == 0.0, 1.0, Aa)
        newton = -g / jnp.maximum(gp, tiny)
        eta = jnp.where(Cc == 0.0, jnp.where(Aa != 0.0, eta_lin, newton), eta)
        eta = jnp.where(g * eta >= 0.0, newton, eta)

        cand = tau + eta
        inb = jnp.isfinite(cand) & (cand > lo) & (cand < hi)
        tau_next = jnp.where(inb, cand, 0.5 * (lo + hi))
        tau_next = jnp.where(g == 0.0, tau, tau_next)
        return tau_next, lo, hi, best_tau, best_g

    big = jnp.full((C,), jnp.inf, dtype)
    tau, lo, hi, best_tau, best_g = jax.lax.fori_loop(
        0, niter, body, (tau0, lo, hi, tau0, big))
    g_fin, _, _ = eval_g(tau)
    tau = jnp.where(jnp.abs(g_fin) < best_g, tau, best_tau)

    tau = jnp.where(active_root & (kprime == 1), rho * z2[0], tau)
    origin = jnp.where(active_root & (kprime == 1), 0, origin)
    tau = jnp.where(active_root, tau, jnp.zeros_like(tau))
    origin = jnp.where(active_root, origin, jc_safe)

    if batched:
        origin_ref[0, :] = origin.astype(jnp.int32)
        tau_ref[0, :] = tau.astype(dtype)
    else:
        origin_ref[...] = origin.astype(jnp.int32)
        tau_ref[...] = tau.astype(dtype)


@functools.partial(jax.jit, static_argnames=("niter", "root_block",
                                             "pole_tile", "interpret"))
def secular_solve_pallas(d, z2, rho, kprime, *, niter: int = DEFAULT_NITER,
                         root_block: int = DEFAULT_ROOT_BLOCK,
                         pole_tile: int = DEFAULT_POLE_TILE,
                         interpret: bool = False):
    """Pallas-kernel secular solve.  Same contract as core.secular.secular_solve."""
    K = d.shape[0]
    C = min(root_block, K)
    grid = ((K + C - 1) // C,)
    Kp = grid[0] * C

    rho_arr = jnp.asarray(rho, d.dtype).reshape(1)
    kp_arr = jnp.asarray(kprime, jnp.int32).reshape(1)

    kernel = functools.partial(_secular_kernel, niter=niter,
                               pole_tile=pole_tile)
    origin, tau = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K,), lambda i: (0,)),    # d: VMEM-resident poles
            pl.BlockSpec((K,), lambda i: (0,)),    # z2: VMEM-resident weights
            pl.BlockSpec((1,), lambda i: (0,)),    # rho
            pl.BlockSpec((1,), lambda i: (0,)),    # kprime
        ],
        out_specs=[
            pl.BlockSpec((C,), lambda i: (i,)),
            pl.BlockSpec((C,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Kp,), jnp.int32),
            jax.ShapeDtypeStruct((Kp,), d.dtype),
        ],
        interpret=interpret,
    )(d, z2, rho_arr, kp_arr)
    return origin[:K], tau[:K]


@functools.partial(jax.jit, static_argnames=("niter", "root_block",
                                             "pole_tile", "interpret"))
def secular_solve_pallas_batch(d, z2, rho, kprime, *, niter: int = DEFAULT_NITER,
                               root_block: int = DEFAULT_ROOT_BLOCK,
                               pole_tile: int = DEFAULT_POLE_TILE,
                               interpret: bool = False):
    """Problem-batched Pallas secular solve: grid = (B, root_blocks).

    d, z2: (B, K); rho, kprime: (B,).  Each grid row owns one problem's
    VMEM-resident pole/weight vectors; the root blocks of different
    problems are fully independent grid steps, so a whole level of the
    batched merge tree is ONE kernel launch instead of B.  Per-problem
    math is identical to :func:`secular_solve_pallas`.

    Returns (origin (B, K) int32, tau (B, K)).
    """
    B, K = d.shape
    C = min(root_block, K)
    nblk = (K + C - 1) // C
    grid = (B, nblk)
    Kp = nblk * C

    rho_arr = jnp.asarray(rho, d.dtype).reshape(B, 1)
    kp_arr = jnp.asarray(kprime, jnp.int32).reshape(B, 1)

    kernel = functools.partial(_secular_kernel, niter=niter,
                               pole_tile=pole_tile, batched=True)
    origin, tau = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, K), lambda b, i: (b, 0)),   # d, per problem
            pl.BlockSpec((1, K), lambda b, i: (b, 0)),   # z2
            pl.BlockSpec((1, 1), lambda b, i: (b, 0)),   # rho
            pl.BlockSpec((1, 1), lambda b, i: (b, 0)),   # kprime
        ],
        out_specs=[
            pl.BlockSpec((1, C), lambda b, i: (b, i)),
            pl.BlockSpec((1, C), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Kp), jnp.int32),
            jax.ShapeDtypeStruct((B, Kp), d.dtype),
        ],
        interpret=interpret,
    )(d, z2, rho_arr, kp_arr)
    return origin[:, :K], tau[:, :K]
