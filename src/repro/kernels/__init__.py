"""Pallas TPU kernels for the eigensolver hot spots the paper optimizes.

  secular_roots.py    -- batched secular root solve (CUDA block-reduction
                         analogue; grid over root blocks, pole-tile loop)
  fused_update.py     -- fused conquer post-pass: one delta sweep emits the
                         Gu-Eisenstat weights AND the selected-row update
  boundary_update.py  -- streamed 2-row selected-row update (legacy
                         two-pass form; reference/benchmark baseline)
  zhat.py             -- Gu-Eisenstat stable weight reconstruction (legacy
                         two-pass form)
  sturm_count.py      -- batched Sturm-sequence eigenvalue counts for the
                         spectrum-slicing front end (grid over problems x
                         probe-shift blocks)

ops.py dispatches between the Pallas kernels (TPU / interpret), the
chunked XLA fallbacks, and the dense small-K path (size-adaptive level
dispatch); ref.py holds deliberately-naive dense oracles.
"""

from repro.kernels.ops import (
    boundary_rows_update,
    resolve_backend,
    secular_postpass,
    secular_postpass_batched,
    secular_solve,
    secular_solve_batched,
    set_backend,
    sturm_count_batched,
    zhat_reconstruct,
)
from repro.kernels.sturm_count import sturm_count_pallas_batch
from repro.kernels.secular_roots import (secular_solve_pallas,
                                         secular_solve_pallas_batch)
from repro.kernels.boundary_update import boundary_rows_update_pallas
from repro.kernels.fused_update import (secular_postpass_pallas,
                                        secular_postpass_pallas_batch)
from repro.kernels.zhat import zhat_reconstruct_pallas

__all__ = [
    "boundary_rows_update", "boundary_rows_update_pallas", "resolve_backend",
    "secular_postpass", "secular_postpass_batched", "secular_postpass_pallas",
    "secular_postpass_pallas_batch",
    "secular_solve", "secular_solve_batched", "secular_solve_pallas",
    "secular_solve_pallas_batch", "set_backend",
    "sturm_count_batched", "sturm_count_pallas_batch",
    "zhat_reconstruct", "zhat_reconstruct_pallas",
]
