"""Pallas TPU kernel: fused conquer post-pass (zhat + selected-row update).

Single-kernel realization of ``core.secular.secular_postpass``: one sweep
over the delta structure ``(d_i - d_org_j) - tau_j`` produces BOTH the
Gu-Eisenstat reconstructed weights (DLAED3) and the r-row selected-row
update (paper Lemma 3.2).  The two-kernel formulation reads the O(K)
vectors (d, z, d_org, tau, R) from HBM twice and round-trips the full
zhat vector through HBM between kernels; the fused kernel reads them once
and keeps zhat in VMEM for the tile it was just reconstructed in -- the
merge is bandwidth-bound (paper Section 4.1), so this halves the streamed
traffic of the conquer post-phase.

Grid mapping: one grid step per POLE block (C poles).  A pole block's zhat
needs only its own rows of the delta structure over the full root range,
which is exactly the (C, K) tile the step forms -- so zhat finalizes
in-register and immediately weights the block's additive contribution to
every root column.  Column contributions and squared norms accumulate
across the (sequential) TPU grid into VMEM-resident output blocks; the
O(K) normalization happens on the final grid step.

VMEM budget per step: O(K) vectors + the (C, T) root-tile slab; the dense
(K, K) secular eigenvector block is never materialized.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_POLE_BLOCK = 128
DEFAULT_ROOT_TILE = 1024


def _root_tile_for(Kp: int, root_tile: int) -> int:
    """Largest tile <= root_tile that divides the padded length exactly
    (tiles must never clamp: clamped dynamic_slice would double-count)."""
    T = min(root_tile, Kp)
    while Kp % T:
        T //= 2
    return max(T, 1)


def _fused_kernel(R_ref, d_ref, z_ref, dorg_ref, tau_ref, rho_ref,
                  kprime_ref, zhat_ref, cols_ref, nrm2_ref, *,
                  root_tile, use_zhat, batched=False):
    # ``batched``: refs carry a leading length-1 problem-block dim and the
    # grid is (B, pole_blocks); the grid iterates problems in the major
    # axis, so each problem's accumulator init (first pole block) and
    # normalization (last pole block) stay correctly sequenced.
    r, Kp = R_ref.shape[-2:]
    C = zhat_ref.shape[-1]
    T = _root_tile_for(Kp, root_tile)
    num_tiles = Kp // T
    dtype = d_ref.dtype

    if batched:
        d = d_ref[0]
        z = z_ref[0]
        d_org = dorg_ref[0]
        tau = tau_ref[0]
        rho = rho_ref[0, 0]
        kprime = kprime_ref[0, 0]
        i = pl.program_id(1)
        num_blocks = pl.num_programs(1)
        read_cols = lambda: cols_ref[0]
        write_cols = lambda v: cols_ref.__setitem__(0, v)
        read_nrm2 = lambda: nrm2_ref[0]
        write_nrm2 = lambda v: nrm2_ref.__setitem__(0, v)
        R_full = R_ref[0]
    else:
        d = d_ref[...]
        z = z_ref[...]
        d_org = dorg_ref[...]
        tau = tau_ref[...]
        rho = rho_ref[0]
        kprime = kprime_ref[0]
        i = pl.program_id(0)
        num_blocks = pl.num_programs(0)
        read_cols = lambda: cols_ref[...]
        write_cols = lambda v: cols_ref.__setitem__(..., v)
        read_nrm2 = lambda: nrm2_ref[...]
        write_nrm2 = lambda v: nrm2_ref.__setitem__(..., v)
        R_full = R_ref[...]

    ic = i * C + jax.lax.iota(jnp.int32, C)
    valid_i = ic < kprime            # active, non-padded poles only
    d_i = d[ic]
    z_i = z[ic]

    @pl.when(i == 0)
    def _init():
        write_cols(jnp.zeros((r, Kp), dtype))
        write_nrm2(jnp.zeros((Kp,), dtype))

    # ---- phase 1: zhat for this pole block (row reduction over roots) ---
    # DLAED3 ratio-product form: numerator/denominator factors pair up as
    # interlaced ratios (lam_j - d_i)/(d_j - d_i), so the reduction is a
    # plain product -- no log/exp in the sweep.  Deflation guarantees pole
    # separation > tol, bounding the partials (LAPACK's own unscaled form).
    def tile(t, prod):
        start = (t * T).astype(jnp.int32)
        dt = jax.lax.dynamic_slice(d, (start,), (T,))
        dot = jax.lax.dynamic_slice(d_org, (start,), (T,))
        tt = jax.lax.dynamic_slice(tau, (start,), (T,))
        jt = start + jax.lax.iota(jnp.int32, T)
        jmask = (jt < kprime)[None, :]
        lam_diff = (dot[None, :] - d_i[:, None]) + tt[None, :]   # (C, T)
        pole_diff = dt[None, :] - d_i[:, None]
        selfmask = jt[None, :] == ic[:, None]
        ok = jmask & ~selfmask
        ratio = jnp.where(ok, lam_diff / jnp.where(ok, pole_diff, 1.0), 1.0)
        return prod * jnp.prod(ratio, axis=-1)

    if use_zhat:
        prod = jax.lax.fori_loop(0, num_tiles, tile,
                                 jnp.ones((C,), dtype))
        self_term = (d_org[ic] - d_i) + tau[ic]            # lam_i - d_i
        z2hat = jnp.abs(prod * self_term) / rho
        zhat_c = jnp.sign(z_i) * jnp.sqrt(z2hat)
        zhat_c = jnp.where(valid_i, zhat_c, z_i).astype(dtype)
    else:
        zhat_c = z_i
    if batched:
        zhat_ref[0, :] = zhat_c
    else:
        zhat_ref[...] = zhat_c
    w = jnp.where(valid_i, zhat_c, 0.0)

    # ---- phase 2: this block's contribution to every root column --------
    # zhat is still in VMEM; no HBM round-trip between the phases.
    Rc = jax.lax.dynamic_slice(
        R_full, (jnp.zeros((), jnp.int32), jnp.asarray(i * C, jnp.int32)),
        (r, C))

    def tile2(t, _):
        start = (t * T).astype(jnp.int32)
        dot = jax.lax.dynamic_slice(d_org, (start,), (T,))
        tt = jax.lax.dynamic_slice(tau, (start,), (T,))
        delta = (d_i[:, None] - dot[None, :]) - tt[None, :]      # (C, T)
        ok = valid_i[:, None] & (delta != 0.0)
        y = jnp.where(ok, w[:, None] / jnp.where(ok, delta, 1.0), 0.0)
        contrib = jax.lax.dot_general(
            Rc, y, (((1,), (0,)), ((), ())),
            preferred_element_type=dtype)                        # (r, T)
        prev = jax.lax.dynamic_slice(
            read_cols(), (jnp.zeros((), jnp.int32), start), (r, T))
        write_cols(jax.lax.dynamic_update_slice(
            read_cols(), prev + contrib,
            (jnp.zeros((), jnp.int32), start)))
        prevn = jax.lax.dynamic_slice(read_nrm2(), (start,), (T,))
        write_nrm2(jax.lax.dynamic_update_slice(
            read_nrm2(), prevn + jnp.sum(y * y, axis=0), (start,)))
        return 0

    jax.lax.fori_loop(0, num_tiles, tile2, 0)

    # Final grid step for this problem: apply the normalization in-place.
    @pl.when(i == num_blocks - 1)
    def _finalize():
        nrm = jnp.sqrt(read_nrm2())
        write_cols(read_cols() / jnp.where(nrm > 0.0, nrm, 1.0)[None, :])


@functools.partial(jax.jit, static_argnames=("use_zhat", "pole_block",
                                             "root_tile", "interpret"))
def secular_postpass_pallas(R, d, z, origin, tau, kprime, rho, *,
                            use_zhat: bool = True,
                            pole_block: int = DEFAULT_POLE_BLOCK,
                            root_tile: int = DEFAULT_ROOT_TILE,
                            interpret: bool = False):
    """Fused Pallas post-pass.  Contract of core.secular.secular_postpass.

    Returns (zhat, rows).  Both the pole and root index spaces are padded
    to the pole-block multiple; padded poles satisfy ic >= K >= kprime and
    contribute nothing, padded root columns are sliced off.
    """
    r, K = R.shape
    C = min(pole_block, K)
    grid = ((K + C - 1) // C,)
    Kp = grid[0] * C

    d_org = d[jnp.minimum(origin, K - 1)]
    if Kp != K:
        pad = Kp - K
        R_p = jnp.pad(R, ((0, 0), (0, pad)))
        d_p = jnp.pad(d, (0, pad))
        z_p = jnp.pad(z, (0, pad))
        dorg_p = jnp.pad(d_org, (0, pad))
        tau_p = jnp.pad(tau, (0, pad))
    else:
        R_p, d_p, z_p, dorg_p, tau_p = R, d, z, d_org, tau

    rho_arr = jnp.asarray(rho, d.dtype).reshape(1)
    kp_arr = jnp.asarray(kprime, jnp.int32).reshape(1)

    kernel = functools.partial(_fused_kernel, root_tile=root_tile,
                               use_zhat=use_zhat)
    zhat, cols, nrm2 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r, Kp), lambda i: (0, 0)),  # R resident
            pl.BlockSpec((Kp,), lambda i: (0,)),      # d
            pl.BlockSpec((Kp,), lambda i: (0,)),      # z
            pl.BlockSpec((Kp,), lambda i: (0,)),      # d[origin]
            pl.BlockSpec((Kp,), lambda i: (0,)),      # tau
            pl.BlockSpec((1,), lambda i: (0,)),       # rho
            pl.BlockSpec((1,), lambda i: (0,)),       # kprime
        ],
        out_specs=[
            pl.BlockSpec((C,), lambda i: (i,)),       # zhat, per pole block
            pl.BlockSpec((r, Kp), lambda i: (0, 0)),  # cols accumulator
            pl.BlockSpec((Kp,), lambda i: (0,)),      # nrm2 accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Kp,), d.dtype),
            jax.ShapeDtypeStruct((r, Kp), R.dtype),
            jax.ShapeDtypeStruct((Kp,), d.dtype),
        ],
        interpret=interpret,
    )(R_p, d_p, z_p, dorg_p, tau_p, rho_arr, kp_arr)

    active = jnp.arange(K) < kprime
    zhat = jnp.where(active, zhat[:K], z).astype(d.dtype)
    rows = jnp.where(active[None, :], cols[:, :K], R).astype(R.dtype)
    return zhat, rows


@functools.partial(jax.jit, static_argnames=("use_zhat", "pole_block",
                                             "root_tile", "interpret"))
def secular_postpass_pallas_batch(R, d, z, origin, tau, kprime, rho, *,
                                  use_zhat: bool = True,
                                  pole_block: int = DEFAULT_POLE_BLOCK,
                                  root_tile: int = DEFAULT_ROOT_TILE,
                                  interpret: bool = False):
    """Problem-batched fused post-pass: grid = (B, pole_blocks).

    R: (B, r, K); d, z, origin, tau: (B, K); kprime, rho: (B,).  Problems
    map to the major grid axis (their accumulator blocks are disjoint),
    pole blocks to the minor axis -- a whole batched merge level's
    post-pass is ONE kernel launch.  Per-problem math is identical to
    :func:`secular_postpass_pallas`.

    Returns (zhat (B, K), rows (B, r, K)).
    """
    B, r, K = R.shape
    C = min(pole_block, K)
    nblk = (K + C - 1) // C
    grid = (B, nblk)
    Kp = nblk * C

    d_org = jnp.take_along_axis(d, jnp.minimum(origin, K - 1), axis=1)
    if Kp != K:
        pad = Kp - K
        R_p = jnp.pad(R, ((0, 0), (0, 0), (0, pad)))
        d_p = jnp.pad(d, ((0, 0), (0, pad)))
        z_p = jnp.pad(z, ((0, 0), (0, pad)))
        dorg_p = jnp.pad(d_org, ((0, 0), (0, pad)))
        tau_p = jnp.pad(tau, ((0, 0), (0, pad)))
    else:
        R_p, d_p, z_p, dorg_p, tau_p = R, d, z, d_org, tau

    rho_arr = jnp.asarray(rho, d.dtype).reshape(B, 1)
    kp_arr = jnp.asarray(kprime, jnp.int32).reshape(B, 1)

    kernel = functools.partial(_fused_kernel, root_tile=root_tile,
                               use_zhat=use_zhat, batched=True)
    zhat, cols, nrm2 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, r, Kp), lambda b, i: (b, 0, 0)),  # R, resident
            pl.BlockSpec((1, Kp), lambda b, i: (b, 0)),        # d
            pl.BlockSpec((1, Kp), lambda b, i: (b, 0)),        # z
            pl.BlockSpec((1, Kp), lambda b, i: (b, 0)),        # d[origin]
            pl.BlockSpec((1, Kp), lambda b, i: (b, 0)),        # tau
            pl.BlockSpec((1, 1), lambda b, i: (b, 0)),         # rho
            pl.BlockSpec((1, 1), lambda b, i: (b, 0)),         # kprime
        ],
        out_specs=[
            pl.BlockSpec((1, C), lambda b, i: (b, i)),         # zhat
            pl.BlockSpec((1, r, Kp), lambda b, i: (b, 0, 0)),  # cols acc
            pl.BlockSpec((1, Kp), lambda b, i: (b, 0)),        # nrm2 acc
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Kp), d.dtype),
            jax.ShapeDtypeStruct((B, r, Kp), R.dtype),
            jax.ShapeDtypeStruct((B, Kp), d.dtype),
        ],
        interpret=interpret,
    )(R_p, d_p, z_p, dorg_p, tau_p, rho_arr, kp_arr)

    active = jnp.arange(K)[None, :] < kprime[:, None]
    zhat = jnp.where(active, zhat[:, :K], z).astype(d.dtype)
    rows = jnp.where(active[:, None, :], cols[:, :, :K], R).astype(R.dtype)
    return zhat, rows
