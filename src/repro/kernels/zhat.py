"""Pallas TPU kernel: Gu-Eisenstat secular weight reconstruction.

LAPACK DLAED3's stable-weight recomputation, streamed: for each active
pole i,

    zhat_i^2 = prod_j (lam_j - d_i) / [rho * prod_{j != i} (d_j - d_i)]

with lam_j - d_i evaluated through the compact delta representation
(d_org_j - d_i) + tau_j -- the paper's cancellation-free denominator form.
Log-space accumulation over root tiles keeps the temporary at
(POLE_BLOCK, ROOT_TILE) and is robust to K ~ 10^5 products.

Grid over pole blocks; all O(K) vectors VMEM-resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_POLE_BLOCK = 128
DEFAULT_ROOT_TILE = 1024


def _zhat_kernel(d_ref, z_ref, dorg_ref, tau_ref, rho_ref, kprime_ref,
                 out_ref, *, root_tile):
    K = d_ref.shape[0]
    C = out_ref.shape[0]
    T = min(root_tile, K)
    num_tiles = (K + T - 1) // T
    dtype = d_ref.dtype

    d = d_ref[...]
    z = z_ref[...]
    d_org = dorg_ref[...]
    tau = tau_ref[...]
    rho = rho_ref[0]
    kprime = kprime_ref[0]

    i = pl.program_id(0)
    ic = i * C + jax.lax.iota(jnp.int32, C)
    ic_safe = jnp.minimum(ic, K - 1)
    active_i = ic < kprime
    d_i = d[ic_safe]
    tiny = jnp.finfo(dtype).tiny

    def body(t, acc):
        log_num, log_den = acc
        start = t * T
        dt = jax.lax.dynamic_slice(d, (start,), (T,))
        dot = jax.lax.dynamic_slice(d_org, (start,), (T,))
        tt = jax.lax.dynamic_slice(tau, (start,), (T,))
        jt = start + jax.lax.iota(jnp.int32, T)
        jmask = (jt < kprime)[None, :]
        lam_diff = (dot[None, :] - d_i[:, None]) + tt[None, :]    # (C, T)
        pole_diff = dt[None, :] - d_i[:, None]
        selfmask = jt[None, :] == ic_safe[:, None]
        log_num = log_num + jnp.sum(
            jnp.where(jmask, jnp.log(jnp.maximum(jnp.abs(lam_diff), tiny)), 0.0),
            axis=-1)
        log_den = log_den + jnp.sum(
            jnp.where(jmask & ~selfmask,
                      jnp.log(jnp.maximum(jnp.abs(pole_diff), tiny)), 0.0),
            axis=-1)
        return log_num, log_den

    zero = jnp.zeros((C,), dtype)
    log_num, log_den = jax.lax.fori_loop(0, num_tiles, body, (zero, zero))
    z2hat = jnp.exp(log_num - log_den) / rho
    z_i = z[ic_safe]
    zhat = jnp.sign(z_i) * jnp.sqrt(jnp.maximum(z2hat, 0.0))
    out_ref[...] = jnp.where(active_i, zhat, z_i).astype(dtype)


@functools.partial(jax.jit, static_argnames=("pole_block", "root_tile",
                                             "interpret"))
def zhat_reconstruct_pallas(d, z, origin, tau, kprime, rho, *,
                            pole_block: int = DEFAULT_POLE_BLOCK,
                            root_tile: int = DEFAULT_ROOT_TILE,
                            interpret: bool = False):
    """Pallas zhat reconstruction.  Contract of core.secular.zhat_reconstruct."""
    K = d.shape[0]
    C = min(pole_block, K)
    grid = ((K + C - 1) // C,)
    Kp = grid[0] * C

    d_org = d[jnp.minimum(origin, K - 1)]
    if Kp != K:
        d_p = jnp.pad(d, (0, Kp - K))
        z_p = jnp.pad(z, (0, Kp - K))
        dorg_p = jnp.pad(d_org, (0, Kp - K))
        tau_p = jnp.pad(tau, (0, Kp - K))
    else:
        d_p, z_p, dorg_p, tau_p = d, z, d_org, tau

    rho_arr = jnp.asarray(rho, d.dtype).reshape(1)
    kp_arr = jnp.asarray(kprime, jnp.int32).reshape(1)

    kernel = functools.partial(_zhat_kernel, root_tile=root_tile)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Kp,), lambda i: (0,)),
            pl.BlockSpec((Kp,), lambda i: (0,)),
            pl.BlockSpec((Kp,), lambda i: (0,)),
            pl.BlockSpec((Kp,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((C,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Kp,), d.dtype),
        interpret=interpret,
    )(d_p, z_p, dorg_p, tau_p, rho_arr, kp_arr)
    return out[:K]
