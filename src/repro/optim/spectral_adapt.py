"""Spectral LR governor: eigenvalue-only curvature -> lr_scale.

The paper's motivating workflow ("the application needs the eigenvalues
before deciding whether eigenvectors are necessary", Section 1) realized as
an optimizer feature: every `period` steps the trainer runs SLQ on the
curvature operator (eigenvalues only -- no eigenvector state is ever
materialized, which is exactly what BR makes cheap) and the governor maps
lam_max to a learning-rate scale:

    scale = min(1, target_sharpness / lam_max)

i.e. classic 2/eta stability control.  Between probes the scale is held.

``probe`` is the governor's native measurement: it runs the
partial-spectrum path (``repro.spectral.spectral_edges`` -- Sturm-sliced
top-1 Ritz value of the Krylov tridiagonal) rather than a full SLQ
spectrum, because lam_max is a 1-of-m eigenvalue problem and the sliced
solver does exactly that much work.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class SpectralGovernor:
    target_sharpness: float = 100.0
    min_scale: float = 0.05
    period: int = 50
    ema: float = 0.7
    _lam_max: float = 0.0
    _scale: float = 1.0

    def should_probe(self, step: int) -> bool:
        return step % self.period == 0

    def probe(self, matvec, params_like, rng, *, num_steps: int = 16) -> float:
        """Measure lam_max via the sliced extremal-edge path and update.

        One Lanczos probe reduced to a single sliced eigenvalue solve
        (index m-1 of the Krylov tridiagonal) -- no full spectrum, no
        boundary rows, no merge tree.  Returns the new lr scale.
        """
        from repro.spectral.slq import sharpness  # deferred: heavy import
        return self.update(sharpness(matvec, params_like, rng,
                                     num_steps=num_steps))

    def update(self, lam_max: float) -> float:
        if self._lam_max == 0.0:
            self._lam_max = lam_max
        else:
            self._lam_max = self.ema * self._lam_max + (1 - self.ema) * lam_max
        if self._lam_max > 0:
            self._scale = max(self.min_scale,
                              min(1.0, self.target_sharpness / self._lam_max))
        return self._scale

    @property
    def scale(self) -> float:
        return self._scale

    @property
    def lam_max(self) -> float:
        return self._lam_max
