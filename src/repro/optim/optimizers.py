"""Hand-rolled optimizers (no optax dependency).

API:
    opt = adamw(lr=3e-4)
    state = opt.init(params)
    new_params, new_state = opt.apply(params, grads, state, lr_scale=1.0)

`lr_scale` is the hook the spectral governor (optim/spectral_adapt.py)
drives from eigenvalue-only curvature estimates.

State dtype is configurable: bf16 moments for HBM-constrained dry-runs,
Adafactor for the 400B-class MoE (factored second moment, O(m+n) per
matrix instead of O(mn)).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    apply: Callable[..., Any]
    name: str


def _cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def sgd(lr: float = 1e-2, momentum: float = 0.9):
    def init(params):
        return {"mu": jax.tree.map(jnp.zeros_like, params),
                "count": jnp.zeros((), jnp.int32)}

    def apply(params, grads, state, lr_scale=1.0):
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype),
                          state["mu"], grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32)
                          - lr * lr_scale * m.astype(jnp.float32)).astype(p.dtype),
            params, mu)
        return new_params, {"mu": mu, "count": state["count"] + 1}

    return Optimizer(init, apply, "sgd")


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          state_dtype=jnp.float32):
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def apply(params, grads, state, lr_scale=1.0):
        c = state["count"] + 1
        bc1 = 1.0 - b1 ** c.astype(jnp.float32)
        bc2 = 1.0 - b2 ** c.astype(jnp.float32)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            mn = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            vn = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            step = (mn / bc1) / (jnp.sqrt(vn / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            pn = p.astype(jnp.float32) - lr * lr_scale * step
            return pn.astype(p.dtype), mn.astype(state_dtype), vn.astype(state_dtype)

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
        v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": m, "v": v, "count": c}

    return Optimizer(init, apply, "adamw")


def adafactor(lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0):
    """Factored second-moment optimizer (Shazeer & Stern 2018).

    For any parameter with >= 2 dims the second moment is stored as a
    (row, col) outer-product factorization over the trailing two axes --
    O(m+n) state, which is what lets the 782B-param llama4 cell fit the
    dry-run HBM budget (EXPERIMENTS.md)."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def zeros(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(zeros, params,
                                  is_leaf=lambda x: isinstance(x, jnp.ndarray)),
                "count": jnp.zeros((), jnp.int32)}

    def apply(params, grads, state, lr_scale=1.0):
        c = state["count"] + 1
        beta = 1.0 - c.astype(jnp.float32) ** -decay

        def upd(p, g, v):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if _factored(p):
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(jnp.mean(vr, axis=-1,
                                                keepdims=True)[..., None], eps))
                step = gf * jax.lax.rsqrt(jnp.maximum(denom, eps))
                nv = {"vr": vr, "vc": vc}
            else:
                vn = beta * v["v"] + (1 - beta) * g2
                step = gf * jax.lax.rsqrt(jnp.maximum(vn, eps))
                nv = {"v": vn}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(step * step) + eps)
            step = step / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            pn = p.astype(jnp.float32) - lr * lr_scale * step
            return pn.astype(p.dtype), nv

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_v = tdef.unflatten([o[1] for o in out])
        return new_params, {"v": new_v, "count": c}

    return Optimizer(init, apply, "adafactor")


OPTIMIZERS = {"sgd": sgd, "adamw": adamw, "adafactor": adafactor}


def get_optimizer(name: str, **kw) -> Optimizer:
    return OPTIMIZERS[name](**kw)
