from repro.optim.optimizers import Optimizer, adamw, adafactor, sgd
from repro.optim.spectral_adapt import SpectralGovernor

__all__ = ["Optimizer", "SpectralGovernor", "adafactor", "adamw", "sgd"]
