"""Coalescing micro-batch scheduler over the plan-cache route keys.

Concurrent callers submit :class:`~repro.core.request.SolveRequest`s;
the scheduler routes each one (``route_request`` -- pure, raises on
malformed input without touching anyone else), groups pending requests
by their batch-unresolved route key, and hands flush batches to the
engine when a group hits one of three triggers:

  * **max_batch**   -- the group holds a full bucket of problems,
  * **max_wait_us** -- the group's oldest request has waited long enough
                       (the latency the service is willing to trade for
                       coalescing),
  * **pressure**    -- the bounded queue is full, so waiting longer
                       cannot increase coalescing.

Two requests coalesce *iff* their route keys are equal -- the grouping
invariant ``resolve_solve_route`` guarantees (equal keys => one shared
compiled executable for the flushed batch).  Unroutable requests
(baseline methods, n == 1) form singleton groups flushed immediately.

Backpressure: ``submit`` blocks while ``queue_depth`` problems are
already pending (bounded queue), so a slow device propagates to callers
instead of growing the heap; ``peak_pending`` records the high-water
mark the bound was observed to hold.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future

from repro.core.request import RoutedRequest, SolveRequest, route_request
from repro.serve.metrics import ServeMetrics, bucket_label


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs of the serving layer (see README "Serving")."""
    max_batch: int = 64          # problems per flush (per group)
    max_wait_us: int = 2000      # oldest-request age that forces a flush
    queue_depth: int = 256       # bounded queue: max pending problems
    submit_timeout_s: float = 30.0   # how long submit may block when full
    retries: int = 1             # transient-device-error relaunches per
                                 # flush (0 disables; deterministic error
                                 # classes never relaunch)
    retry_backoff_s: float = 0.05
    heartbeat_path: str | None = None   # Watchdog file (None: temp dir)
    watchdog_timeout_s: float = 300.0
    straggler_window: int = 64
    straggler_threshold: float = 3.0


@dataclasses.dataclass
class PendingRequest:
    """One queued request: its route, its future, and its clocks.

    ``deadline_t`` is the absolute monotonic instant the request's
    ``deadline_ms`` budget runs out (None: no deadline).  The engine --
    not the scheduler -- enforces it, failing expired requests with
    :class:`repro.core.guard.DeadlineExceeded` at flush assembly (so an
    expired request never holds a launch slot) and again at demux (so a
    slow flush cannot resolve a request past its budget).
    """
    routed: RoutedRequest
    future: Future
    submit_t: float
    deadline_t: float | None = None

    @property
    def problems(self) -> int:
        return self.routed.batch

    def expired(self, now: float) -> bool:
        return self.deadline_t is not None and now >= self.deadline_t


class SchedulerClosed(RuntimeError):
    pass


class QueueFull(RuntimeError):
    pass


class CoalescingScheduler:
    """Request intake + grouping; the engine drains it via ``next_flush``."""

    def __init__(self, config: ServeConfig | None = None,
                 metrics: ServeMetrics | None = None):
        self.config = config or ServeConfig()
        self.metrics = metrics or ServeMetrics()
        self._cv = threading.Condition()
        # route key (or a unique direct token) -> list[PendingRequest];
        # insertion order preserved so the oldest group flushes first.
        self._groups: dict = {}
        self._pending = 0
        self._closed = False
        self.peak_pending = 0

    # ------------------------------------------------------------ intake

    def submit(self, request: SolveRequest) -> Future:
        """Enqueue a request; returns a Future resolving to SolveResult.

        Routing errors (bad shapes, unknown methods, malformed windows)
        fail only this request's future.  Blocks under backpressure; a
        full queue past ``submit_timeout_s`` fails the future with
        :class:`QueueFull`.
        """
        future: Future = Future()
        try:
            routed = route_request(request)
        except Exception as exc:  # poisoned request: isolate at the door
            self.metrics.record_error("rejected")
            future.set_exception(exc)
            return future

        if routed.empty:
            # A select="v" window with no eigenvalues: nothing to launch.
            from repro.core.request import execute_request
            future.set_result(execute_request(routed))
            return future

        label = bucket_label(routed.route)
        deadline = time.monotonic() + self.config.submit_timeout_s
        with self._cv:
            while (not self._closed
                   and self._pending + routed.batch > self.config.queue_depth
                   and self._pending > 0):
                remaining = deadline - time.monotonic()
                if remaining <= 0 or not self._cv.wait(remaining):
                    self.metrics.record_error(label)
                    future.set_exception(QueueFull(
                        f"serve queue full ({self._pending} problems "
                        f"pending >= queue_depth={self.config.queue_depth})"))
                    return future
            if self._closed:
                future.set_exception(SchedulerClosed("scheduler is closed"))
                return future
            key = routed.route if routed.route is not None \
                else ("direct", id(future))
            now = time.monotonic()
            deadline_t = (None if request.deadline_ms is None
                          else now + request.deadline_ms * 1e-3)
            self._groups.setdefault(key, []).append(
                PendingRequest(routed, future, now, deadline_t))
            self._pending += routed.batch
            self.peak_pending = max(self.peak_pending, self._pending)
            self.metrics.record_submit(label, routed.batch)
            self._cv.notify_all()
        return future

    # ------------------------------------------------------------ drain

    def pending_problems(self) -> int:
        with self._cv:
            return self._pending

    def next_flush(self, timeout: float | None = 0.05):
        """Block until a group is due and pop its flush batch.

        Returns a non-empty list of :class:`PendingRequest` sharing one
        route key (at most ``max_batch`` problems; an oversized single
        request flushes alone), or None when the timeout expires with
        nothing due, or None immediately when closed and drained.
        """
        deadline = (time.monotonic() + timeout
                    if timeout is not None else None)
        with self._cv:
            while True:
                batch = self._pop_due_locked()
                if batch:
                    self._pending -= sum(p.problems for p in batch)
                    self._cv.notify_all()
                    return batch
                if self._closed and not self._groups:
                    return None
                now = time.monotonic()
                wait_until = deadline
                oldest = self._oldest_deadline_locked()
                if oldest is not None:
                    wait_until = (oldest if wait_until is None
                                  else min(wait_until, oldest))
                if wait_until is None:
                    self._cv.wait()
                    continue
                if wait_until <= now:
                    if deadline is not None and deadline <= now:
                        return None
                    continue  # a group just came due; re-evaluate
                self._cv.wait(wait_until - now)
                if (deadline is not None and time.monotonic() >= deadline
                        and not self._any_due_locked()):
                    return None

    def close(self) -> None:
        """Stop intake; queued work stays flushable (drained by the
        engine -- close makes every group immediately due)."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    @property
    def closed(self) -> bool:
        with self._cv:
            return self._closed

    # ------------------------------------------------------- internals

    def _group_due_locked(self, key, group, now) -> bool:
        if not group:
            return False
        if isinstance(key, tuple) and key and key[0] == "direct":
            return True   # unroutable: nothing to coalesce with
        if self._closed:
            return True
        size = sum(p.problems for p in group)
        if size >= self.config.max_batch:
            return True
        if self._pending >= self.config.queue_depth:
            return True   # pressure: waiting cannot add coalescing
        age_us = (now - group[0].submit_t) * 1e6
        return age_us >= self.config.max_wait_us

    def _any_due_locked(self) -> bool:
        now = time.monotonic()
        return any(self._group_due_locked(k, g, now)
                   for k, g in self._groups.items())

    def _oldest_deadline_locked(self):
        """Earliest moment any current group becomes due by age."""
        deadlines = [g[0].submit_t + self.config.max_wait_us * 1e-6
                     for g in self._groups.values() if g]
        return min(deadlines) if deadlines else None

    def _pop_due_locked(self):
        now = time.monotonic()
        best_key, best_size = None, -1
        for key, group in self._groups.items():
            if not self._group_due_locked(key, group, now):
                continue
            size = sum(p.problems for p in group)
            if size > best_size:
                best_key, best_size = key, size
        if best_key is None:
            return None
        group = self._groups[best_key]
        batch, taken = [], 0
        while group:
            nxt = group[0]
            if batch and taken + nxt.problems > self.config.max_batch:
                break   # leave the remainder for the next flush
            batch.append(group.pop(0))
            taken += nxt.problems
            if taken >= self.config.max_batch:
                break
        if not group:
            del self._groups[best_key]
        return batch
