"""Serving engine: the worker loop that turns flush batches into device
launches and demuxes results back onto request futures.

One daemon thread owns the device:

    next_flush -> stage (host pack + pad) -> launch (async dispatch)
               -> [stage/launch the NEXT flush]  -> finish (wait + demux)

Staging and launching of flush i+1 overlap the device execution of flush
i (double buffering): JAX dispatch is asynchronous, so ``_launch``
returns as soon as the work is enqueued and ``_finish`` blocks on the
previous flush's arrays only after the next one is already in flight.

Mixed-n solve flushes are host-padded to the group's common padded width
with ``_host_pad`` -- a numpy mirror of ``br_dc._pad_problem``'s
decoupled-sentinel construction (kept bitwise identical; pinned by
tests), so every problem's padded rows are exactly the rows its sync
solve would have produced internally and service results stay bit-for-bit
equal to the sync API.  Each problem's own boundary row rides the traced
track slot (``SolvePlan.execute(orig_n=...)``).

Reliability comes from the ``repro.runtime`` substrate: a
:class:`~repro.runtime.watchdog.Watchdog` heartbeats once per flush, a
per-bucket :class:`~repro.runtime.straggler.StragglerMonitor` flags slow
flushes against the bucket's own timing baseline, and
:func:`~repro.runtime.retry.retry_transient` retries transient device
errors.  A flush that still fails falls back to solving its requests one
by one, so a poisoned request fails alone and its flushmates complete.
"""

from __future__ import annotations

import os
import tempfile
import threading
import time
from concurrent.futures import InvalidStateError

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import guard as _guard
from repro.core import plan as _plan
from repro.core.request import SolveResult, _finalize_lanes, execute_request
from repro.runtime import StragglerMonitor, Watchdog, retry_transient
from repro.runtime import faults as _faults
from repro.runtime.retry import TRANSIENT_DEFAULT
from repro.serve.metrics import ServeMetrics, bucket_label
from repro.serve.scheduler import CoalescingScheduler, ServeConfig


def _resolve_future(future, result=None, exc=None) -> None:
    """Resolve a request future, tolerating callers that cancelled (or a
    fallback re-resolving members a partial demux already set): an
    InvalidStateError here must never escape into the worker loop -- a
    dead engine thread would hang every subsequent request forever."""
    try:
        if future.done():
            return
        if exc is not None:
            future.set_exception(exc)
        else:
            future.set_result(result)
    except InvalidStateError:
        pass


def _host_pad(d: np.ndarray, e: np.ndarray, N: int):
    """Pad (B, n) problems to width N with decoupled sentinel blocks.

    Bitwise mirror of ``br_dc._pad_problem`` (numpy instead of jnp so
    staging costs no device dispatches): sentinel diagonal entries sit
    above each problem's own Gershgorin bound, couplings into the padded
    region are exactly zero.  Returns (d_pad (B, N), e_pad (B, N-1)).
    """
    B, n = d.shape
    if n == N:
        return d, e
    emax = (np.max(np.abs(e), axis=1) if e.shape[1]
            else np.zeros((B,), d.dtype))
    # dtype-typed constants: NumPy 1.x value-based promotion silently
    # lifts `2.0 * f32_array` to f64, which would stage f32 traffic
    # through an f64 sentinel row (bitwise identical for f64 batches,
    # a silent promotion for f32/mixed ones).
    two = d.dtype.type(2.0)
    one = d.dtype.type(1.0)
    sentinel = np.max(np.abs(d), axis=1) + two * emax + one
    d_pad = np.concatenate(
        [d, np.broadcast_to(sentinel[:, None], (B, N - n)).astype(d.dtype)],
        axis=1)
    e_pad = np.concatenate([e, np.zeros((B, N - n), d.dtype)], axis=1)
    return d_pad, e_pad


def _flush_ready(flush: "_Flush") -> bool:
    """True when finishing the flush would not block (device done or the
    flush already failed); conservative True for results that are not
    lazy jax arrays (direct-path SolveResults may hold numpy)."""
    if flush.error is not None:
        return True
    obj = getattr(flush.result, "eigenvalues", flush.result)
    is_ready = getattr(obj, "is_ready", None)
    return True if is_ready is None else bool(is_ready())


class _Flush:
    """One staged flush: the launch inputs plus everything needed to
    demux device outputs back onto the member requests.  ``cert`` holds
    the flush-wide certificate mask (one batched Sturm sweep over the
    padded flush) when the route carries ``certify=True``."""
    __slots__ = ("batch", "route", "label", "result", "error", "t_launch",
                 "cert")

    def __init__(self, batch, route, label):
        self.batch = batch
        self.route = route
        self.label = label
        self.result = None
        self.error: BaseException | None = None
        self.t_launch = 0.0
        self.cert = None


class ServeEngine:
    """Owns the worker thread, the watchdog, and per-bucket monitors."""

    def __init__(self, scheduler: CoalescingScheduler,
                 config: ServeConfig | None = None,
                 metrics: ServeMetrics | None = None):
        self.scheduler = scheduler
        self.config = config or scheduler.config
        self.metrics = metrics or scheduler.metrics
        hb = self.config.heartbeat_path or os.path.join(
            tempfile.gettempdir(), f"repro-serve-heartbeat-{os.getpid()}.json")
        self._watchdog = Watchdog(hb, timeout_s=self.config.watchdog_timeout_s)
        self._stragglers: dict[str, StragglerMonitor] = {}
        self._thread: threading.Thread | None = None
        self._flush_index = 0
        self._last_beat = 0.0
        self._beat_warned = False

    # ---------------------------------------------------------- lifecycle

    def start(self) -> "ServeEngine":
        if self._thread is None:
            self._watchdog.start()
            self._thread = threading.Thread(
                target=self._loop, name="repro-serve-engine", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        """Drain the queue (the scheduler is closed first) and join."""
        self.scheduler.close()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._watchdog.stop()

    # --------------------------------------------------------------- loop

    def _loop(self) -> None:
        inflight: _Flush | None = None
        while True:
            if (inflight is None and self.scheduler.closed
                    and self.scheduler.pending_problems() == 0):
                return
            try:
                inflight = self._loop_once(inflight)
            except Exception as exc:
                # The worker thread must survive ANYTHING -- a dead
                # engine hangs every queued and future request forever
                # with zero errors reported.  Resolve whatever flush was
                # in flight (fallback skips already-done futures) and
                # keep serving.
                if inflight is not None:
                    for p in inflight.batch:
                        _resolve_future(p.future, exc=exc)
                    inflight = None
                else:
                    # Nothing to fail -- but never drop the evidence.
                    print(f"[serve] engine loop error (no flush in "
                          f"flight): {exc!r}", flush=True)

    def _loop_once(self, inflight: _Flush | None) -> _Flush | None:
        # Non-blocking poll while a flush is in flight (so it can be
        # finished the moment no follow-up work is due); short waits
        # otherwise to notice close/drain quickly.
        timeout = 0.0 if inflight is not None else 0.05
        batch = self.scheduler.next_flush(timeout=timeout)
        if batch is not None:
            # Flush assembly is the first point the engine owns the
            # requests: fail the ones whose deadline_ms budget ran out
            # while they were queued, so they never hold a launch slot.
            batch = self._reap_expired(batch)
        if not batch:
            if inflight is not None:
                self._finish_safely(inflight)
            else:
                self._idle_beat()
            return None
        if inflight is not None and _flush_ready(inflight):
            # Device already done: finish first so the flush's timing
            # (and its waiters' latency) don't absorb the next flush's
            # staging cost.
            self._finish_safely(inflight)
            inflight = None
        flush = self._stage_and_launch(batch)
        if inflight is not None:
            self._finish_safely(inflight)
        return flush

    def _finish_safely(self, flush: _Flush) -> None:
        """_finish with a last-resort guard: no matter what the finish
        bookkeeping does, every member future ends up resolved and the
        exception never reaches the worker loop with another flush in
        flight."""
        try:
            self._finish(flush)
        except Exception as exc:
            flush.error = exc
            try:
                self._fallback(flush)
            except Exception:
                for p in flush.batch:
                    _resolve_future(p.future, exc=exc)

    def _idle_beat(self) -> None:
        """Keep the heartbeat fresh while the service is merely idle --
        the Watchdog protocol means 'worker thread alive', not 'traffic
        present', so an external supervisor must not restart a healthy
        but quiet server."""
        now = time.monotonic()
        if now - self._last_beat >= min(30.0,
                                        self.config.watchdog_timeout_s / 4):
            self._beat(idle=True)

    def _beat(self, **info) -> None:
        self._last_beat = time.monotonic()
        try:
            self._watchdog.beat(self._flush_index, **info)
        except OSError as exc:
            # An unwritable heartbeat path degrades monitoring, never
            # serving (and must never kill the worker thread).
            if not self._beat_warned:
                self._beat_warned = True
                print(f"[serve] heartbeat write failed ({exc!r}); "
                      f"watchdog protocol degraded", flush=True)

    # ------------------------------------------------------------- stages

    def _stage_and_launch(self, batch) -> _Flush:
        """Stage + dispatch one flush; JAX dispatch is async so this
        returns while the device still computes.  Errors (including any
        raised at dispatch) are handled in _finish, whose relaunch path
        owns the transient-retry budget -- execution faults only surface
        at block_until_ready there, so that is where retrying belongs."""
        route = batch[0].routed.route
        flush = _Flush(batch, route, bucket_label(route))
        flush.t_launch = time.perf_counter()
        try:
            # Chaos site "serve.stage": a delay here stalls staging (the
            # straggler monitor and watchdog see it); an error demotes
            # the flush to the retry/fallback path like any staging bug.
            _faults.inject("serve.stage")
            flush.result = self._launch(flush)
        except Exception as exc:   # retried/isolated in _finish
            flush.error = exc
        return flush

    def _reap_expired(self, batch):
        now = time.monotonic()
        live = []
        for p in batch:
            if p.expired(now):
                self._fail_deadline(p, bucket_label(p.routed.route))
            else:
                live.append(p)
        return live

    def _fail_deadline(self, p, label: str) -> None:
        self.metrics.record_deadline(label)
        self.metrics.record_error(label)
        _guard.DEADLINES.increment()
        waited_ms = (time.monotonic() - p.submit_t) * 1e3
        _resolve_future(p.future, exc=_guard.DeadlineExceeded(
            f"request expired: deadline_ms="
            f"{p.routed.request.deadline_ms:g} budget exhausted "
            f"({waited_ms:.1f} ms since submit)"))

    def _launch_and_wait(self, flush: _Flush):
        result = self._launch(flush)
        jax.block_until_ready(getattr(result, "eigenvalues", result))
        return result

    def _launch(self, flush: _Flush):
        # Chaos site "serve.launch": hit once per launch *attempt*, so a
        # count-driven schedule can fail the first dispatch and let the
        # transient-retry relaunch succeed (or keep failing to force the
        # per-request fallback).
        _faults.inject("serve.launch")
        route = flush.route
        if isinstance(route, _plan.PlanKey):
            return self._launch_solve(flush)
        if isinstance(route, _plan.RangePlanKey):
            return self._launch_range(flush)
        # Direct (uncoalescable) request: the sync path, one launch.
        return execute_request(flush.batch[0].routed)

    def _launch_solve(self, flush: _Flush):
        route = flush.route
        N = route.padded_n
        ds, es, orig_n = [], [], []
        for p in flush.batch:
            d = np.asarray(p.routed.d)
            e = np.asarray(p.routed.e)
            d, e = _host_pad(d, e, N)
            ds.append(d)
            es.append(e)
            orig_n.extend([p.routed.n] * p.routed.batch)
        d_all = np.concatenate(ds, axis=0)
        e_all = np.concatenate(es, axis=0)
        plan = _plan.plan_for_route(route, d_all.shape[0])
        res = plan.execute(d_all, e_all,
                           orig_n=np.asarray(orig_n, np.int32))
        if route.certify:
            # One batched Sturm sweep certifies the WHOLE flush against
            # the padded inputs.  Bit-equivalent to each member's sync
            # certificate: padding is decoupled (zero couplings, sentinel
            # rows above the Gershgorin bound) so counts at real targets
            # are unchanged, and the executor masks sentinel rows out of
            # the per-problem tolerance norm.  Dispatch is async -- demux
            # materializes the mask alongside the eigenvalues.
            from repro.core import bisect as _bis
            lam = res.eigenvalues
            dj = jnp.asarray(d_all)
            ej = jnp.asarray(e_all)
            flush.cert = _bis._certify_executor(
                dj, ej * ej, lam, jnp.asarray(orig_n, jnp.int32),
                jnp.asarray(route.refine_tol, dj.dtype))[0]
        return res

    def _launch_range(self, flush: _Flush):
        d_all = np.concatenate([np.asarray(p.routed.d)
                                for p in flush.batch], axis=0)
        e_all = np.concatenate([np.asarray(p.routed.e)
                                for p in flush.batch], axis=0)
        il = np.concatenate([np.full((p.routed.batch,), p.routed.il)
                             for p in flush.batch])
        k = max(p.routed.k for p in flush.batch)
        plan = _plan.range_plan_for_route(flush.route, d_all.shape[0])
        return plan.execute(d_all, e_all, il, k)

    # ------------------------------------------------------------- finish

    def _finish(self, flush: _Flush) -> None:
        if flush.error is None:
            try:
                jax.block_until_ready(
                    getattr(flush.result, "eigenvalues", flush.result))
            except Exception as exc:
                flush.error = exc
        if (flush.error is not None and self.config.retries > 0
                and isinstance(flush.error, TRANSIENT_DEFAULT)):
            # Transient device faults (preemption, flaky interconnect)
            # surface either at dispatch or at block_until_ready; give
            # the whole launch+wait the configured retry budget before
            # demoting the flush to per-request fallback.  Errors outside
            # the transient classes (ValueError etc.) skip straight to
            # fallback -- relaunching a whole coalesced batch on a
            # deterministic failure would head-of-line block every other
            # bucket for retries * backoff.
            self.metrics.record_retry(flush.label)
            relaunch = retry_transient(
                self._launch_and_wait, retries=self.config.retries - 1,
                backoff_s=self.config.retry_backoff_s,
                on_retry=lambda i, exc: self.metrics.record_retry(
                    flush.label))
            try:
                flush.result = relaunch(flush)
                flush.error = None
            except Exception as exc:
                flush.error = exc
        if flush.error is not None:
            self._fallback(flush)
            return
        duration = time.perf_counter() - flush.t_launch
        try:
            self._demux(flush)
        except Exception as exc:
            flush.error = exc
            self._fallback(flush)
            return
        problems = sum(p.problems for p in flush.batch)
        self.metrics.record_flush(flush.label, len(flush.batch), problems,
                                  duration)
        now = time.monotonic()
        for p in flush.batch:
            self.metrics.record_latency(flush.label, now - p.submit_t)
        self._flush_index += 1
        self._beat(bucket=flush.label, requests=len(flush.batch),
                   problems=problems)
        mon = self._stragglers.get(flush.label)
        if mon is None:
            mon = self._stragglers[flush.label] = StragglerMonitor(
                window=self.config.straggler_window,
                threshold=self.config.straggler_threshold)
        mon.record(self._flush_index, duration)

    def _demux(self, flush: _Flush) -> None:
        # One host transfer per flushed output, numpy views per request:
        # slicing the (possibly device-sharded) batch arrays on device
        # would dispatch a gather per request -- measurably slower than
        # the serving win at small n.
        route = flush.route
        if isinstance(route, _plan.PlanKey):
            res = flush.result
            lam_all = np.asarray(res.eigenvalues)
            blo_all = None if res.blo is None else np.asarray(res.blo)
            bhi_all = None if res.bhi is None else np.asarray(res.bhi)
            cert_all = None if flush.cert is None else np.asarray(flush.cert)
            now = time.monotonic()
            off = 0
            for p in flush.batch:
                r = p.routed
                end = off + r.batch
                lam = lam_all[off:end, :r.n]
                blo = None if blo_all is None else blo_all[off:end, :r.n]
                bhi = None if bhi_all is None else bhi_all[off:end, :r.n]
                cert = None if cert_all is None else cert_all[off:end, :r.n]
                off = end
                if p.expired(now):
                    # Post-launch deadline check: the flush finished, but
                    # this member's budget ran out while it executed.
                    self._fail_deadline(p, flush.label)
                    continue
                try:
                    # Per-request degradation ladder -- the SAME
                    # finalizer the sync path runs, so a request gets one
                    # answer whether it ran alone or coalesced.  The
                    # host transfer above already paid for the finite
                    # check, so demux always screens for output poison.
                    lam, blo, bhi, diag = _finalize_lanes(
                        r, lam, blo, bhi, cert=cert, check_finite=True)
                except Exception as exc:
                    # A member whose ladder is exhausted fails ALONE; its
                    # flushmates keep demuxing.
                    self.metrics.record_error(flush.label)
                    _resolve_future(p.future, exc=exc)
                    continue
                if diag and diag.get("escalations"):
                    self.metrics.record_degradation(
                        flush.label,
                        lanes=sum(ev["lanes"]
                                  for ev in diag["escalations"]))
                if r.request.kind == "full":
                    lam = lam[0]
                    blo = None if blo is None else blo[0]
                    bhi = None if bhi is None else bhi[0]
                _resolve_future(p.future, SolveResult(
                    eigenvalues=lam, blo=blo, bhi=bhi,
                    kind=r.request.kind, method=r.request.method,
                    diagnostics=diag))
        elif isinstance(route, _plan.RangePlanKey):
            lam_all = np.asarray(flush.result)
            now = time.monotonic()
            off = 0
            for p in flush.batch:
                r = p.routed
                lam = lam_all[off:off + r.batch, :r.k]
                off += r.batch
                if p.expired(now):
                    self._fail_deadline(p, flush.label)
                    continue
                diag = None
                if r.scale != 1.0:
                    inv = np.dtype(lam.dtype).type(1.0 / r.scale)
                    lam = lam * inv
                    diag = {"equilibration_scale": r.scale}
                if r.request.certify:
                    # Bisection brackets every value with exact integer
                    # counts: certified by construction, no sweep needed
                    # (mirrors the sync range path).
                    diag = dict(diag or ())
                    diag.update(certified=int(r.batch * r.k),
                                lanes=int(r.batch * r.k))
                if r.single:
                    lam = lam[0]
                _resolve_future(p.future, SolveResult(
                    eigenvalues=lam, kind=r.request.kind,
                    method=r.request.method, diagnostics=diag))
        else:
            p = flush.batch[0]
            if p.expired(time.monotonic()):
                self._fail_deadline(p, flush.label)
            else:
                _resolve_future(p.future, flush.result)

    def _fallback(self, flush: _Flush) -> None:
        """Flush-level failure: isolate it -- re-run each member through
        the sync path so only genuinely poisoned requests fail."""
        self.metrics.record_fallback(flush.label)
        for p in flush.batch:
            if p.future.done():   # partial demux already resolved it
                continue
            if p.expired(time.monotonic()):
                self._fail_deadline(p, flush.label)
                continue
            try:
                result = execute_request(p.routed)
                jax.block_until_ready(result.eigenvalues)
                _resolve_future(p.future, result)
                self.metrics.record_latency(flush.label,
                                            time.monotonic() - p.submit_t)
            except Exception as exc:
                self.metrics.record_error(flush.label)
                _resolve_future(p.future, exc=exc)
        self._beat(bucket=flush.label, fallback=True,
                   requests=len(flush.batch))
