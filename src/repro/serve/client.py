"""Client facade: the eigensolver as a service, sync or async.

    from repro.serve import EigensolverClient

    with EigensolverClient(max_batch=64, max_wait_us=2000) as client:
        lam = client.solve(d, e)                        # sync, blocks
        fut = client.solve_async(d, e)                  # -> Future
        res = client.solve_batch(D, E, return_boundary=True)
        top = client.solve_range(d, e, select="i", il=n-32, iu=n-1)
        print(client.metrics()["buckets"])

Every call builds the same :class:`~repro.core.request.SolveRequest`
the sync API builds, submits it to the coalescing scheduler, and (for
the sync variants) blocks on the returned future -- concurrent callers'
requests coalesce into shared device launches and the results are
bit-for-bit what the sync API returns.  ``prewarm=...`` compiles the
expected buckets before the first request (see
:func:`repro.core.plan.prewarm`).
"""

from __future__ import annotations

from concurrent.futures import Future

from repro.core import plan as _plan
from repro.core.request import SolveRequest, SolveResult
from repro.serve.engine import ServeEngine
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import CoalescingScheduler, ServeConfig


def _robust_kw(knobs: dict) -> dict:
    """Lift the robustness knobs out of **knobs into their SolveRequest
    fields (they are request attributes, not solver knobs): ``certify``
    joins the route key so certified requests coalesce together;
    ``deadline_ms`` arms the engine's expiry checks."""
    return {"certify": bool(knobs.pop("certify", False)),
            "deadline_ms": knobs.pop("deadline_ms", None)}


class EigensolverClient:
    """Owns one scheduler + engine pair; thread-safe for any number of
    submitting threads.  Construction knobs mirror :class:`ServeConfig`;
    close() (or the context manager) drains queued work before returning.
    """

    def __init__(self, *, prewarm=None, config: ServeConfig | None = None,
                 **config_kwargs):
        if config is not None and config_kwargs:
            raise ValueError("pass either config or individual knobs")
        self.config = config or ServeConfig(**config_kwargs)
        self.metrics_sink = ServeMetrics()
        self.scheduler = CoalescingScheduler(self.config, self.metrics_sink)
        self.engine = ServeEngine(self.scheduler, self.config,
                                  self.metrics_sink)
        if prewarm is not None:
            _plan.prewarm(prewarm)
        self.engine.start()

    # ------------------------------------------------------------ submit

    def submit(self, request: SolveRequest) -> Future:
        """The async front door: returns a Future[SolveResult]."""
        return self.scheduler.submit(request)

    # ------------------------------------------------- convenience forms

    def solve_async(self, d, e, method: str = "br",
                    return_boundary: bool = False, **knobs) -> Future:
        return self.submit(SolveRequest(
            d=d, e=e, kind="full", method=method,
            return_boundary=return_boundary, **_robust_kw(knobs),
            knobs=knobs))

    def solve(self, d, e, method: str = "br", **knobs):
        """All eigenvalues of one problem -- the service's sync mirror of
        ``eigvalsh_tridiagonal``; returns the (n,) spectrum."""
        return self.solve_async(d, e, method=method, **knobs) \
            .result().eigenvalues

    def solve_batch_async(self, d, e, method: str = "br",
                          return_boundary: bool = False, **knobs) -> Future:
        return self.submit(SolveRequest(
            d=d, e=e, kind="batch", method=method,
            return_boundary=return_boundary, **_robust_kw(knobs),
            knobs=knobs))

    def solve_batch(self, d, e, method: str = "br",
                    return_boundary: bool = False, **knobs) -> SolveResult:
        """(B, n) stacked problems; returns the full SolveResult (with
        boundary rows when requested) like ``eigvalsh_tridiagonal_batch``."""
        return self.solve_batch_async(
            d, e, method=method, return_boundary=return_boundary,
            **knobs).result()

    def solve_range_async(self, d, e, *, select: str = "i", il=None,
                          iu=None, vl=None, vu=None, **knobs) -> Future:
        return self.submit(SolveRequest(
            d=d, e=e, kind="range", select=select, il=il, iu=iu, vl=vl,
            vu=vu, **_robust_kw(knobs), knobs=knobs))

    def solve_range(self, d, e, *, select: str = "i", il=None, iu=None,
                    vl=None, vu=None, **knobs):
        """Selected eigenvalues -- the service's sync mirror of
        ``eigvalsh_tridiagonal_range``."""
        return self.solve_range_async(
            d, e, select=select, il=il, iu=iu, vl=vl, vu=vu,
            **knobs).result().eigenvalues

    # --------------------------------------------------------- lifecycle

    def metrics(self) -> dict:
        """Per-bucket serving metrics + plan-cache stats (see
        :meth:`repro.serve.metrics.ServeMetrics.snapshot`)."""
        return self.metrics_sink.snapshot()

    def close(self) -> None:
        """Stop intake, drain queued flushes, join the worker."""
        self.engine.stop()

    def __enter__(self) -> "EigensolverClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
