"""Eigensolver-as-a-service: a coalescing micro-batch front end over the
plan/executor core.

Request lifecycle: ``submit -> route -> coalesce -> flush -> demux``.
Concurrent requests are routed to their bucketed compile-cache keys
(``repro.core.request``), grouped per key by the
:class:`CoalescingScheduler`, launched as shared batched solves by the
:class:`ServeEngine` (double-buffered staging, watchdog heartbeats,
straggler monitoring, transient-error retry, poisoned-request
isolation), and demuxed back onto per-request futures -- bit-for-bit the
sync API's answers, at coalesced throughput.
"""

from repro.core.request import (KINDS, METHODS, SolveRequest, SolveResult,
                                execute_request, route_request)
from repro.serve.client import EigensolverClient
from repro.serve.engine import ServeEngine
from repro.serve.metrics import ServeMetrics, bucket_label
from repro.serve.scheduler import (CoalescingScheduler, PendingRequest,
                                   QueueFull, SchedulerClosed, ServeConfig)

__all__ = [
    "CoalescingScheduler", "EigensolverClient", "KINDS", "METHODS",
    "PendingRequest", "QueueFull", "SchedulerClosed", "ServeConfig",
    "ServeEngine", "ServeMetrics", "SolveRequest", "SolveResult",
    "bucket_label", "execute_request", "route_request",
]
