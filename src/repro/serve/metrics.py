"""Per-bucket serving metrics: coalesce factor, latency percentiles,
error counts, and the plan-cache view.

Every request is attributed to the *bucket* its route key resolves to --
the same grouping the scheduler coalesces on -- so the numbers answer
the capacity-planning questions directly: how wide are flushes per
bucket (coalesce factor), what latency do requests in that bucket see
(p50/p99 submit->demux), and is steady-state traffic hitting compiled
executables (``plan_cache`` hits/traces via
:func:`repro.core.plan.plan_cache_stats`).
"""

from __future__ import annotations

import threading

from repro.core.instrument import LatencyRecorder


def bucket_label(route) -> str:
    """Human-stable label for a route key (PlanKey / RangePlanKey / None).

    Uses the fields that define the compiled executable's shape class;
    knob fields are left out so dashboards stay readable -- two knob
    variants of the same shape aggregate into one line.
    """
    if route is None:
        return "direct"
    if hasattr(route, "padded_n"):
        tail = "+rows" if route.return_boundary else ""
        return f"solve/N{route.padded_n}/{route.dtype}{tail}"
    return f"range/n{route.n}/k{route.k_bucket}/{route.dtype}"


class _Bucket:
    __slots__ = ("requests", "problems", "flushes", "flushed_problems",
                 "errors", "fallbacks", "retries", "degradations",
                 "degraded_lanes", "deadline_expired", "latency",
                 "flush_time")

    def __init__(self):
        self.requests = 0          # submitted requests
        self.problems = 0          # submitted problems (a batch counts B)
        self.flushes = 0           # device launches
        self.flushed_problems = 0  # problems launched (incl. coalesced)
        self.errors = 0            # requests whose future got an exception
        self.fallbacks = 0         # flushes that fell back to singles
        self.retries = 0           # transient-error relaunches
        self.degradations = 0      # requests escalated down the ladder
        self.degraded_lanes = 0    # eigenvalue lanes recomputed by it
        self.deadline_expired = 0  # requests failed with DeadlineExceeded
        self.latency = LatencyRecorder()     # per-request submit->demux, s
        self.flush_time = LatencyRecorder()  # per-flush device wall, s


class ServeMetrics:
    """Thread-safe per-bucket aggregation; ``snapshot()`` is the wire
    format (plain dicts, milliseconds for latencies)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._buckets: dict[str, _Bucket] = {}

    def _bucket(self, label: str) -> _Bucket:
        with self._lock:
            b = self._buckets.get(label)
            if b is None:
                b = self._buckets[label] = _Bucket()
            return b

    def record_submit(self, label: str, problems: int = 1) -> None:
        b = self._bucket(label)
        with self._lock:
            b.requests += 1
            b.problems += problems

    def record_flush(self, label: str, requests: int, problems: int,
                     duration_s: float) -> None:
        b = self._bucket(label)
        with self._lock:
            b.flushes += 1
            b.flushed_problems += problems
        b.flush_time.record(duration_s)

    def record_latency(self, label: str, seconds: float) -> None:
        self._bucket(label).latency.record(seconds)

    def record_error(self, label: str, n: int = 1) -> None:
        b = self._bucket(label)
        with self._lock:
            b.errors += n

    def record_fallback(self, label: str) -> None:
        b = self._bucket(label)
        with self._lock:
            b.fallbacks += 1

    def record_retry(self, label: str) -> None:
        b = self._bucket(label)
        with self._lock:
            b.retries += 1

    def record_degradation(self, label: str, lanes: int = 1) -> None:
        b = self._bucket(label)
        with self._lock:
            b.degradations += 1
            b.degraded_lanes += lanes

    def record_deadline(self, label: str, n: int = 1) -> None:
        b = self._bucket(label)
        with self._lock:
            b.deadline_expired += n

    def snapshot(self) -> dict:
        """Per-bucket stats + the process-wide plan-cache counters.

        ``coalesce_factor`` is launched problems per device launch --
        1.0 means the scheduler never merged anything, max_batch means
        every flush was full.
        """
        from repro.core.plan import plan_cache_stats
        out: dict = {"buckets": {}, "plan_cache": plan_cache_stats()}
        with self._lock:
            items = list(self._buckets.items())
        for label, b in items:
            with self._lock:
                flushes = b.flushes
                row = {
                    "requests": b.requests,
                    "problems": b.problems,
                    "flushes": flushes,
                    "errors": b.errors,
                    "fallbacks": b.fallbacks,
                    "retries": b.retries,
                    "degradations": b.degradations,
                    "degraded_lanes": b.degraded_lanes,
                    "deadline_expired": b.deadline_expired,
                    "coalesce_factor": (b.flushed_problems / flushes
                                        if flushes else 0.0),
                }
            row["latency_p50_ms"] = b.latency.percentile(50) * 1e3
            row["latency_p99_ms"] = b.latency.percentile(99) * 1e3
            row["flush_p50_ms"] = b.flush_time.percentile(50) * 1e3
            out["buckets"][label] = row
        return out
