"""Eigensolver-as-a-service demo: mixed workload through the coalescer.

    PYTHONPATH=src python examples/serve_demo.py [--requests 200] [--smoke]

Drives a mixed request stream -- full spectra at several sizes, a
stacked batch, top-k/bottom-k range slices -- from several submitter
threads through :class:`repro.serve.EigensolverClient`, then prints the
request lifecycle and the per-bucket metrics table (coalesce factor,
p50/p99 latency, plan-cache hits).

The lifecycle every request takes:

    submit   -> the client routes it to its bucketed compile-cache key
    route    -> equal keys are guaranteed to share one executable
    coalesce -> the scheduler groups pending requests per key until
                max_batch / max_wait_us / queue pressure fires
    flush    -> the engine launches ONE batched solve per group
                (mixed sizes host-padded, boundary rows tracked per
                problem) with watchdog + straggler + retry coverage
    demux    -> each future resolves to bit-for-bit the sync answer

``--smoke`` is the CI gate: exits non-zero unless every request
succeeded and same-bucket traffic actually coalesced (factor > 1).

``--chaos`` arms a scripted deterministic fault schedule
(``repro.runtime.faults``) before driving the same workload: a transient
launch fault (consumes one retry), NaN-poisoned device outputs (walks
the degradation ladder), and a staging delay (trips the straggler
clock).  The gate then also requires ZERO hung futures -- every future
resolves despite the storm -- and at least one recorded degradation.
"""

import argparse
import sys
import threading
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200,
                    help="total request count across all kinds")
    ap.add_argument("--threads", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-us", type=int, default=3000)
    ap.add_argument("--smoke", action="store_true",
                    help="assert zero errors and coalesce factor > 1")
    ap.add_argument("--chaos", action="store_true",
                    help="inject a scripted fault schedule; assert zero "
                         "hung futures and recorded degradations")
    args = ap.parse_args(argv)

    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.serve import EigensolverClient

    sizes = (48, 56, 64)          # one shared padded bucket: N = 64

    def make(n, rng):             # caller supplies its own Generator --
        return rng.normal(size=n), rng.normal(size=n - 1)  # not thread-safe

    print("[serve] prewarming flush buckets (cold-start-free serving)...")
    b, spec = 1, []
    while b <= args.max_batch:
        # Every (bucket, flush-width) pair traffic can produce: the mixed
        # sizes share ONE padded solve bucket (N = 64), while range plans
        # key on exact n -- three buckets, all k-widths riding k_bucket 8.
        spec.append({"kind": "solve", "n": 64, "batch": b})
        spec += [{"kind": "range", "n": n, "k": 8, "batch": b}
                 for n in sizes]
        b *= 2
    client = EigensolverClient(max_batch=args.max_batch,
                               max_wait_us=args.max_wait_us,
                               queue_depth=4 * args.max_batch,
                               prewarm=spec)

    if args.chaos:
        # Armed AFTER prewarm so the schedule's hit counts line up with
        # request traffic, not compile-time dry runs.  Count-driven and
        # seeded: the same run injects the same faults every time.
        from repro.runtime import FaultSpec, configure_faults
        configure_faults([
            FaultSpec(site="serve.launch", kind="error", times=(0,),
                      error="transient"),
            FaultSpec(site="plan.output", kind="nan", times=(1, 4),
                      lane=0, width=1),
            FaultSpec(site="serve.stage", kind="delay", times=(3,),
                      delay_s=0.05),
        ])
        print("[serve] chaos schedule armed: serve.launch error, "
              "plan.output NaN x2, serve.stage delay")

    futs, lock = [], threading.Lock()

    def worker(widx):
        local_rng = np.random.default_rng(widx)
        out = []
        for i in range(args.requests // args.threads):
            n = sizes[(widx + i) % len(sizes)]
            d, e = make(n, local_rng)
            kind = (widx + i) % 4
            if kind < 2:                      # full spectrum
                out.append(client.solve_async(d, e))
            elif kind == 2:                   # top-8 slice
                out.append(client.solve_range_async(
                    d, e, select="i", il=n - 8, iu=n - 1))
            else:                             # bottom-5 slice (same k
                out.append(client.solve_range_async(  # bucket as top-8)
                    d, e, select="i", il=0, iu=4))
            if local_rng.random() < 0.2:
                time.sleep(0.001)             # bursty, not perfectly smooth
        with lock:
            futs.extend(out)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(args.threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    import concurrent.futures as _cf
    errors = hung = 0
    for f in futs:
        try:
            f.result(timeout=600)
        except _cf.TimeoutError:              # the one unforgivable sin
            hung += 1
            print("[serve] request HUNG (future never resolved)")
        except Exception as exc:  # noqa: BLE001 - demo counts, then reports
            errors += 1
            print(f"[serve] request failed: {exc!r}")
    dt = time.perf_counter() - t0

    snap = client.metrics()
    client.close()
    if args.chaos:
        from repro.runtime import fault_stats, reset_faults
        chaos_stats = fault_stats()
        reset_faults()

    print(f"\n[serve] {len(futs)} requests in {dt:.2f}s "
          f"({len(futs) / dt:.0f} req/s), {errors} errors")
    print(f"[serve] {'bucket':<28}{'req':>6}{'flush':>7}{'coal':>7}"
          f"{'p50ms':>8}{'p99ms':>8}{'err':>5}")
    coal_num = coal_den = 0
    for label, b in sorted(snap["buckets"].items()):
        print(f"[serve] {label:<28}{b['requests']:>6}{b['flushes']:>7}"
              f"{b['coalesce_factor']:>7.1f}{b['latency_p50_ms']:>8.1f}"
              f"{b['latency_p99_ms']:>8.1f}{b['errors']:>5}")
        coal_num += b["problems"]
        coal_den += b["flushes"]
    cache = snap["plan_cache"]
    overall = coal_num / max(coal_den, 1)
    print(f"[serve] overall coalesce factor: {overall:.2f}x")
    print(f"[serve] plan cache: {cache['size']} solve + "
          f"{cache['range_size']} range plans, "
          f"{cache['hits'] + cache['range_hits']} hits, "
          f"{cache['executor_traces'] + cache['range_executor_traces']} "
          f"traces, {(cache['state_bytes'] + cache['range_state_bytes']) / 1e6:.2f} MB state budget")

    if args.chaos:
        degr = sum(b.get("degradations", 0)
                   for b in snap["buckets"].values())
        retries = sum(b.get("retries", 0)
                      for b in snap["buckets"].values())
        print(f"[serve] chaos: fired={chaos_stats['fired']}, "
              f"degradations={degr}, retries={retries}, hung={hung}")

    if args.smoke:
        ok = errors == 0 and hung == 0 and overall > 1.0
        if args.chaos:
            ok = ok and degr >= 1
            print(f"[serve] chaos smoke: {'PASS' if ok else 'FAIL'} "
                  f"(errors={errors}, hung={hung}, degradations={degr})")
        else:
            print(f"[serve] smoke: {'PASS' if ok else 'FAIL'} "
                  f"(errors={errors}, coalesce={overall:.2f})")
        if not ok:
            sys.exit(1)


if __name__ == "__main__":
    main()
