"""Batched serving: prefill + greedy decode with per-layer KV/state caches.

    PYTHONPATH=src python examples/serve_batched.py --arch mamba2-130m

Runs the same serve_prefill/serve_step functions the multi-pod dry-run
lowers for the decode_32k / long_500k cells (here on reduced configs).
"""

import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--smoke", "--batch", "4",
                "--prompt-len", "32", "--gen", "16"])


if __name__ == "__main__":
    main()
