"""Quickstart: the boundary-row eigensolver public API.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
jax.config.update("jax_enable_x64", True)

import numpy as np
import scipy.linalg as sla

from repro.core import (eigvalsh_tridiagonal, eigvalsh_tridiagonal_br,
                        eigvalsh_tridiagonal_range, make_family,
                        workspace_model, workspace_model_lazy)


def main():
    # A symmetric tridiagonal from the paper's `uniform` family.
    n = 2048
    d, e = make_family("uniform", n)

    # --- eigenvalues via boundary-row D&C (the paper's algorithm) --------
    lam = eigvalsh_tridiagonal(d, e)                    # method="br"
    ref = sla.eigh_tridiagonal(d, e, eigvals_only=True)
    err = np.max(np.abs(np.asarray(lam) - ref)) / np.max(np.abs(ref))
    print(f"BR vs LAPACK stemr: e_fwd = {err:.2e}  (n = {n})")

    # --- the other design points ------------------------------------------
    for method in ("sterf", "lazy", "full"):
        lam_m = eigvalsh_tridiagonal(d, e, method=method)
        err_m = np.max(np.abs(np.asarray(lam_m) - ref))
        print(f"  method={method:6s} max|diff vs ref| = {err_m:.2e}")

    # --- partial spectrum: k << n eigenvalues by index or value window ----
    top8 = eigvalsh_tridiagonal_range(d, e, select="i", il=n - 8, iu=n - 1)
    err_p = np.max(np.abs(np.asarray(top8) - ref[n - 8:]))
    print(f"top-8 slice (Sturm bisection): max|diff vs ref| = {err_p:.2e}")

    # --- boundary rows: the O(n) state that replaces dense eigenvectors ---
    res = eigvalsh_tridiagonal_br(d, e, return_boundary=True)
    print(f"boundary rows: |blo| = {np.linalg.norm(res.blo):.6f}, "
          f"|bhi| = {np.linalg.norm(res.bhi):.6f}  (unit rows of Q)")

    # --- the memory story (paper Table 1) ----------------------------------
    n_big = 65536
    br = workspace_model(n_big)["persistent_bytes"] / 2**20
    lazy = workspace_model_lazy(n_big)["persistent_bytes"] / 2**30
    print(f"workspace at n={n_big}: BR = {br:.1f} MiB (O(n)), "
          f"lazy-replay D&C = {lazy:.1f} GiB (O(n^2))")


if __name__ == "__main__":
    main()
