"""End-to-end training: a real (reduced) model for a few hundred steps.

    PYTHONPATH=src python examples/train_end_to_end.py            # ~22M params
    PYTHONPATH=src python examples/train_end_to_end.py --full     # mamba2-130m

Drives the same repro.launch.train stack used at scale: sharded step,
prefetching pipeline, atomic checkpoints with auto-resume, watchdog,
straggler stats.  On the CPU container the default config converges
visibly within ~200 steps.
"""

import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="train the full mamba2-130m (TPU-scale) config")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    argv = ["--arch", "mamba2-130m",
            "--steps", str(args.steps),
            "--batch", "8", "--seq", "128",
            "--lr", "1e-3",
            "--ckpt-dir", "/tmp/repro_e2e_ckpt",
            "--ckpt-every", "50", "--log-every", "10"]
    if not args.full:
        argv.append("--smoke")
    losses = train_main(argv)
    print(f"\nfinal loss {losses[-1]:.4f} (started {losses[0]:.4f}) -- "
          f"{'improved' if losses[-1] < losses[0] else 'NOT improved'}")


if __name__ == "__main__":
    main()
