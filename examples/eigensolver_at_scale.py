"""The eigensolver as a standalone service: large tridiagonals, all
methods, timing + workspace accounting (paper Tables 1-3 in miniature).

    PYTHONPATH=src python examples/eigensolver_at_scale.py [--n 8192]

Batched serving (the plan/executor front door -- one device solve for a
whole batch of problems, B * O(n) persistent state):

    PYTHONPATH=src python examples/eigensolver_at_scale.py --n 1024 --batch 64
"""

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--family", default="uniform")
    ap.add_argument("--batch", type=int, default=1,
                    help="solve a batch of B independent problems through "
                         "the plan/executor core (1 = single-problem mode)")
    args = ap.parse_args()

    # Before jax imports: forced host devices let batched solves shard
    # problem batches across CPU cores.
    if args.batch > 1:
        from repro.hostdev import force_host_devices  # jax-free
        force_host_devices()

    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    import scipy.linalg as sla

    from repro.core import (eigvalsh_tridiagonal_batch,
                            eigvalsh_tridiagonal_br, make_family,
                            make_family_batch, plan_cache_stats,
                            workspace_model, workspace_model_lazy)

    n = args.n
    if args.batch > 1:
        B = args.batch
        ds, es = make_family_batch(args.family, n, B)
        print(f"family={args.family} n={n} batch={B} "
              f"devices={len(jax.devices())}")

        t0 = time.time()
        res = eigvalsh_tridiagonal_batch(ds, es)
        res.eigenvalues.block_until_ready()
        t_cold = time.time() - t0
        t0 = time.time()
        res = eigvalsh_tridiagonal_batch(ds, es)
        res.eigenvalues.block_until_ready()
        t_warm = time.time() - t0

        # warm the single-solve executable so the loop timing is compile-free
        eigvalsh_tridiagonal_br(ds[0], es[0]).eigenvalues.block_until_ready()
        t0 = time.time()
        for b in range(B):
            out = eigvalsh_tridiagonal_br(ds[b], es[b]).eigenvalues
        out.block_until_ready()
        t_loop = time.time() - t0

        ref = sla.eigh_tridiagonal(ds[0], es[0], eigvals_only=True)
        err = np.max(np.abs(np.asarray(res.eigenvalues[0]) - ref)) / \
            max(1, np.max(np.abs(ref)))
        ws = workspace_model(n, batch=B)
        print(f"batched: cold {t_cold:.2f}s, warm {t_warm:.3f}s "
              f"({t_warm / B * 1e3:.2f} ms/problem), e_fwd {err:.2e}")
        print(f"looped singles: {t_loop:.3f}s "
              f"({t_loop / B * 1e3:.2f} ms/problem) "
              f"-> batching speedup {t_loop / t_warm:.2f}x")
        print(f"batch workspace: {ws['persistent_bytes'] / 2**20:8.2f} MiB "
              f"persistent ({ws['model']})")
        print(f"plan cache: {plan_cache_stats()}")
        return

    d, e = make_family(args.family, n)
    print(f"family={args.family} n={n}")

    t0 = time.time()
    res = eigvalsh_tridiagonal_br(d, e)
    res.eigenvalues.block_until_ready()
    t_cold = time.time() - t0
    t0 = time.time()
    res = eigvalsh_tridiagonal_br(d, e)
    res.eigenvalues.block_until_ready()
    t_warm = time.time() - t0

    t0 = time.time()
    ref = sla.eigh_tridiagonal(d, e, eigvals_only=True)
    t_scipy = time.time() - t0
    err = np.max(np.abs(np.asarray(res.eigenvalues) - ref)) / \
        max(1, np.max(np.abs(ref)))

    ws_br = workspace_model(n)
    ws_lazy = workspace_model_lazy(n)
    print(f"BR:    cold {t_cold:.2f}s, warm {t_warm:.2f}s, e_fwd {err:.2e}")
    print(f"scipy stemr reference: {t_scipy:.2f}s")
    print(f"BR workspace:   {ws_br['persistent_bytes']/2**20:8.2f} MiB  "
          f"({ws_br['model']})")
    print(f"lazy workspace: {ws_lazy['persistent_bytes']/2**20:8.2f} MiB  "
          f"({ws_lazy['model']})")
    print(f"deflation profile (active rank per level): "
          f"{[int(np.mean(k)) for k in res.kprime_per_level]}")


if __name__ == "__main__":
    main()
