"""The eigensolver as a standalone service: large tridiagonals, all
methods, timing + workspace accounting (paper Tables 1-3 in miniature).

    PYTHONPATH=src python examples/eigensolver_at_scale.py [--n 8192]
"""

import argparse
import time

import jax
jax.config.update("jax_enable_x64", True)

import numpy as np
import scipy.linalg as sla

from repro.core import (eigvalsh_tridiagonal_br, eigvalsh_tridiagonal_lazy,
                        make_family, workspace_model, workspace_model_lazy)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--family", default="uniform")
    args = ap.parse_args()
    n = args.n

    d, e = make_family(args.family, n)
    print(f"family={args.family} n={n}")

    t0 = time.time()
    res = eigvalsh_tridiagonal_br(d, e)
    res.eigenvalues.block_until_ready()
    t_cold = time.time() - t0
    t0 = time.time()
    res = eigvalsh_tridiagonal_br(d, e)
    res.eigenvalues.block_until_ready()
    t_warm = time.time() - t0

    t0 = time.time()
    ref = sla.eigh_tridiagonal(d, e, eigvals_only=True)
    t_scipy = time.time() - t0
    err = np.max(np.abs(np.asarray(res.eigenvalues) - ref)) / \
        max(1, np.max(np.abs(ref)))

    ws_br = workspace_model(n)
    ws_lazy = workspace_model_lazy(n)
    print(f"BR:    cold {t_cold:.2f}s, warm {t_warm:.2f}s, e_fwd {err:.2e}")
    print(f"scipy stemr reference: {t_scipy:.2f}s")
    print(f"BR workspace:   {ws_br['persistent_bytes']/2**20:8.2f} MiB  "
          f"({ws_br['model']})")
    print(f"lazy workspace: {ws_lazy['persistent_bytes']/2**20:8.2f} MiB  "
          f"({ws_lazy['model']})")
    print(f"deflation profile (active rank per level): "
          f"{[int(np.mean(k)) for k in res.kprime_per_level]}")


if __name__ == "__main__":
    main()
