"""Spectral curvature monitoring during training -- the paper's
eigenvalue-only workflow as a first-class training feature.

Every few steps, stochastic Lanczos quadrature reduces the training
Hessian to a small tridiagonal; the BR boundary-row solver returns
(eigenvalues, first-row weights) = exactly the Gauss quadrature rule, with
no eigenvector matrix ever materialized.  lam_max then drives the LR
governor.

    PYTHONPATH=src python examples/spectral_monitor.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data import SyntheticTokens
from repro.models import transformer as tf
from repro.optim.optimizers import adamw
from repro.optim.spectral_adapt import SpectralGovernor
from repro.spectral import make_hvp, slq_spectrum


def main():
    cfg = get_smoke_config("qwen3-0.6b")
    rng = jax.random.PRNGKey(0)
    params = tf.init_model(rng, cfg)
    opt = adamw(lr=3e-3)
    state = opt.init(params)
    src = SyntheticTokens(cfg.vocab_size, 64, seed=0)
    governor = SpectralGovernor(target_sharpness=50.0)

    @jax.jit
    def step(params, state, batch, lr_scale):
        (loss, _), grads = jax.value_and_grad(
            lambda p: tf.loss_fn(p, cfg, batch), has_aux=True)(params)
        params, state = opt.apply(params, grads, state, lr_scale=lr_scale)
        return params, state, loss

    lr_scale = 1.0
    for i in range(60):
        raw = src.batch(i, 0, 8)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        params, state, loss = step(params, state, batch, lr_scale)

        if i % 15 == 0:
            hvp = make_hvp(lambda p: tf.loss_fn(p, cfg, batch)[0], params)
            est = slq_spectrum(hvp, params, jax.random.fold_in(rng, i),
                               num_probes=2, num_steps=12)
            lr_scale = governor.update(est.lam_max)
            grid = np.linspace(est.lam_min, est.lam_max, 7)
            dens = est.density(grid)
            bars = "".join("#" if x > np.max(dens) / 4 else "."
                           for x in dens)
            print(f"step {i:3d} loss={float(loss):.3f} "
                  f"lam_max={est.lam_max:9.2f} lam_min={est.lam_min:9.2f} "
                  f"trace~{est.trace_est:10.1f} lr_scale={lr_scale:.3f} "
                  f"density[{bars}]")
        elif i % 5 == 0:
            print(f"step {i:3d} loss={float(loss):.3f}")


if __name__ == "__main__":
    main()
