"""Partial-spectrum sweep: sliced Sturm-bisection solves vs the full conquer.

The reason eigenvalue-only solvers win biggest in practice (Keyes et al.,
PAPERS.md) is that real workloads rarely need all n eigenvalues; this
suite measures the library's spectrum-slicing front end against the full
BR solve at the same accuracy contract.  Rows:

    partial_k{k}_n{n}       -- eigvalsh_tridiagonal_range, top-k slice
                               (derived carries full/partial = the slicing
                               speedup; the acceptance bar is >= 3x for
                               k=32 at n=4096 on CPU)
    full_n{n}               -- the full BR conquer at the same n
    partial_band_n{n}       -- select-by-value band around the spectrum
                               median (the condition-estimation shape)
    sturm_sweep_n{n}        -- one batched Sturm-count sweep in isolation
                               (the bisection front's per-iteration cost)

Emit machine-readable results with

    PYTHONPATH=src python -m benchmarks.run --only partial --json BENCH_partial.json
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import time_call, time_pair
from repro.core import (eigvalsh_tridiagonal_br, eigvalsh_tridiagonal_range,
                        make_family, sturm_count)


def run(report, *, quick=False):
    sizes = (1024,) if quick else (1024, 4096)
    ks = (8, 32) if quick else (8, 32, 128)
    for n in sizes:
        d, e = make_family("uniform", n)

        def full():
            return eigvalsh_tridiagonal_br(d, e).eigenvalues

        t_full = time_call(full)
        report(f"full_n{n}", t_full, "")

        for k in ks:
            def partial(k=k):
                return eigvalsh_tridiagonal_range(
                    d, e, select="i", il=n - k, iu=n - 1)

            t_partial, t_full_i = time_pair(partial, full, iters=5)
            report(f"partial_k{k}_n{n}", t_partial,
                   f"full/partial={t_full_i / t_partial:.2f}x")

        # Select-by-value band: ~32 eigenvalues around the spectrum
        # median (two host-side Sturm counts + one sliced solve -- the
        # condition-estimation shape).  Window edges derived from the
        # full solve so the row keeps its meaning for any family/size.
        lam_full = np.asarray(full())
        vl = float(lam_full[n // 2 - 16]) + 1e-12
        vu = float(lam_full[n // 2 + 16]) + 1e-12

        def band():
            return eigvalsh_tridiagonal_range(d, e, select="v",
                                              vl=vl, vu=vu)

        nb = int(np.asarray(band()).shape[0])
        t_band = time_call(band, iters=5)
        report(f"partial_band_n{n}", t_band, f"hits={nb}")

        # One Sturm sweep in isolation: the per-iteration cost the whole
        # bisection front is built from (64 probe shifts).
        shifts = jnp.linspace(float(lam_full[0]) - 1.0,
                              float(lam_full[-1]) + 1.0, 64)

        def sweep():
            return sturm_count(d, e, shifts)

        t_sweep = time_call(sweep, iters=5)
        report(f"sturm_sweep_n{n}", t_sweep, "shifts=64")
