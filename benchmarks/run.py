"""Benchmark driver: one module per paper table + roofline aggregation.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME] [--json [PATH]]

Prints ``name,us_per_call,derived`` CSV rows.  With ``--json`` the rows
are also written as structured JSON (default path BENCH_conquer.json in
the repo root) so perf PRs leave a machine-readable trajectory.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes (CI-friendly)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", nargs="?", const="BENCH_conquer.json",
                    default=None, metavar="PATH",
                    help="also write results as JSON (default "
                         "BENCH_conquer.json)")
    ap.add_argument("--prewarm", action="store_true",
                    help="compile the common benchmark plan buckets before "
                         "timing (plan.prewarm) so suite rows measure "
                         "steady-state executables, not first-call traces")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="force this many XLA host CPU devices (default: "
                         "cpu count) so batched solves shard across cores; "
                         "0 leaves XLA_FLAGS untouched")
    ap.add_argument("--mesh", type=int, default=None,
                    help="solver mesh width for the dist suite: force at "
                         "least this many host devices (power of two) and "
                         "shard huge solves over them; errors out if jax "
                         "was initialized first instead of silently "
                         "falling back to one device")
    args = ap.parse_args(argv)

    if args.mesh is not None:
        if args.mesh < 1 or (args.mesh & (args.mesh - 1)):
            ap.error(f"--mesh must be a positive power of two, "
                     f"got {args.mesh}")
        if args.host_devices is not None and args.host_devices < args.mesh:
            ap.error(f"--host-devices {args.host_devices} is smaller than "
                     f"--mesh {args.mesh}")
        if "jax" in sys.modules:
            raise RuntimeError(
                "--mesh must take effect before first jax init, but jax "
                "is already imported in this process; run the benchmark "
                "driver as the entry point (python -m benchmarks.run)")

    # Must happen before the first jax import: forced host devices let the
    # batched plan executor shard problem batches across CPU cores (the
    # looped baselines are one problem wide and cannot use them).  Only
    # `--only batched` runs get this by default -- partitioning the host
    # would silently change the measured environment of every other
    # suite and break comparability with committed snapshots (full-suite
    # runs therefore record the batched rows UNSHARDED; pass
    # --host-devices explicitly to override either way).
    from repro.hostdev import force_host_devices  # jax-free
    if args.host_devices is not None:
        force_host_devices(args.host_devices)
    elif args.mesh is not None:
        force_host_devices(args.mesh)
    elif args.only in ("batched", "serve"):
        # serve: coalesced flushes shard across host devices exactly like
        # the batched suite; the one-by-one baseline is one problem wide
        # and cannot, which is the point of the comparison.
        force_host_devices()
    elif args.only == "dist":
        # Strong scaling needs >= 4 shards even on small hosts.
        force_host_devices(max(4, os.cpu_count() or 1))

    import jax
    jax.config.update("jax_enable_x64", True)

    if args.mesh is not None and jax.device_count() < args.mesh:
        raise RuntimeError(
            f"--mesh {args.mesh} requested but only {jax.device_count()} "
            f"devices came up; XLA_FLAGS already configured "
            f"a smaller host-device count before this run "
            f"(XLA_FLAGS={os.environ.get('XLA_FLAGS', '')!r})")

    from benchmarks import (bench_accuracy, bench_batched, bench_dist,
                            bench_fused, bench_kernels, bench_merge,
                            bench_mixed, bench_partial, bench_robust,
                            bench_scaling, bench_serve, bench_vs_lazy,
                            bench_vs_sterf, bench_workspace, roofline)

    if args.prewarm:
        from repro.core.plan import prewarm
        sizes = (256, 512) if args.quick else (256, 512, 1024, 2048)
        spec = [{"kind": "solve", "n": nn, "batch": 1} for nn in sizes]
        spec.append({"kind": "range", "n": 1024 if args.quick else 4096,
                     "k": 32, "batch": 1})
        info = prewarm(spec)
        print(f"# prewarm: {info['plans']} plans, {info['traces']} traces, "
              f"{info['seconds']:.1f}s", flush=True)

    rows = []
    records = []
    current_suite = [""]

    def report(name, seconds, derived=""):
        line = f"{name},{seconds * 1e6:.1f},{derived}"
        rows.append(line)
        records.append({"suite": current_suite[0], "name": name,
                        "us_per_call": round(seconds * 1e6, 1),
                        "derived": derived})
        print(line, flush=True)

    suites = {
        "workspace": lambda: bench_workspace.run(report),
        "vs_sterf": lambda: bench_vs_sterf.run(
            report, sizes=(512, 1024) if args.quick else (1024, 2048),
            sterf_max=1024 if args.quick else 2048),
        "vs_lazy": lambda: bench_vs_lazy.run(
            report, sizes=(512, 1024) if args.quick else (1024, 2048, 4096)),
        "batched": lambda: bench_batched.run(report, quick=args.quick),
        "scaling": lambda: bench_scaling.run(
            report, sizes=(256, 512, 1024) if args.quick
            else (512, 1024, 2048, 4096)),
        "accuracy": lambda: bench_accuracy.run(
            report, n=1024 if args.quick else 4096),
        "kernels": lambda: bench_kernels.run(
            report, K=512 if args.quick else 2048),
        "fused": lambda: bench_fused.run(
            report, sizes=(512, 1024) if args.quick else (1024, 2048, 4096)),
        "merge": lambda: bench_merge.run(report, quick=args.quick),
        "partial": lambda: bench_partial.run(report, quick=args.quick),
        "mixed": lambda: bench_mixed.run(report, quick=args.quick),
        "serve": lambda: bench_serve.run(report, quick=args.quick),
        "robust": lambda: bench_robust.run(report, quick=args.quick),
        "dist": lambda: bench_dist.run(report, quick=args.quick,
                                       max_shards=args.mesh),
        "roofline": lambda: roofline.run(report),
    }

    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        current_suite[0] = name
        try:
            fn()
        except Exception as e:  # keep the harness running
            report(f"{name}_ERROR", 0.0, repr(e))
        print(f"# {name} took {time.time() - t0:.1f}s", flush=True)

    print(f"# total rows: {len(rows)}")

    if args.json:
        payload = {
            "meta": {
                "backend": jax.default_backend(),
                "device": str(jax.devices()[0]),
                "num_devices": len(jax.devices()),
                "cpu_count": os.cpu_count(),
                "platform": platform.platform(),
                "jax": jax.__version__,
                "quick": bool(args.quick),
                "only": args.only,
                "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
            },
            "rows": records,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {os.path.abspath(args.json)}")


if __name__ == "__main__":
    main()
