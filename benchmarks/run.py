"""Benchmark driver: one module per paper table + roofline aggregation.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes (CI-friendly)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    import jax
    jax.config.update("jax_enable_x64", True)

    from benchmarks import (bench_accuracy, bench_batched, bench_kernels,
                            bench_scaling, bench_vs_lazy, bench_vs_sterf,
                            bench_workspace, roofline)

    rows = []

    def report(name, seconds, derived=""):
        line = f"{name},{seconds * 1e6:.1f},{derived}"
        rows.append(line)
        print(line, flush=True)

    suites = {
        "workspace": lambda: bench_workspace.run(report),
        "vs_sterf": lambda: bench_vs_sterf.run(
            report, sizes=(512, 1024) if args.quick else (1024, 2048),
            sterf_max=1024 if args.quick else 2048),
        "vs_lazy": lambda: bench_vs_lazy.run(
            report, sizes=(512, 1024) if args.quick else (1024, 2048, 4096)),
        "batched": lambda: bench_batched.run(
            report, n=1024 if args.quick else 2048),
        "scaling": lambda: bench_scaling.run(
            report, sizes=(256, 512, 1024) if args.quick
            else (512, 1024, 2048, 4096)),
        "accuracy": lambda: bench_accuracy.run(
            report, n=1024 if args.quick else 4096),
        "kernels": lambda: bench_kernels.run(
            report, K=512 if args.quick else 2048),
        "roofline": lambda: roofline.run(report),
    }

    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception as e:  # keep the harness running
            report(f"{name}_ERROR", 0.0, repr(e))
        print(f"# {name} took {time.time() - t0:.1f}s", flush=True)

    print(f"# total rows: {len(rows)}")


if __name__ == "__main__":
    main()
