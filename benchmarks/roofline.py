"""Roofline aggregation: reports/dryrun/*.json -> EXPERIMENTS.md tables.

Per (arch x shape x mesh) cell:
  compute / memory / collective terms in seconds (per-chip quantities over
  per-chip rates -- equivalent to total/(chips*rate)), the dominant term,
  MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per step, and the
  useful-compute ratio MODEL_FLOPS / (HLO_FLOPs * chips).

Usage:
    PYTHONPATH=src python -m benchmarks.roofline [--dir reports/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def model_flops(rep: dict) -> float:
    """6*N*D per step (training); for inference cells, 2*N*D per generated
    token (decode) or 2*N*D*tokens (prefill)."""
    n_active = rep.get("active_params", rep.get("params", 0))
    tokens = rep["seq_len"] * rep["global_batch"]
    if rep["kind"] == "train":
        return 6.0 * n_active * tokens
    if rep["kind"] == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * rep["global_batch"]     # decode: 1 new token/seq


def chips_of(rep: dict) -> int:
    return 512 if rep.get("multi_pod") else 256


def load_reports(directory: str):
    reports = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            reports.append(json.load(f))
    return reports


def row(rep: dict) -> dict:
    # Prefer layer-calibrated terms (XLA cost_analysis counts while-loop
    # bodies once; the dry-run extrapolates metric(L) = base + L*delta).
    r = rep.get("roofline_calibrated", rep["roofline"])
    cal = rep.get("calibrated")
    flops_chip = (cal or rep)["flops_per_chip"]
    chips = chips_of(rep)
    mf = model_flops(rep)
    hlo_total = flops_chip * chips
    return {
        "arch": rep["arch"], "shape": rep["shape"],
        "mesh": "2x16x16" if rep.get("multi_pod") else "16x16",
        "compute_s": r["compute_s"], "memory_s": r["memory_s"],
        "collective_s": r["collective_s"], "dominant": r["dominant"],
        "roofline_fraction": r["roofline_fraction"],
        "model_flops": mf,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "peak_gib": rep["memory"].get("peak_bytes", 0) / 2**30,
        "compile_s": rep.get("compile_s", 0.0),
        "calibrated": cal is not None,
    }


def markdown_table(rows, multi_pod: bool = False) -> str:
    hdr = ("| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
           "| dominant | roofline frac | 6ND/HLO | peak GiB/chip |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if (r["mesh"] == "2x16x16") != multi_pod:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['roofline_fraction']:.2f} | {r['useful_ratio']:.2f} "
            f"| {r['peak_gib']:.2f} |")
    return hdr + "\n".join(lines)


def run(report, directory: str = None):
    directory = directory or os.path.join(
        os.path.dirname(__file__), "..", "reports", "dryrun")
    reports = load_reports(directory)
    if not reports:
        report("roofline_cells", 0.0, "no dry-run reports found")
        return
    rows = [row(r) for r in reports]
    n_dom = {}
    for r in rows:
        n_dom[r["dominant"]] = n_dom.get(r["dominant"], 0) + 1
    fracs = [r["roofline_fraction"] for r in rows]
    report("roofline_cells", 0.0,
           f"cells={len(rows)} dominant={n_dom} "
           f"frac_min={min(fracs):.2f} frac_max={max(fracs):.2f}")
    for r in rows:
        report(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}", 0.0,
               f"dom={r['dominant']} frac={r['roofline_fraction']:.2f} "
               f"useful={r['useful_ratio']:.2f} peak={r['peak_gib']:.1f}GiB")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=None)
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    directory = args.dir or os.path.join(
        os.path.dirname(__file__), "..", "reports", "dryrun")
    rows = [row(r) for r in load_reports(directory)]
    if args.markdown:
        print("### Single-pod (16x16)\n")
        print(markdown_table(rows, multi_pod=False))
        print("\n### Multi-pod (2x16x16)\n")
        print(markdown_table(rows, multi_pod=True))
    else:
        run(lambda n, s, d: print(f"{n},{s*1e6:.1f},{d}"), directory)


if __name__ == "__main__":
    main()
