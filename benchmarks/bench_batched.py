"""Table 4 analogue: values-only BR vs conventional D&C compute-and-discard.

cuSOLVER Xstedc(compz='N') computes through the full-eigenvector D&C and
returns values only -- our `full_discard` baseline reproduces that design
point (quadratic workspace, full GEMM merges).  Both paths start from d/e
and share deflation/secular machinery, so the ratio isolates the
boundary-row state reduction, exactly like the H100 table.
"""

from __future__ import annotations

from benchmarks.common import time_call
from repro.core import (eigvalsh_tridiagonal_br,
                        eigvalsh_tridiagonal_full_discard, make_family)


def run(report, n=2048):
    for family in ("uniform", "normal", "toeplitz", "clustered"):
        d, e = make_family(family, n)
        t_br = time_call(lambda: eigvalsh_tridiagonal_br(d, e).eigenvalues)
        t_full = time_call(
            lambda: eigvalsh_tridiagonal_full_discard(d, e), iters=1)
        report(f"t4_br_{family}_n{n}", t_br, "")
        report(f"t4_fulldiscard_{family}_n{n}", t_full,
               f"full/br={t_full/t_br:.2f}x")
