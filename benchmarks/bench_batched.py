"""Batched-throughput sweep: the plan/executor front door vs looped solves.

The paper's O(n) boundary-row state is what makes many-problem workloads
viable (B * O(n) persistent state, not B * O(n^2)); this suite measures
the execution-side half of that claim: one batched device solve through
the bucketed compile cache vs a Python loop of single solves at the same
total work.  Rows:

    batched_B{B}_n{n}    -- one eigvalsh_tridiagonal_batch launch
    looped_B{B}_n{n}     -- B sequential eigvalsh_tridiagonal_br solves
                            (derived column carries looped/batched = the
                            batching speedup)

Emit machine-readable results with

    PYTHONPATH=src python -m benchmarks.run --only batched --json BENCH_batched.json
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import time_pair
from repro.core import (eigvalsh_tridiagonal_batch, eigvalsh_tridiagonal_br,
                        make_family, make_family_batch)


def run(report, *, quick=False, leaf=32):
    sizes = (256,) if quick else (256, 1024)
    batches = (1, 8, 64) if quick else (1, 8, 64, 256)
    for n in sizes:
        for B in batches:
            ds, es = make_family_batch("uniform", n, B)

            def batched():
                return eigvalsh_tridiagonal_batch(
                    ds, es, leaf=leaf).eigenvalues

            def looped():
                out = None
                for b in range(B):
                    out = eigvalsh_tridiagonal_br(
                        ds[b], es[b], leaf=leaf).eigenvalues
                return out

            t_batched, t_looped = time_pair(batched, looped, iters=3)
            report(f"batched_B{B}_n{n}", t_batched,
                   f"per_problem_us={t_batched / B * 1e6:.1f}")
            report(f"looped_B{B}_n{n}", t_looped,
                   f"looped/batched={t_looped / t_batched:.2f}x")

    if not quick:
        _run_table4(report)


def _run_table4(report, n=2048):
    """Table 4 analogue: values-only BR vs conventional D&C
    compute-and-discard (cuSOLVER Xstedc compz='N' stand-in) -- kept from
    the pre-batching suite so the paper's BR-vs-full-discard ratio stays
    on the benchmark trajectory."""
    from benchmarks.common import time_call
    from repro.core import eigvalsh_tridiagonal_full_discard

    for family in ("uniform", "normal", "toeplitz", "clustered"):
        d, e = make_family(family, n)
        t_br = time_call(lambda: eigvalsh_tridiagonal_br(d, e).eigenvalues)
        t_full = time_call(
            lambda: eigvalsh_tridiagonal_full_discard(d, e), iters=1)
        report(f"t4_br_{family}_n{n}", t_br, "")
        report(f"t4_fulldiscard_{family}_n{n}", t_full,
               f"full/br={t_full/t_br:.2f}x")
