"""Numerical accuracy (paper section 5.8): normalized forward/backward
errors against the LAPACK (scipy stemr) reference.

    e_fwd = ||lam - lam_ref||_inf / max(1, ||lam_ref||_inf)
    e_bwd = ||lam - lam_ref||_inf / max(1, ||T||_inf)
"""

from __future__ import annotations

import numpy as np
import scipy.linalg as sla

from repro.core import eigvalsh_tridiagonal_br, make_family


def run(report, n=4096):
    for family in ("uniform", "normal", "toeplitz", "clustered",
                   "wilkinson"):
        d, e = make_family(family, n)
        ref = sla.eigh_tridiagonal(d, e, eigvals_only=True)
        lam = np.asarray(eigvalsh_tridiagonal_br(d, e).eigenvalues)
        t_norm = np.max(np.abs(d)) + 2 * np.max(np.abs(e))
        e_fwd = np.max(np.abs(lam - ref)) / max(1.0, np.max(np.abs(ref)))
        e_bwd = np.max(np.abs(lam - ref)) / max(1.0, t_norm)
        report(f"acc_{family}_n{n}", 0.0,
               f"e_fwd={e_fwd:.3e} e_bwd={e_bwd:.3e}")
