"""Table 3 analogue: BR vs internal values-only (lazy-replay) D&C.

Output-equivalent paths sharing the same merge core; the ratio isolates
exactly the replay term (c_rep K^2 reconstruction GEMVs) plus the dense
local-transform materialization that BR removes.  Workspace columns are
the analytic models validated in tests.
"""

from __future__ import annotations

from benchmarks.common import time_call
from repro.core import (eigvalsh_tridiagonal_br, eigvalsh_tridiagonal_lazy,
                        make_family, workspace_model, workspace_model_lazy)


def run(report, sizes=(1024, 2048, 4096)):
    for family in ("uniform", "normal"):
        for n in sizes:
            d, e = make_family(family, n)
            t_br = time_call(
                lambda: eigvalsh_tridiagonal_br(d, e).eigenvalues)
            t_lazy = time_call(lambda: eigvalsh_tridiagonal_lazy(d, e),
                               iters=1)
            ws_br = workspace_model(n)["persistent_bytes"] / 2**20
            ws_lz = workspace_model_lazy(n)["persistent_bytes"] / 2**20
            report(f"t3_br_{family}_n{n}", t_br, f"ws={ws_br:.2f}MiB")
            report(f"t3_lazy_{family}_n{n}", t_lazy,
                   f"ws={ws_lz:.1f}MiB int/br={t_lazy/t_br:.2f}x")
