"""Serving throughput: coalesced submission vs one-by-one.

The question this answers: given a stream of independent single-problem
requests, how much does the coalescing front end buy over submitting
them one at a time?

  serve_sync_loop_n{n}     -- sequential sync-path loop (execute_request
                              per problem; the strongest baseline: no
                              service overhead at all)
  serve_one_by_one_n{n}    -- closed-loop concurrency 1 through the
                              service: each request waits for its result
                              before the next is submitted, so nothing
                              ever coalesces (the literal one-by-one
                              submission mode)
  serve_coalesced_sat_n{n} -- saturating arrival: T threads submit R
                              requests as fast as they can; same-bucket
                              traffic merges into shared sharded launches
  serve_coalesced_low_n{n} -- low arrival rate (inter-arrival >> service
                              time): nothing to coalesce with, so this
                              row prices the max_wait latency the service
                              adds when traffic is sparse

``us_per_call`` is wall time per request (interleaved best-of rounds --
the 2-core CI boxes are noisy); derived carries request rate, coalesce
factor, p50/p99 latency, and the coalesced speedup against BOTH
baselines (acceptance bar: >= 2x one-by-one at saturation for
same-bucket traffic).  All power-of-two flush buckets are prewarmed
first so every row measures steady-state serving, never compiles.
"""

from __future__ import annotations

import threading
import time

import numpy as np


def _problems(n, count, seed=0):
    rng = np.random.default_rng(seed)
    return [(rng.normal(size=n), rng.normal(size=n - 1))
            for _ in range(count)]


def _drive(client, problems, threads, interarrival_s=0.0):
    """Submit every problem (round-robin across threads), wait for all;
    returns wall seconds."""
    futs = [None] * len(problems)

    def worker(idx):
        for i in range(idx, len(problems), threads):
            if interarrival_s:
                time.sleep(interarrival_s)
            d, e = problems[i]
            futs[i] = client.solve_async(d, e)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(threads)]
    t0 = time.perf_counter()
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    for f in futs:
        f.result(timeout=600)
    return time.perf_counter() - t0


def run(report, quick=False):
    from repro.core.plan import clear_plan_cache, prewarm
    from repro.core.request import SolveRequest, execute_request
    from repro.serve import EigensolverClient

    n = 128
    max_batch = 16 if quick else 32
    R = 64 if quick else 160
    R_seq = 24 if quick else 48
    rounds = 2 if quick else 3
    threads = 4

    # Steady state only: compile every power-of-two flush bucket up front.
    spec, b = [], 1
    while b <= max_batch:
        spec.append({"kind": "solve", "n": n, "batch": b})
        b *= 2
    info = prewarm(spec)
    report(f"serve_prewarm_n{n}", info["seconds"],
           f"plans={info['plans']} traces={info['traces']}")

    problems = _problems(n, R)
    reqs = [SolveRequest(d=d, e=e) for d, e in problems]

    client_seq = EigensolverClient(max_batch=max_batch, max_wait_us=2000,
                                   queue_depth=4 * max_batch)
    client_sat = EigensolverClient(max_batch=max_batch, max_wait_us=2000,
                                   queue_depth=4 * max_batch)
    try:
        # Warm every code path once outside the timed rounds.
        np.asarray(execute_request(reqs[0]).eigenvalues)
        client_seq.solve(*problems[0])
        _drive(client_sat, problems[:8], threads)

        t_sync = t_one = t_sat = float("inf")
        for _ in range(rounds):   # interleaved best-of: noise-robust
            t0 = time.perf_counter()
            for rq in reqs[:R_seq]:
                np.asarray(execute_request(rq).eigenvalues)
            t_sync = min(t_sync, (time.perf_counter() - t0) / R_seq)

            t0 = time.perf_counter()
            for d, e in problems[:R_seq]:
                client_seq.solve(d, e)
            t_one = min(t_one, (time.perf_counter() - t0) / R_seq)

            t_sat = min(t_sat, _drive(client_sat, problems, threads) / R)
        snap = client_sat.metrics()["buckets"][f"solve/N{n}/float64"]
    finally:
        client_seq.close()
        client_sat.close()

    report(f"serve_sync_loop_n{n}", t_sync, f"rate={1 / t_sync:.0f}req/s")
    report(f"serve_one_by_one_n{n}", t_one, f"rate={1 / t_one:.0f}req/s")
    report(f"serve_coalesced_sat_n{n}", t_sat,
           f"rate={1 / t_sat:.0f}req/s coalesce={snap['coalesce_factor']:.1f}x"
           f" p50={snap['latency_p50_ms']:.1f}ms"
           f" p99={snap['latency_p99_ms']:.1f}ms"
           f" speedup_vs_one_by_one={t_one / t_sat:.2f}x"
           f" speedup_vs_sync_loop={t_sync / t_sat:.2f}x")

    # Low arrival rate: prices the added wait, not throughput.
    R_low = 12 if quick else 24
    interarrival = 3.0 * t_sync
    with EigensolverClient(max_batch=max_batch, max_wait_us=2000,
                           queue_depth=4 * max_batch) as client:
        _drive(client, problems[:4], 1)
        t_low = _drive(client, problems[:R_low], 1,
                       interarrival_s=interarrival)
        snap = client.metrics()["buckets"][f"solve/N{n}/float64"]
    report(f"serve_coalesced_low_n{n}", t_low / R_low,
           f"rate={R_low / t_low:.0f}req/s"
           f" coalesce={snap['coalesce_factor']:.1f}x"
           f" p99={snap['latency_p99_ms']:.1f}ms")

    clear_plan_cache()
