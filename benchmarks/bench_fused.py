"""Fused single-pass conquer vs the legacy three-pass pipeline.

Measures the tentpole claims end-to-end and in isolation:

  * full solver wall time, fused (single delta pass, ratio-product zhat,
    size-adaptive dense dispatch) vs legacy (chunked lax.map secular solve
    + separate log-space zhat and boundary-row passes) at n in
    {1024, 2048, 4096};
  * the post-pass alone at the top-merge size (the bandwidth-bound kernel
    the paper identifies);
  * return_boundary on a padded size: one tracked-row solve vs the old
    flip-identity double solve (simulated by solving the reversed problem
    again, exactly what the old code did).

A/B pairs are measured interleaved (common.time_pair) so load drift on
shared hosts cannot masquerade as a speedup.  Rows feed
BENCH_conquer.json via ``python -m benchmarks.run --json``.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import time_pair
from repro.core import eigvalsh_tridiagonal_br, make_family
from repro.core import secular as sec


def run(report, sizes=(1024, 2048, 4096)):
    import jax

    for n in sizes:
        d, e = make_family("normal", n)

        # Full BR conquer (boundary rows propagated through every merge,
        # including the root -- eigenvalue-only root_mode skips the
        # post-pass at the top merge entirely, in both pipelines).
        t_legacy, t_fused = time_pair(
            lambda: eigvalsh_tridiagonal_br(
                d, e, return_boundary=True, fused=False).bhi,
            lambda: eigvalsh_tridiagonal_br(
                d, e, return_boundary=True, fused=True).bhi)
        report(f"conquer_legacy3pass_n{n}", t_legacy, "baseline")
        report(f"conquer_fused_n{n}", t_fused,
               f"speedup={t_legacy / t_fused:.2f}x")

        # Post-pass in isolation at the top-merge size K = n (jitted --
        # the solver runs it inside one jit; unjitted lax.scan/map retrace
        # per call and would measure tracing, not the kernel).
        rng = np.random.default_rng(0)
        K = n
        dd = jnp.asarray(np.sort(rng.standard_normal(K)))
        z = rng.standard_normal(K)
        z /= np.linalg.norm(z)
        z = jnp.asarray(z)
        rho = 0.7
        origin, tau = sec.secular_solve(dd, z * z, rho, K, niter=16)
        R = jnp.asarray(rng.standard_normal((2, K)))

        @jax.jit
        def two_pass():
            zh = sec.zhat_reconstruct(dd, z, origin, tau, K, rho)
            return sec.boundary_rows_update(R, dd, zh, origin, tau, K)

        @jax.jit
        def one_pass():
            return sec.secular_postpass(R, dd, z, origin, tau, K, rho)[1]

        t2, t1 = time_pair(two_pass, one_pass)
        report(f"postpass_twopass_K{K}", t2, "zhat + rows (2 delta sweeps)")
        report(f"postpass_fused_K{K}", t1,
               f"1 delta sweep, speedup={t2 / t1:.2f}x")

    # --- padded return_boundary: tracked row vs flip double-solve ---------
    n_pad = 3000                         # pads to N = 4096
    d, e = make_family("normal", n_pad)

    def single_solve():
        return eigvalsh_tridiagonal_br(d, e, return_boundary=True).bhi

    def double_solve():                  # what the pre-fusion code did
        r1 = eigvalsh_tridiagonal_br(d, e, return_boundary=True)
        r2 = eigvalsh_tridiagonal_br(d[::-1], e[::-1], return_boundary=True)
        return r1.blo, r2.blo

    t_double, t_single = time_pair(double_solve, single_solve, iters=3)
    report(f"boundary_padded_double_n{n_pad}", t_double, "flip identity (old)")
    report(f"boundary_padded_single_n{n_pad}", t_single,
           f"tracked row, speedup={t_double / t_single:.2f}x")
