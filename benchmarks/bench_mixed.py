"""Mixed-precision pipeline: f64 tree vs f32 tree vs f32+certified-refine.

Three rows per size over the full-spectrum resident/fused configuration:

  * ``mixed_f64_n{..}``   -- the default double-precision tree (baseline);
  * ``mixed_f32_n{..}``   -- the raw f32 tree (dtype=float32, native):
    the speed ceiling, but only ~1e-6 absolute accuracy;
  * ``mixed_mixed_n{..}`` -- precision="mixed": the f32 tree plus the f64
    Sturm certification / cluster polish.  Derived stats carry the
    speedup over f64, the max |mixed - f64| error in eps_f64 * ||T||
    units (the acceptance bar is <= 64), and the refinement gauge's
    polished-lane fraction + polish iterations
    (``SOLVE_COUNTER.measure(refinement=True)``) -- the pipeline's
    effective-work lever, exactly like the deflation ratio is the merge
    tree's.

Rows feed BENCH_mixed.json via
``python -m benchmarks.run --only mixed --json BENCH_mixed.json``.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import time_call
from repro.core import make_family
from repro.core.br_dc import SOLVE_COUNTER, eigvalsh_tridiagonal_br

EPS = np.finfo(np.float64).eps


def run(report, quick: bool = False, sizes=None):
    if sizes is None:
        sizes = (256, 1024) if quick else (1024, 4096, 16384)

    for n in sizes:
        d, e = make_family("normal", n)
        d32 = np.asarray(d, np.float32)
        e32 = np.asarray(e, np.float32)
        scale = max(1.0, np.abs(d).max() + 2.0 * np.abs(e).max())
        # One timed sample at the biggest size (a single f64 solve there
        # is tens of seconds on CPU); best-of-3 below it.
        iters = 1 if n >= 16384 else 3

        lam64 = np.asarray(eigvalsh_tridiagonal_br(d, e).eigenvalues)
        t64 = time_call(
            lambda: eigvalsh_tridiagonal_br(d, e).eigenvalues,
            warmup=0, iters=iters)
        report(f"mixed_f64_n{n}", t64, "baseline f64 tree")

        lam32 = np.asarray(
            eigvalsh_tridiagonal_br(d32, e32).eigenvalues, np.float64)
        t32 = time_call(
            lambda: eigvalsh_tridiagonal_br(d32, e32).eigenvalues,
            warmup=0, iters=iters)
        err32 = np.abs(lam32 - lam64).max() / (EPS * scale)
        report(f"mixed_f32_n{n}", t32,
               f"raw f32 tree, speedup={t64 / t32:.2f}x, "
               f"err={err32:.3g}eps")

        with SOLVE_COUNTER.measure(refinement=True) as window:
            lam_mx = np.asarray(
                eigvalsh_tridiagonal_br(d, e, precision="mixed").eigenvalues)
        stats = window.refinement_stats
        t_mx = time_call(
            lambda: eigvalsh_tridiagonal_br(
                d, e, precision="mixed").eigenvalues,
            warmup=0, iters=iters)
        err_mx = np.abs(lam_mx - lam64).max() / (EPS * scale)
        report(f"mixed_mixed_n{n}", t_mx,
               f"speedup={t64 / t_mx:.2f}x, err={err_mx:.3g}eps, "
               f"polish_fraction={stats['polish_fraction']:.4f}, "
               f"polish_iters={stats['iterations']}, "
               f"rounds={stats['max_rounds']}")
