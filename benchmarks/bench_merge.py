"""Merge-level benchmarks: parallel deflation head + resident merge.

Measures the two tentpole claims of the deflation/residency PR, isolated
and end-to-end, on low-deflation vs high-deflation inputs:

  * ``deflate_head_*`` -- the close-pole deflation head ALONE (jitted
    level-scope dispatch vs the vmapped sequential DLAED2 chain) on
    synthetic sorted-pole levels with a controlled close-pair fraction:
    0 (low deflation: the steady state, where the head is pure detection)
    and 0.25 (high deflation: planted duplicate poles, the glued-family
    regime, where the escalation tiers carry the chain).
  * ``solver_deflate_*`` -- full BR solver, parallel head (default
    budget) vs sequential chain (``deflate_budget=0``), on the
    glued-Wilkinson (deflation-heavy) and normal (low-deflation)
    families; ``derived`` carries the speedup and the per-level
    deflation ratio observed by the ``SolveCounter`` gauge.
  * ``resident_*`` -- the single-launch resident merge
    (``secular_merge_resident_batched``) vs the two-launch dense
    solve + post-pass pipeline at sub-threshold K (the dispatch the
    Pallas kernel collapses on TPU; on CPU the win is the avoided
    intermediate materialization, which grows with K).

A/B pairs are measured interleaved (common.time_pair) so load drift on
shared hosts cannot masquerade as a speedup.  Rows feed BENCH_merge.json
via ``python -m benchmarks.run --only merge --json BENCH_merge.json``.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import time_pair
from repro.core import br_dc
from repro.core import eigvalsh_tridiagonal_br, make_family
from repro.core import merge as _merge
from repro.core import secular as sec


def _head_problem(W, K, close_frac, seed=0):
    """One synthetic merge level: (W, K) sorted poles with a planted
    fraction of exactly-close pairs (duplicate pole values)."""
    rng = np.random.default_rng(seed)
    d = np.sort(rng.standard_normal((W, K)), axis=1)
    ncl = int(close_frac * K)
    if ncl:
        for w in range(W):
            ix = rng.choice(K - 1, ncl, replace=False)
            d[w, ix + 1] = d[w, ix] + 1e-16
        d = np.sort(d, axis=1)
    z = rng.standard_normal((W, K))
    z /= np.linalg.norm(z, axis=1, keepdims=True)
    tol = 8 * np.finfo(np.float64).eps * np.max(np.abs(d), axis=1)
    small = 1.0 * np.abs(z) <= tol[:, None]
    R = rng.standard_normal((W, 2, K))
    return (jnp.asarray(d), jnp.asarray(z), jnp.asarray(R),
            jnp.asarray(small), jnp.asarray(tol))


def run(report, quick=False):
    # ---- isolated deflation head: sequential chain vs parallel head ----
    @jax.jit
    def head_seq(d, z, R, small, tol):
        return jax.vmap(_merge._close_pole_scan)(d, z, R, small, tol)

    @functools.partial(jax.jit, static_argnames=("budget",))
    def head_par(d, z, R, small, tol, budget=_merge.DEFAULT_DEFLATE_BUDGET):
        return _merge._deflate_level(d, z, R, small, tol, budget=budget)

    shapes = ((8, 512), (2, 1024)) if quick else ((8, 512), (2, 1024),
                                                  (1, 2048))
    for W, K in shapes:
        for frac, label in ((0.0, "lowdefl"), (0.25, "highdefl")):
            args = _head_problem(W, K, frac)
            nrot = int(np.asarray(head_seq(*args)[3]).sum()
                       - np.asarray(args[3]).sum())
            t_seq, t_par = time_pair(lambda: head_seq(*args)[0],
                                     lambda: head_par(*args)[0], iters=11)
            report(f"deflate_head_seq_{label}_W{W}_K{K}", t_seq,
                   f"sequential chain, {nrot} rotations")
            report(f"deflate_head_par_{label}_W{W}_K{K}", t_par,
                   f"detect+tiered chain, speedup={t_seq / t_par:.2f}x")

    # ---- full solver: parallel head vs sequential chain ----------------
    sizes = (512, 1024) if quick else (1024, 2048)
    for fam in ("glued_wilkinson", "normal"):
        for n in sizes:
            d, e = make_family(fam, n)
            t_seq, t_par = time_pair(
                lambda: eigvalsh_tridiagonal_br(
                    d, e, deflate_budget=0).eigenvalues,
                lambda: eigvalsh_tridiagonal_br(d, e).eigenvalues,
                iters=13)
            with br_dc.SOLVE_COUNTER.measure(deflation=True) as w:
                eigvalsh_tridiagonal_br(d, e).eigenvalues.block_until_ready()
            ratios = w.deflation_ratios
            top = max(ratios) if ratios else 0
            gauge = f"kprime/K@top={ratios.get(top, 1.0):.2f}"
            report(f"solver_deflate_seq_{fam}_n{n}", t_seq,
                   "sequential chain (deflate_budget=0)")
            report(f"solver_deflate_par_{fam}_n{n}", t_par,
                   f"speedup={t_seq / t_par:.2f}x, {gauge}")

    # ---- resident merge: one launch vs two-launch solve+postpass -------
    rng = np.random.default_rng(0)
    Ks = (128, 256) if quick else (128, 256, 512)
    for K in Ks:
        W = 8
        d = jnp.asarray(np.sort(rng.standard_normal((W, K)), axis=1))
        z = rng.standard_normal((W, K))
        z /= np.linalg.norm(z, axis=1, keepdims=True)
        z = jnp.asarray(z)
        rho = jnp.full((W,), 0.7)
        kp = jnp.full((W,), K, jnp.int32)
        R = jnp.asarray(rng.standard_normal((W, 2, K)))
        z2 = z * z

        @jax.jit
        def launch_solve(d, z2, rho, kp):
            return sec.secular_solve_batched(d, z2, rho, kp, dense=True)

        @jax.jit
        def launch_post(R, d, z, origin, tau, kp, rho):
            return sec.secular_postpass_batched(R, d, z, origin, tau, kp,
                                                rho, dense=True)

        @jax.jit
        def launch_one(d, z, R, rho, kp):
            return sec.secular_merge_resident_batched(d, z, R, rho, kp)

        def two_launch():
            o, t = launch_solve(d, z2, rho, kp)
            return launch_post(R, d, z, o, t, kp, rho)[1]

        def one_launch():
            return launch_one(d, z, R, rho, kp)[3]

        t2, t1 = time_pair(two_launch, one_launch, iters=21)
        report(f"resident_twolaunch_W{W}_K{K}", t2,
               "dense solve + postpass, 2 dispatches")
        report(f"resident_onelaunch_W{W}_K{K}", t1,
               f"fused resident merge, speedup={t2 / t1:.2f}x")
