"""Kernel microbenchmarks: chunked XLA path vs Pallas interpret mode.

Interpret-mode timings are NOT TPU performance (the body executes in
Python/XLA-on-CPU); they are recorded to document the validation cost and
the XLA-path throughput that the paper-style secular solve achieves on CPU.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import time_call
from repro.core import secular as sec
from repro.kernels.secular_roots import secular_solve_pallas


def run(report, K=2048):
    rng = np.random.default_rng(0)
    d = jnp.asarray(np.sort(rng.standard_normal(K)))
    z = rng.standard_normal(K)
    z /= np.linalg.norm(z)
    z2 = jnp.asarray(z * z)

    t_xla = time_call(lambda: sec.secular_solve(d, z2, 0.7, K, niter=16)[1])
    report(f"kern_secular_xla_K{K}", t_xla,
           f"{16 * K * K / t_xla / 1e9:.2f} Gterms/s")
    t_pl = time_call(
        lambda: secular_solve_pallas(d, z2, jnp.asarray(0.7, d.dtype),
                                     jnp.asarray(K), niter=16,
                                     interpret=True)[1], iters=1)
    report(f"kern_secular_pallas_interpret_K{K}", t_pl, "validation-mode")
