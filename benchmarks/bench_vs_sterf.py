"""Table 2 analogue: BR vs QR/QL (sterf) across spectral families.

Caveats mirrored from the paper (section 5.7): on Toeplitz/clustered both
algorithms are near-quadratic and BR's advantage shrinks to a constant
factor; on uniform/normal deflation makes BR's merge path cheap.

Note our sterf baseline is the masked fixed-shape QL (tests show it is
LAPACK-accurate); its constant factor is ~2x a block-tracked Fortran
implementation, which we report rather than hide -- the scipy stemr
reference time is included as an independent yardstick.
"""

from __future__ import annotations

import numpy as np
import scipy.linalg as sla

from benchmarks.common import time_call
from repro.core import (eigvalsh_tridiagonal_br, eigvalsh_tridiagonal_sterf,
                        make_family)

FAMILIES = ("uniform", "normal", "toeplitz", "clustered")


def run(report, sizes=(1024, 2048), sterf_max=2048):
    for family in FAMILIES:
        for n in sizes:
            d, e = make_family(family, n)
            t_br = time_call(
                lambda: eigvalsh_tridiagonal_br(d, e).eigenvalues)
            report(f"t2_br_{family}_n{n}", t_br, "")
            t0 = np.inf
            if n <= sterf_max:
                t0 = time_call(lambda: eigvalsh_tridiagonal_sterf(d, e),
                               iters=1)
                report(f"t2_sterf_{family}_n{n}", t0,
                       f"br_speedup={t0/t_br:.2f}x")
            import time as _t
            t1 = _t.perf_counter()
            sla.eigh_tridiagonal(d, e, eigvals_only=True)
            t_scipy = _t.perf_counter() - t1
            report(f"t2_scipy_stemr_{family}_n{n}", t_scipy,
                   f"br_vs_scipy={t_scipy/t_br:.2f}x")
