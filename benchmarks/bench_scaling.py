"""Empirical scaling exponents (paper sections 5.4 / 5.7).

Fits t ~ N^p for BR on deflation-friendly (uniform) and deflation-hostile
(toeplitz, clustered) families.  The paper's caveat is reproduced: BR is
*not* claimed sub-quadratic when deflation is weak.
"""

from __future__ import annotations

from benchmarks.common import fit_exponent, time_call
from repro.core import eigvalsh_tridiagonal_br, make_family


def run(report, sizes=(512, 1024, 2048, 4096)):
    for family in ("uniform", "toeplitz", "clustered"):
        ts = []
        for n in sizes:
            d, e = make_family(family, n)
            t = time_call(lambda: eigvalsh_tridiagonal_br(d, e).eigenvalues,
                          iters=2)
            ts.append(t)
            report(f"scaling_br_{family}_n{n}", t, "")
        p = fit_exponent(sizes, ts)
        report(f"scaling_exponent_{family}", 0.0, f"t~N^{p:.3f}")
