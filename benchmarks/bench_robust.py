"""Robustness overhead: what do the guard and the certificate cost?

The graceful-degradation machinery is only shippable if its steady-state
price is negligible -- a guard that taxes every healthy solve buys
nothing.  Rows (default n=4096, full spectrum):

  * ``robust_plain_n{..}``     -- the unguarded-equivalent baseline: the
    request core with certify off, scale 1 (the guard's zero-copy pass
    through; bit-identical to the seed behavior by construction);
  * ``robust_guard_n{..}``     -- same solve, measured against plain with
    interleaved timing: prices the front-door validation + equilibration
    screen alone (acceptance: <= 10% overhead);
  * ``robust_certify_n{..}``   -- certify=True: adds the one batched
    Sturm sweep (acceptance: <= 10% overhead -- the sweep is O(n log n)
    against the tree's O(n^2)-ish constant);
  * ``robust_serve_certified`` -- certified coalesced flush throughput
    vs uncertified through the service (acceptance: within 15%).

Rows feed BENCH_robust.json via
``python -m benchmarks.run --only robust --json BENCH_robust.json``.
"""

from __future__ import annotations

import threading

import numpy as np

from benchmarks.common import time_call, time_pair
from repro.core import SolveRequest, clear_plan_cache, execute_request
from repro.core import make_family


def _serve_throughput(certify: bool, problems, reqs_per_thread=4,
                      threads=4):
    from repro.serve import EigensolverClient
    import time as _time
    with EigensolverClient(max_batch=len(problems)) as client:
        futs = [None] * (threads * reqs_per_thread)

        def worker(t):
            for i in range(reqs_per_thread):
                d, e = problems[(t * reqs_per_thread + i) % len(problems)]
                futs[t * reqs_per_thread + i] = client.solve_async(
                    d, e, certify=certify)

        def drive():
            ts = [threading.Thread(target=worker, args=(t,))
                  for t in range(threads)]
            t0 = _time.perf_counter()
            for th in ts:
                th.start()
            for th in ts:
                th.join()
            for f in futs:
                f.result()
            return _time.perf_counter() - t0

        drive()   # warm-up pass: compiles the coalesced batch buckets
        wall = min(drive(), drive())
    return wall / len(futs)


def run(report, quick: bool = False, n: int | None = None):
    if n is None:
        n = 1024 if quick else 4096
    d, e = make_family("normal", n)
    iters = 3 if quick else 5

    clear_plan_cache()
    plain_req = SolveRequest(d=d, e=e)
    cert_req = SolveRequest(d=d, e=e, certify=True)

    # Warm both executables (the certify sweep is its own jit; the tree
    # executable is shared -- pinned by tests/test_guard.py).
    execute_request(plain_req)
    execute_request(cert_req)

    t_plain, t_cert = time_pair(
        lambda: execute_request(plain_req).eigenvalues,
        lambda: execute_request(cert_req).eigenvalues, iters=iters)
    report(f"robust_plain_n{n}", t_plain, "request core, certify off")
    cert_over = (t_cert / t_plain - 1.0) * 100.0
    report(f"robust_certify_n{n}", t_cert,
           f"certified solve, overhead={cert_over:+.1f}% "
           f"(bar <= 10%)")

    # Guard-alone price: the validation + equilibration screen runs
    # host-side before every routed solve; price it directly (numpy
    # reductions over (n,) + (n-1,)) relative to the solve.
    from repro.core import guard as _guard
    t_screen = time_call(
        lambda: _guard.equilibrate(*_guard.validate_problem(d, e)),
        warmup=1, iters=max(iters, 10))
    report(f"robust_guard_n{n}", t_plain + t_screen,
           f"guarded solve, overhead={t_screen / t_plain * 100.0:+.2f}% "
           f"(bar <= 10%)")

    # Certified serving throughput vs uncertified.
    count = 4 if quick else 8
    ns = n // 4
    rng = np.random.default_rng(0)
    problems = [(rng.normal(size=ns), rng.normal(size=ns - 1))
                for _ in range(count)]
    per_req_plain = _serve_throughput(False, problems)
    per_req_cert = _serve_throughput(True, problems)
    gap = (per_req_cert / per_req_plain - 1.0) * 100.0
    report("robust_serve_certified", per_req_cert,
           f"certified flush vs plain {per_req_plain * 1e6:.0f}us, "
           f"gap={gap:+.1f}% (bar <= 15%)")
