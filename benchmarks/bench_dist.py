"""Strong-scaling benchmark for the distributed-conquer sharded solve.

One huge problem per row, solved end-to-end at mesh widths P in
{1, 2, 4} (capped by the visible device count), so the derived column
is the strong-scaling ratio wall(P) / wall(1) -- the number the
distributed-conquer acceptance gate reads from BENCH_dist.json:

    PYTHONPATH=src python -m benchmarks.run --only dist --json BENCH_dist.json

The driver forces >= 4 host devices for ``--only dist`` runs (or
exactly ``--mesh P``); on a box whose *physical* core count is below the
mesh width the forced devices time-slice one core and the ratio
degrades toward >= 1.0 -- the JSON meta block records cpu_count so a
reader can tell real scaling from oversubscription.
"""

from __future__ import annotations

import time

import numpy as np


def _time_solve(d, e, P):
    import jax

    from repro.core.br_dc import eigvalsh_tridiagonal_batch

    # Warmup carries the trace + compile for this (n, P) bucket.
    jax.block_until_ready(
        eigvalsh_tridiagonal_batch(d, e, mesh=P).eigenvalues)
    t0 = time.perf_counter()
    jax.block_until_ready(
        eigvalsh_tridiagonal_batch(d, e, mesh=P).eigenvalues)
    return time.perf_counter() - t0


def run(report, quick: bool = False, max_shards: int | None = None,
        sizes=None):
    import jax

    devs = jax.device_count()
    if devs < 2:
        report("dist_SKIP", 0.0,
               f"needs >= 2 devices, have {devs}; run via "
               f"`benchmarks.run --only dist` (forces host devices)")
        return
    if sizes is None:
        sizes = (2048, 4096) if quick else (16384, 65536)
    widths = [P for P in (1, 2, 4) if P <= devs]
    if max_shards is not None:
        widths = [P for P in widths if P <= max_shards] or [1]

    rng = np.random.default_rng(0)
    for n in sizes:
        d = rng.standard_normal((1, n))
        e = rng.standard_normal((1, n - 1))
        base = None
        for P in widths:
            dt = _time_solve(d, e, P)
            if P == 1:
                base = dt
                derived = "P1 baseline"
            else:
                derived = f"vs_P1={dt / base:.3f}" if base else ""
            report(f"dist_n{n}_P{P}", dt, derived)
