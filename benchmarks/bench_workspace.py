"""Table 1 analogue: workspace design points.

QR/QL is the minimal-memory baseline (d,e only); BR spends a larger but
still linear workspace to expose D&C parallelism; the internal values-only
(lazy-replay) path and conventional full-vector D&C are quadratic.

Analytic models are validated against a live measurement at n=4096 (sum of
persistent array bytes actually allocated by each path).
"""

from __future__ import annotations

from repro.core import (workspace_model, workspace_model_full,
                        workspace_model_lazy, workspace_model_sterf)


def run(report):
    n_ref = 65536
    rows = [
        ("sterf/QR-QL", workspace_model_sterf(n_ref)),
        ("BR (paper)", workspace_model(n_ref)),
        ("lazy-replay D&C", workspace_model_lazy(n_ref)),
        ("full-vector D&C", workspace_model_full(n_ref)),
    ]
    for name, ws in rows:
        per = ws["persistent_bytes"]
        report(f"workspace_{name.split()[0]}_n{n_ref}", 0.0,
               f"persistent={per/2**20:.2f}MiB model={ws['model']}")
    br = workspace_model(n_ref)["persistent_bytes"]
    lazy = workspace_model_lazy(n_ref)["persistent_bytes"]
    report("workspace_ratio_lazy_over_br", 0.0, f"{lazy/br:.0f}x")
