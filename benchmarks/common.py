"""Shared benchmark utilities."""

from __future__ import annotations

import time

import jax
import numpy as np


def time_call(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Best-of-iters wall time in seconds (matches the paper's protocol:
    best elapsed over repeated runs for small sizes)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    best = np.inf
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        best = min(best, time.perf_counter() - t0)
    return best


def time_pair(fn_a, fn_b, *, iters: int = 7):
    """Best-of-iters wall times of two competing implementations, measured
    INTERLEAVED (a, b, a, b, ...) so background-load drift hits both
    equally.  Best-of (not mean/median) because scheduler/throttle spikes
    only ever inflate a sample -- the minimum is the honest estimate of
    each implementation's unloaded cost on shared hosts."""
    jax.block_until_ready(fn_a())
    jax.block_until_ready(fn_b())
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        tb.append(time.perf_counter() - t0)
    return float(np.min(ta)), float(np.min(tb))


def csv_row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"


def fit_exponent(ns, ts):
    """Least-squares slope of log t vs log n."""
    ns = np.asarray(ns, float)
    ts = np.asarray(ts, float)
    A = np.stack([np.log(ns), np.ones_like(ns)], axis=1)
    coef, *_ = np.linalg.lstsq(A, np.log(ts), rcond=None)
    return float(coef[0])
