"""Baselines: STERF (QR/QL), lazy-replay D&C, full-vector D&C.

Theorem 3.3's premise (shared merge core) means lazy/full/BR must agree to
rounding; STERF is an independent algorithm and checked against scipy.
"""

import numpy as np
import pytest
import scipy.linalg as sla

from repro.core import (
    eig_tridiagonal_full_dc,
    eigvalsh_tridiagonal,
    eigvalsh_tridiagonal_lazy,
    eigvalsh_tridiagonal_sterf,
    dense_from_tridiag,
    make_family,
)


@pytest.mark.parametrize("family", ["uniform", "toeplitz", "clustered"])
@pytest.mark.parametrize("n", [16, 100, 256])
def test_sterf_matches_lapack(family, n):
    d, e = make_family(family, n)
    got = np.asarray(eigvalsh_tridiagonal_sterf(d, e))
    ref = sla.eigh_tridiagonal(d, e, eigvals_only=True)
    assert np.max(np.abs(got - ref)) / max(1, np.max(np.abs(ref))) < 1e-11


@pytest.mark.parametrize("family", ["uniform", "normal", "clustered"])
def test_lazy_replay_agrees_with_br(family):
    """Same split tree + deflation + secular convention => same values."""
    n = 128
    d, e = make_family(family, n)
    br = np.asarray(eigvalsh_tridiagonal(d, e, leaf=8, method="br"))
    lazy = np.asarray(eigvalsh_tridiagonal_lazy(d, e, leaf=8))
    np.testing.assert_allclose(lazy, br, atol=1e-11, rtol=0)


@pytest.mark.parametrize("n", [32, 96])
def test_full_dc_eigenpairs(n):
    """Full-vector D&C: A Q = Q diag(lam) and Q orthogonal."""
    d, e = make_family("uniform", n)
    lam, Q = eig_tridiagonal_full_dc(d, e, leaf=8)
    lam, Q = np.asarray(lam), np.asarray(Q)
    A = np.asarray(dense_from_tridiag(d, e))
    assert np.max(np.abs(Q.T @ Q - np.eye(n))) < 1e-10
    assert np.max(np.abs(A @ Q - Q * lam[None, :])) < 1e-9
    ref = sla.eigh_tridiagonal(d, e, eigvals_only=True)
    assert np.max(np.abs(lam - ref)) < 1e-11


def test_all_methods_agree():
    d, e = make_family("normal", 150)
    ref = sla.eigh_tridiagonal(d, e, eigvals_only=True)
    for method in ("br", "sterf", "lazy", "full", "eigh", "bisect"):
        got = np.asarray(eigvalsh_tridiagonal(d, e, method=method))
        err = np.max(np.abs(got - ref)) / max(1, np.max(np.abs(ref)))
        assert err < 1e-10, (method, err)
