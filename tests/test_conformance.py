"""Cross-method conformance: every solver, every family, one tolerance.

One parametrized matrix over FAMILIES x methods x sizes, all compared to
``scipy.linalg.eigh_tridiagonal`` at the single documented tolerance

    CONFORMANCE_TOL = 64 * eps * max(1, ||T||_inf)

(|T|_inf bounded by max|d| + 2 max|e|).  64 eps absorbs both sides'
rounding: the paper's own accuracy bar is 8 * eps * ||T|| against the
*same-arithmetic* full solve, but a cross-library comparison stacks
scipy/LAPACK's error on top of ours (measured worst case across the
sweep is ~40 eps * ||T||, uniform family at n = 257).  Methods that
agree to 8 eps internally are pinned by tests/test_bisect.py and
tests/test_batched.py; this suite is the external contract.

The Toeplitz family additionally has the closed form

    lam_j = d + 2 |e| cos(pi j / (n + 1)),   j = 1..n

which is an *exact external oracle* -- no LAPACK in the loop at all.
"""

import numpy as np
import pytest
import scipy.linalg as sla

from repro.core import (FAMILIES, METHODS, eigvalsh_tridiagonal,
                        eigvalsh_tridiagonal_range, make_family)

EPS = np.finfo(np.float64).eps
CONFORMANCE_TOL_EPS = 64.0


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_executables():
    # The conformance sweep (methods x families x sizes, now including
    # the precision="mixed" column's f32 tree + certify/refine
    # executables) is the biggest single compile load in the suite.
    # XLA:CPU holds every executable's memory mappings until process
    # exit and vm.max_map_count is a process-wide kernel budget, so
    # release the plan cache and jit caches when the module finishes.
    yield
    import jax

    from repro.core.plan import clear_plan_cache
    clear_plan_cache()
    jax.clear_caches()

SIZES = (1, 2, 3, 17, 128, 257)

# Per-method solver kwargs: the D&C methods take the tree knobs (small
# leaf keeps multi-level merge trees in play at these sizes); sterf /
# eigh / bisect have no tree.
_METHOD_KW = {
    "br": {"leaf": 8},
    "lazy": {"leaf": 8},
    "full": {"leaf": 8},
    "sterf": {},
    "eigh": {},
    "bisect": {},
}


def conformance_tol(d, e):
    nrm = np.max(np.abs(d)) + (2.0 * np.max(np.abs(e)) if len(e) else 0.0)
    return CONFORMANCE_TOL_EPS * EPS * max(1.0, nrm)


def _scipy_ref(d, e):
    if len(d) == 1:
        return np.asarray(d, np.float64)
    return sla.eigh_tridiagonal(d, e, eigvals_only=True)


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("family", FAMILIES)
def test_method_matches_scipy(family, method, n):
    d, e = make_family(family, n)
    got = np.asarray(eigvalsh_tridiagonal(d, e, method=method,
                                          **_METHOD_KW[method]))
    ref = _scipy_ref(d, e)
    assert got.shape == ref.shape
    np.testing.assert_allclose(got, ref, rtol=0, atol=conformance_tol(d, e))
    assert np.all(np.diff(got) >= -conformance_tol(d, e))   # ascending


@pytest.mark.partial
@pytest.mark.parametrize("n", [n for n in SIZES if n > 1])
@pytest.mark.parametrize("family", FAMILIES)
def test_range_slice_matches_scipy(family, n):
    """The sliced path joins the conformance matrix: an interior window
    (and the full window at tiny n) against the same scipy slice."""
    d, e = make_family(family, n)
    ref = _scipy_ref(d, e)
    il, iu = (0, n - 1) if n <= 3 else (n // 4, n // 4 + min(8, n // 2))
    got = np.asarray(eigvalsh_tridiagonal_range(d, e, select="i",
                                                il=il, iu=iu))
    np.testing.assert_allclose(got, ref[il:iu + 1], rtol=0,
                               atol=conformance_tol(d, e))


@pytest.mark.mixed
@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("family", FAMILIES)
def test_mixed_matches_scipy(family, n):
    """precision="mixed" joins the conformance matrix: the f32 tree +
    Sturm-certified f64 refinement must meet the same external 64-eps
    contract as every native method, for every family x size."""
    d, e = make_family(family, n)
    got = np.asarray(eigvalsh_tridiagonal(d, e, precision="mixed", leaf=8))
    assert got.dtype == np.float64        # mixed returns f64 eigenvalues
    ref = _scipy_ref(d, e)
    assert got.shape == ref.shape
    np.testing.assert_allclose(got, ref, rtol=0, atol=conformance_tol(d, e))
    assert np.all(np.diff(got) >= 0.0)    # refinement sorts exactly


def _toeplitz_closed_form(n, d0=2.0, e0=0.25):
    j = np.arange(1, n + 1, dtype=np.float64)
    return np.sort(d0 + 2.0 * abs(e0) * np.cos(np.pi * j / (n + 1)))


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("method", METHODS)
def test_toeplitz_closed_form(method, n):
    """Analytic eigenvalues of the Toeplitz family: an exact external
    oracle that does not route through any LAPACK implementation."""
    d, e = make_family("toeplitz", n)
    got = np.asarray(eigvalsh_tridiagonal(d, e, method=method,
                                          **_METHOD_KW[method]))
    want = _toeplitz_closed_form(n)
    np.testing.assert_allclose(got, want, rtol=0,
                               atol=conformance_tol(d, e))


@pytest.mark.mixed
@pytest.mark.parametrize("n", SIZES)
def test_toeplitz_closed_form_mixed(n):
    """Mixed precision against the exact analytic oracle -- the cosine
    spectrum is dense with near-uniform gaps, a worst case for an f32
    tree's cluster resolution."""
    d, e = make_family("toeplitz", n)
    got = np.asarray(eigvalsh_tridiagonal(d, e, precision="mixed", leaf=8))
    np.testing.assert_allclose(got, _toeplitz_closed_form(n), rtol=0,
                               atol=conformance_tol(d, e))


@pytest.mark.partial
@pytest.mark.parametrize("n", [17, 128, 257])
def test_toeplitz_closed_form_range(n):
    d, e = make_family("toeplitz", n)
    want = _toeplitz_closed_form(n)
    k = 5
    got = np.asarray(eigvalsh_tridiagonal_range(d, e, select="i",
                                                il=n - k, iu=n - 1))
    np.testing.assert_allclose(got, want[n - k:], rtol=0,
                               atol=conformance_tol(d, e))
