"""Property-based tests (hypothesis) for the secular solver + merge core.

System invariants under test:
  * interlacing:  d_j < lam_j < d_{j+1}  (strict, active poles)
  * agreement with dense numpy eigvalsh on diag(d) + rho z z^T
  * deflation invariance: zero-weight poles pass through exactly
  * shift invariance: spectrum(d + c) == spectrum(d) + c
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional dev dependency "
    "`hypothesis` (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.secular import secular_solve, secular_eigenvalues


def _solve(d, z2, rho, kprime, niter=24):
    origin, tau = secular_solve(jnp.asarray(d), jnp.asarray(z2),
                                rho, kprime, niter=niter)
    return np.asarray(jnp.asarray(d)[origin] + tau)


@st.composite
def secular_problem(draw):
    K = draw(st.integers(min_value=2, max_value=48))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    # separated poles (deflation is tested separately)
    gaps = rng.uniform(0.05, 1.0, K)
    d = np.cumsum(gaps)
    z = rng.uniform(0.1, 1.0, K) * rng.choice([-1.0, 1.0], K)
    z /= np.linalg.norm(z)
    rho = float(draw(st.sampled_from([1e-3, 0.1, 1.0, 10.0])))
    return d, z, rho


@given(secular_problem())
@settings(max_examples=40, deadline=None)
def test_matches_dense_eigvalsh(prob):
    d, z, rho = prob
    K = len(d)
    lam = np.sort(_solve(d, z * z, rho, K))
    ref = np.linalg.eigvalsh(np.diag(d) + rho * np.outer(z, z))
    scale = max(1.0, np.max(np.abs(ref)))
    assert np.max(np.abs(lam - ref)) / scale < 1e-11


@given(secular_problem())
@settings(max_examples=40, deadline=None)
def test_interlacing(prob):
    d, z, rho = prob
    K = len(d)
    lam = _solve(d, z * z, rho, K)
    span = rho * np.sum(z * z)
    assert np.all(lam[:-1] > d[:-1]) and np.all(lam[:-1] < d[1:])
    assert d[-1] < lam[-1] <= d[-1] + span + 1e-12


@given(secular_problem(), st.integers(min_value=1, max_value=10))
@settings(max_examples=25, deadline=None)
def test_deflated_passthrough(prob, extra):
    """Poles appended with z == 0 beyond kprime come back verbatim."""
    d, z, rho = prob
    K = len(d)
    d_ext = np.concatenate([d, d[-1] + 1.0 + np.arange(extra)])
    z2_ext = np.concatenate([z * z, np.zeros(extra)])
    origin, tau = secular_solve(jnp.asarray(d_ext), jnp.asarray(z2_ext),
                                rho, K, niter=24)
    lam = np.asarray(jnp.asarray(d_ext)[origin] + tau)
    np.testing.assert_array_equal(lam[K:], d_ext[K:])
    ref = np.linalg.eigvalsh(np.diag(d) + rho * np.outer(z, z))
    assert np.max(np.abs(np.sort(lam[:K]) - ref)) < 1e-10


@given(secular_problem(), st.floats(min_value=-5.0, max_value=5.0,
                                    allow_nan=False))
@settings(max_examples=25, deadline=None)
def test_shift_invariance(prob, shift):
    d, z, rho = prob
    K = len(d)
    lam0 = np.sort(_solve(d, z * z, rho, K))
    lam1 = np.sort(_solve(d + shift, z * z, rho, K))
    assert np.max(np.abs(lam1 - (lam0 + shift))) < 1e-9


@st.composite
def tridiag_problem(draw):
    n = draw(st.integers(min_value=4, max_value=120))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    d = rng.standard_normal(n)
    e = rng.uniform(1e-3, 1.0, n - 1) * rng.choice([-1.0, 1.0], n - 1)
    return d, e


@given(tridiag_problem())
@settings(max_examples=30, deadline=None)
def test_br_full_pipeline_property(prob):
    """End-to-end BR vs scipy on arbitrary tridiagonals (signs, scales)."""
    import scipy.linalg as sla
    from repro.core import eigvalsh_tridiagonal
    d, e = prob
    got = np.asarray(eigvalsh_tridiagonal(d, e, leaf=8))
    ref = sla.eigh_tridiagonal(d, np.abs(e), eigvals_only=True)
    # |e| is WLOG: the tridiagonal spectrum is invariant to off-diag signs
    scale = max(1.0, np.max(np.abs(ref)))
    assert np.max(np.abs(got - ref)) / scale < 1e-11
