"""Property-based tests (hypothesis) for the secular solver + solver core.

System invariants under test:
  * interlacing:  d_j < lam_j < d_{j+1}  (strict, active poles)
  * agreement with dense numpy eigvalsh on diag(d) + rho z z^T
  * deflation invariance: zero-weight poles pass through exactly
  * shift invariance: spectrum(d + c) == spectrum(d) + c
  * whole-solver analytic invariants, run against BOTH the full BR path
    and the sliced (Sturm bisection) range path:
      - affine equivariance  eig(alpha T + beta I) = alpha eig(T) + beta
      - trace / Frobenius     sum lam = sum d;  sum lam^2 = |d|^2 + 2|e|^2
      - Cauchy interlacing of the leading (n-1)-submatrix
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional dev dependency "
    "`hypothesis` (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.secular import secular_solve, secular_eigenvalues


def _solve(d, z2, rho, kprime, niter=24):
    origin, tau = secular_solve(jnp.asarray(d), jnp.asarray(z2),
                                rho, kprime, niter=niter)
    return np.asarray(jnp.asarray(d)[origin] + tau)


@st.composite
def secular_problem(draw):
    K = draw(st.integers(min_value=2, max_value=48))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    # separated poles (deflation is tested separately)
    gaps = rng.uniform(0.05, 1.0, K)
    d = np.cumsum(gaps)
    z = rng.uniform(0.1, 1.0, K) * rng.choice([-1.0, 1.0], K)
    z /= np.linalg.norm(z)
    rho = float(draw(st.sampled_from([1e-3, 0.1, 1.0, 10.0])))
    return d, z, rho


@given(secular_problem())
@settings(max_examples=40, deadline=None)
def test_matches_dense_eigvalsh(prob):
    d, z, rho = prob
    K = len(d)
    lam = np.sort(_solve(d, z * z, rho, K))
    ref = np.linalg.eigvalsh(np.diag(d) + rho * np.outer(z, z))
    scale = max(1.0, np.max(np.abs(ref)))
    assert np.max(np.abs(lam - ref)) / scale < 1e-11


@given(secular_problem())
@settings(max_examples=40, deadline=None)
def test_interlacing(prob):
    d, z, rho = prob
    K = len(d)
    lam = _solve(d, z * z, rho, K)
    span = rho * np.sum(z * z)
    assert np.all(lam[:-1] > d[:-1]) and np.all(lam[:-1] < d[1:])
    assert d[-1] < lam[-1] <= d[-1] + span + 1e-12


@given(secular_problem(), st.integers(min_value=1, max_value=10))
@settings(max_examples=25, deadline=None)
def test_deflated_passthrough(prob, extra):
    """Poles appended with z == 0 beyond kprime come back verbatim."""
    d, z, rho = prob
    K = len(d)
    d_ext = np.concatenate([d, d[-1] + 1.0 + np.arange(extra)])
    z2_ext = np.concatenate([z * z, np.zeros(extra)])
    origin, tau = secular_solve(jnp.asarray(d_ext), jnp.asarray(z2_ext),
                                rho, K, niter=24)
    lam = np.asarray(jnp.asarray(d_ext)[origin] + tau)
    np.testing.assert_array_equal(lam[K:], d_ext[K:])
    ref = np.linalg.eigvalsh(np.diag(d) + rho * np.outer(z, z))
    assert np.max(np.abs(np.sort(lam[:K]) - ref)) < 1e-10


@given(secular_problem(), st.floats(min_value=-5.0, max_value=5.0,
                                    allow_nan=False))
@settings(max_examples=25, deadline=None)
def test_shift_invariance(prob, shift):
    d, z, rho = prob
    K = len(d)
    lam0 = np.sort(_solve(d, z * z, rho, K))
    lam1 = np.sort(_solve(d + shift, z * z, rho, K))
    assert np.max(np.abs(lam1 - (lam0 + shift))) < 1e-9


@st.composite
def tridiag_problem(draw):
    n = draw(st.integers(min_value=4, max_value=120))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    d = rng.standard_normal(n)
    e = rng.uniform(1e-3, 1.0, n - 1) * rng.choice([-1.0, 1.0], n - 1)
    return d, e


@given(tridiag_problem())
@settings(max_examples=30, deadline=None)
def test_br_full_pipeline_property(prob):
    """End-to-end BR vs scipy on arbitrary tridiagonals (signs, scales)."""
    import scipy.linalg as sla
    from repro.core import eigvalsh_tridiagonal
    d, e = prob
    got = np.asarray(eigvalsh_tridiagonal(d, e, leaf=8))
    ref = sla.eigh_tridiagonal(d, np.abs(e), eigvals_only=True)
    # |e| is WLOG: the tridiagonal spectrum is invariant to off-diag signs
    scale = max(1.0, np.max(np.abs(ref)))
    assert np.max(np.abs(got - ref)) / scale < 1e-11


# ---------------------------------------------------------------------------
# Whole-solver analytic invariants (full BR path AND the sliced range path)
# ---------------------------------------------------------------------------

@st.composite
def tridiag_problem_fixed_n(draw):
    """Like tridiag_problem but n drawn from a small set, so the sliced
    path's per-n executables stay on a handful of compiles."""
    n = draw(st.sampled_from([16, 33, 64, 100]))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    d = rng.standard_normal(n)
    e = rng.uniform(1e-3, 1.0, n - 1) * rng.choice([-1.0, 1.0], n - 1)
    return d, e


def _solve_both_paths(d, e, il, iu):
    """(full-path slice, range-path slice) over indices [il, iu]."""
    from repro.core import eigvalsh_tridiagonal, eigvalsh_tridiagonal_range
    full = np.asarray(eigvalsh_tridiagonal(d, e, leaf=8))[il:iu + 1]
    rng_ = np.asarray(eigvalsh_tridiagonal_range(d, e, select="i",
                                                 il=il, iu=iu))
    return full, rng_


@given(tridiag_problem_fixed_n(),
       st.floats(min_value=0.1, max_value=4.0, allow_nan=False),
       st.floats(min_value=-5.0, max_value=5.0, allow_nan=False))
@settings(max_examples=20, deadline=None)
def test_affine_equivariance_both_paths(prob, alpha, beta):
    """eig(alpha T + beta I) == alpha eig(T) + beta, positive alpha (index
    order preserved), for the full BR path and the sliced range path."""
    d, e = prob
    n = len(d)
    il, iu = n // 3, n // 3 + min(5, n // 2)
    base_f, base_r = _solve_both_paths(d, e, il, iu)
    aff_f, aff_r = _solve_both_paths(alpha * d + beta, alpha * e, il, iu)
    scale = max(1.0, abs(alpha) * np.max(np.abs(d)) + abs(beta))
    assert np.max(np.abs(aff_f - (alpha * base_f + beta))) / scale < 1e-10
    assert np.max(np.abs(aff_r - (alpha * base_r + beta))) / scale < 1e-10


@given(tridiag_problem_fixed_n())
@settings(max_examples=15, deadline=None)
def test_negation_reverses_spectrum_both_paths(prob):
    """alpha = -1: eig(-T) = -reverse(eig(T)); the top-k slice of -T is
    the negated bottom-k slice of T."""
    from repro.core import eigvalsh_tridiagonal, eigvalsh_tridiagonal_range
    d, e = prob
    n = len(d)
    lam = np.asarray(eigvalsh_tridiagonal(d, e, leaf=8))
    neg = np.asarray(eigvalsh_tridiagonal(-d, e, leaf=8))
    scale = max(1.0, np.max(np.abs(lam)))
    assert np.max(np.abs(neg - (-lam[::-1]))) / scale < 1e-10
    k = min(4, n)
    top_neg = np.asarray(eigvalsh_tridiagonal_range(-d, e, select="i",
                                                    il=n - k, iu=n - 1))
    assert np.max(np.abs(top_neg - (-lam[:k][::-1]))) / scale < 1e-10


@given(tridiag_problem_fixed_n())
@settings(max_examples=20, deadline=None)
def test_trace_and_frobenius_invariants(prob):
    """sum lam == trace(T) and sum lam^2 == ||T||_F^2 = |d|^2 + 2|e|^2
    -- exact matrix invariants every correct spectrum must reproduce."""
    from repro.core import eigvalsh_tridiagonal
    d, e = prob
    n = len(d)
    for method in ("br", "bisect"):
        lam = np.asarray(eigvalsh_tridiagonal(
            d, e, method=method, **({"leaf": 8} if method == "br" else {})))
        tr = np.sum(d)
        fro2 = np.sum(d * d) + 2.0 * np.sum(e * e)
        scale = max(1.0, abs(tr), fro2)
        assert abs(np.sum(lam) - tr) / max(1.0, abs(tr)) < n * 1e-13, method
        assert abs(np.sum(lam * lam) - fro2) / fro2 < n * 1e-13, method


@given(tridiag_problem_fixed_n())
@settings(max_examples=15, deadline=None)
def test_cauchy_interlacing_both_paths(prob):
    """Eigenvalues of the leading (n-1)-submatrix interlace the full
    spectrum: lam_j(T) <= mu_j <= lam_{j+1}(T)."""
    from repro.core import eigvalsh_tridiagonal, eigvalsh_tridiagonal_range
    d, e = prob
    n = len(d)
    lam = np.asarray(eigvalsh_tridiagonal(d, e, leaf=8))
    tol = 1e-10 * max(1.0, np.max(np.abs(lam)))
    for path in ("br", "range"):
        if path == "br":
            mu = np.asarray(eigvalsh_tridiagonal(d[:-1], e[:-1], leaf=8))
        else:
            mu = np.asarray(eigvalsh_tridiagonal_range(
                d[:-1], e[:-1], select="i", il=0, iu=n - 2))
        assert np.all(lam[:-1] <= mu + tol), path
        assert np.all(mu <= lam[1:] + tol), path
