"""Deflation-heavy merge coverage: the parallel deflation head.

  * glued-Wilkinson family (the canonical deflation-heavy D&C stress
    input) solved with the parallel detect-compact-apply head vs the
    exact sequential chain (``deflate_budget=0``): eigenvalues within
    8 * eps * ||T||, identical per-level kprime, on single AND batched
    (B, n) paths;
  * low-deflation families: the two paths are bit-identical (no
    rotations fire, so the restricted chain is a provable no-op of the
    sequential one);
  * budget-overflow tier escalation: a budget of 1 overflows on every
    deflation-heavy merge and must escalate to the K/2 / full-K tiers
    without changing results; a detected missed cascade (forced) must
    take the sequential fallback bit-exactly;
  * the deflation-ratio gauge: per-level kprime/K observed inside
    ``measure(deflation=True)`` windows, nothing recorded otherwise.
"""

import numpy as np
import pytest
import scipy.linalg as sla

from repro.core import br_dc
from repro.core import (eigvalsh_tridiagonal_batch, eigvalsh_tridiagonal_br,
                        make_family, make_family_batch)

pytestmark = pytest.mark.deflation


def _tnorm(d, e):
    return float(np.max(np.abs(d)) + 2.0 * np.max(np.abs(e)))


@pytest.mark.parametrize("mat", ["glued_wilkinson", "toeplitz"])
@pytest.mark.parametrize("n", [96, 200, 320])
def test_parallel_head_matches_sequential_chain(mat, n):
    """Parallel head vs sequential chain: same rotations, same kprime,
    eigenvalues to within 8 * eps * ||T|| (identical up to the
    compiler's per-program FMA contraction in the rotation updates)."""
    d, e = make_family(mat, n)
    r_par = eigvalsh_tridiagonal_br(d, e, leaf=8, return_boundary=True)
    r_seq = eigvalsh_tridiagonal_br(d, e, leaf=8, return_boundary=True,
                                    deflate_budget=0)
    tol = 8 * np.finfo(np.float64).eps * _tnorm(d, e)
    np.testing.assert_allclose(np.asarray(r_par.eigenvalues),
                               np.asarray(r_seq.eigenvalues),
                               rtol=0, atol=tol)
    if mat == "toeplitz":
        # Entrywise boundary-row comparison is only well-posed away from
        # eigenvalue clusters (glued-Wilkinson's 1e-8-wide clusters let
        # eigenvector entries rotate freely under one-ulp pole changes).
        np.testing.assert_allclose(np.asarray(r_par.bhi),
                                   np.asarray(r_seq.bhi),
                                   rtol=0, atol=1e-12)
    assert abs(np.linalg.norm(np.asarray(r_par.bhi)) - 1.0) < 1e-9
    for kp, ks in zip(r_par.kprime_per_level, r_seq.kprime_per_level):
        np.testing.assert_array_equal(np.asarray(kp), np.asarray(ks))


@pytest.mark.parametrize("mat", ["glued_wilkinson", "toeplitz"])
def test_parallel_head_matches_lapack(mat):
    """The parallel head must not cost accuracy against LAPACK through
    the whole tree (glued-Wilkinson resolves to its 1e-8 cluster width,
    as any D&C does)."""
    n = 200
    d, e = make_family(mat, n)
    ref = sla.eigh_tridiagonal(d, e, eigvals_only=True)
    got = eigvalsh_tridiagonal_br(d, e, leaf=8).eigenvalues
    scale = max(1.0, np.max(np.abs(ref)))
    tol = 5e-13 if mat == "toeplitz" else 1e-7
    assert np.max(np.abs(np.asarray(got) - ref)) / scale < tol


@pytest.mark.parametrize("family", ["normal", "uniform", "clustered"])
def test_low_deflation_bit_identical(family):
    """On low-deflation families no rotation fires, so the parallel head
    and the overflow-fallback (sequential) path must agree BIT-exactly --
    batched, through the full tree."""
    D, E = make_family_batch(family, 200, 3)
    r_par = eigvalsh_tridiagonal_batch(D, E, leaf=8, return_boundary=True)
    r_seq = eigvalsh_tridiagonal_batch(D, E, leaf=8, return_boundary=True,
                                       deflate_budget=0)
    np.testing.assert_array_equal(np.asarray(r_par.eigenvalues),
                                  np.asarray(r_seq.eigenvalues))
    np.testing.assert_array_equal(np.asarray(r_par.bhi),
                                  np.asarray(r_seq.bhi))


@pytest.mark.parametrize("n", [160, 256])
def test_batched_glued_matches_sequential(n):
    """Batched (B, n) glued-Wilkinson path: parallel head vs sequential
    chain per problem, plus identical per-level kprime diagnostics."""
    D, E = make_family_batch("glued_wilkinson", n, 4)
    r_par = eigvalsh_tridiagonal_batch(D, E, leaf=8)
    r_seq = eigvalsh_tridiagonal_batch(D, E, leaf=8, deflate_budget=0)
    tol = 8 * np.finfo(np.float64).eps * max(
        _tnorm(D[b], E[b]) for b in range(D.shape[0]))
    np.testing.assert_allclose(np.asarray(r_par.eigenvalues),
                               np.asarray(r_seq.eigenvalues),
                               rtol=0, atol=tol)
    for kp, ks in zip(r_par.kprime_per_level, r_seq.kprime_per_level):
        np.testing.assert_array_equal(np.asarray(kp), np.asarray(ks))


def test_budget_overflow_escalates_tiers_exactly():
    """deflate_budget=1 overflows on every deflation-heavy merge (tens of
    rotation candidates per node) -> the level must escalate to the K/2
    or full-K tier and still match the sequential baseline (same
    rotations, identical kprime, eigenvalues within the rotation
    arithmetic's FMA-contraction ulp)."""
    d, e = make_family("glued_wilkinson", 200)
    r_tiny = eigvalsh_tridiagonal_br(d, e, leaf=8, deflate_budget=1)
    r_seq = eigvalsh_tridiagonal_br(d, e, leaf=8, deflate_budget=0)
    tol = 8 * np.finfo(np.float64).eps * _tnorm(d, e)
    np.testing.assert_allclose(np.asarray(r_tiny.eigenvalues),
                               np.asarray(r_seq.eigenvalues),
                               rtol=0, atol=tol)
    for kp, ks in zip(r_tiny.kprime_per_level, r_seq.kprime_per_level):
        np.testing.assert_array_equal(np.asarray(kp), np.asarray(ks))


def test_missed_cascade_falls_back_to_sequential(monkeypatch):
    """The sequential fallback fires on a detected missed rotation; force
    the detector to report a miss and pin that the level output is the
    sequential chain's, bit for bit."""
    import jax
    import jax.numpy as jnp
    from repro.core import merge as M

    rng = np.random.default_rng(0)
    W, K = 3, 64
    d = jnp.asarray(np.sort(rng.standard_normal((W, K)), axis=1))
    z = rng.standard_normal((W, K))
    z /= np.linalg.norm(z, axis=1, keepdims=True)
    z = jnp.asarray(z)
    tol = jnp.full((W,), 1e-12)
    small = jnp.zeros((W, K), bool)
    R = jnp.asarray(rng.standard_normal((W, 2, K)))

    want = jax.vmap(M._close_pole_scan)(d, z, R, small, tol)
    monkeypatch.setattr(
        M, "_deflate_missed",
        lambda d0, z0, d1, z1, small, tol, pk, cand: jnp.any(d0 == d0))
    got = M._deflate_level(d, z, R, small, tol, budget=8)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_huge_budget_degrades_to_sequential():
    """A budget >= K cannot shorten the chain; the dispatch must fall
    through to the sequential scan (still exact, no cond overhead)."""
    d, e = make_family("glued_wilkinson", 96)
    r_big = eigvalsh_tridiagonal_br(d, e, leaf=8, deflate_budget=1 << 20)
    r_seq = eigvalsh_tridiagonal_br(d, e, leaf=8, deflate_budget=0)
    np.testing.assert_array_equal(np.asarray(r_big.eigenvalues),
                                  np.asarray(r_seq.eigenvalues))


def test_deflation_gauge_observes_ratios():
    """measure(deflation=True) exposes per-level kprime/K; a plain
    window records nothing and costs nothing."""
    d, e = make_family("glued_wilkinson", 256)
    with br_dc.SOLVE_COUNTER.measure(deflation=True) as w:
        eigvalsh_tridiagonal_br(d, e, leaf=16)
    ratios = w.deflation_ratios
    assert ratios, "gauge window recorded no levels"
    assert set(ratios) == set(range(len(ratios)))   # contiguous levels
    assert all(0.0 < r <= 1.0 for r in ratios.values())
    # glued-Wilkinson deflates heavily above the leaves: the top level
    # must keep well under the full secular rank.
    assert ratios[max(ratios)] < 0.9

    with br_dc.SOLVE_COUNTER.measure() as w2:
        eigvalsh_tridiagonal_br(d, e, leaf=16)
    assert w2.deflation_ratios == {}
    assert w2.count == 1
