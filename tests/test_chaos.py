"""Chaos suite: deterministic fault injection against the solve and
serve stacks (marker: ``chaos``).

The properties this file pins, per ISSUE 8:

  * every submitted future RESOLVES under every fault schedule (no hung
    clients, ever);
  * a fault scoped to one request never harms a flushmate -- the others'
    answers stay bit-identical to their fault-free solves;
  * escalations land in ``SolveResult.diagnostics``, the SOLVE_COUNTER
    degradation gauge, and the serve metrics;
  * transient faults consume the retry budget, deterministic faults
    skip it (straight to per-request fallback);
  * with injection disabled the harness is invisible: outputs are
    bit-identical to a build that never imported it.

Determinism: the registry is count-driven (per-site hit counters), so
the same traffic against the same schedule injects the same faults --
each test re-runs exactly.
"""

import numpy as np
import pytest

import jax

from repro.core import (SOLVE_COUNTER, SolveRequest, clear_plan_cache,
                        eigvalsh_tridiagonal, execute_request,
                        plan_cache_stats)
from repro.core import guard as _guard
from repro.runtime import (FaultSpec, InjectedDeterministicError,
                           InjectedTransientError, configure_faults,
                           fault_stats, faults_enabled, reset_faults)
from repro.serve import EigensolverClient

pytestmark = pytest.mark.chaos

DEVICES = jax.device_count()


@pytest.fixture(autouse=True)
def _clean_slate():
    # clear_plan_cache resets the fault registry AND the robustness
    # counters on both sides of every test: no schedule or escalation
    # tally may leak between tests (or into other files).
    clear_plan_cache()
    yield
    clear_plan_cache()
    assert not faults_enabled()


def _problem(n, seed=0):
    rng = np.random.default_rng(seed + n)
    return rng.normal(size=n), rng.normal(size=n - 1)


def _problems(n, count, seed=0):
    return [_problem(n, seed=seed + 17 * i) for i in range(count)]


# ------------------------------------------------- registry determinism


def test_registry_is_count_driven_and_deterministic():
    schedule = [FaultSpec(site="plan.launch", kind="error", times=(1,),
                          error="transient")]
    d, e = _problem(32)

    def run():
        clear_plan_cache()
        configure_faults(schedule)
        outcomes = []
        for _ in range(3):
            try:
                lam = np.asarray(eigvalsh_tridiagonal(d, e))
                outcomes.append(("ok", lam))
            except InjectedTransientError:
                outcomes.append(("fault", None))
        stats = fault_stats()
        reset_faults()
        return outcomes, stats

    first, stats1 = run()
    second, stats2 = run()
    # Hit 1 (the second launch) faults; hits 0 and 2 succeed -- every run.
    assert [o[0] for o in first] == ["ok", "fault", "ok"]
    assert [o[0] for o in second] == ["ok", "fault", "ok"]
    np.testing.assert_array_equal(first[0][1], second[0][1])
    assert stats1["hits"] == stats2["hits"]
    assert stats1["fired"] == stats2["fired"] == {"plan.launch": 1}


def test_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(site="plan.launch", kind="explode")
    with pytest.raises(ValueError):
        FaultSpec(site="plan.launch", error="sometimes")


def test_env_var_schedule(monkeypatch):
    monkeypatch.setenv(
        "REPRO_FAULTS",
        '[{"site": "plan.launch", "kind": "error", "times": [0],'
        ' "error": "deterministic"}]')
    configure_faults()
    d, e = _problem(24)
    with pytest.raises(InjectedDeterministicError):
        eigvalsh_tridiagonal(d, e)
    lam = np.asarray(eigvalsh_tridiagonal(d, e))   # next hit: clean
    reset_faults()
    np.testing.assert_array_equal(lam,
                                  np.asarray(eigvalsh_tridiagonal(d, e)))


# ------------------------------------------- disabled => bit-identical


def test_disabled_harness_is_bit_invisible():
    d, e = _problem(64)
    D = np.stack([d, d * 1.5])
    E = np.stack([e, e * 1.5])
    baseline = np.asarray(eigvalsh_tridiagonal(D, E))
    # Arm a schedule, burn it, reset -- then re-solve: the hooks are in
    # the path both times, the bits must not notice.
    configure_faults([FaultSpec(site="plan.output", kind="nan",
                                times=(0,))])
    eigvalsh_tridiagonal(D, E)
    reset_faults()
    np.testing.assert_array_equal(np.asarray(eigvalsh_tridiagonal(D, E)),
                                  baseline)


def test_disabled_harness_serve_bit_identical_to_sync():
    probs = _problems(48, 4)
    refs = [np.asarray(eigvalsh_tridiagonal(d, e)) for d, e in probs]
    with EigensolverClient(max_wait_us=20000) as client:
        futs = [client.solve_async(d, e) for d, e in probs]
        res = [f.result(timeout=120) for f in futs]
    for r, ref in zip(res, refs):
        np.testing.assert_array_equal(np.asarray(r.eigenvalues), ref)
        assert r.diagnostics is None


# ------------------------------------------------------ sync escalation


def test_output_poison_escalates_and_is_recorded():
    d, e = _problem(48)
    ref = np.asarray(eigvalsh_tridiagonal(d, e))
    gstart = len(SOLVE_COUNTER.degradation_events())
    configure_faults([FaultSpec(site="plan.output", kind="nan", times=(0,),
                                lane=0, width=1)])
    res = execute_request(SolveRequest(d=d, e=e))
    reset_faults()
    lam = np.asarray(res.eigenvalues)
    # Recovered through the ladder: certified-by-construction bisection.
    np.testing.assert_allclose(lam, ref, rtol=0,
                               atol=1e-11 * np.max(np.abs(ref)))
    esc = res.diagnostics["escalations"]
    assert esc == ({"from": "native", "to": "bisect", "lanes": 48},)
    events = SOLVE_COUNTER.degradation_events(gstart)
    assert ("native", "bisect", 48) in events
    assert plan_cache_stats()["degradations"] == 1


def test_poison_with_certify_repairs_and_recertifies():
    d, e = _problem(48)
    configure_faults([FaultSpec(site="plan.output", kind="nan", times=(0,),
                                lane=0, width=1)])
    res = execute_request(SolveRequest(d=d, e=e, certify=True))
    reset_faults()
    diag = res.diagnostics
    assert diag["escalations"]
    assert diag["lanes"] == 48
    ref = np.asarray(eigvalsh_tridiagonal(d, e))
    np.testing.assert_allclose(np.asarray(res.eigenvalues), ref, rtol=0,
                               atol=1e-11 * np.max(np.abs(ref)))


def test_mixed_precision_poison_escalates_to_native():
    d, e = _problem(96)
    ref = np.asarray(eigvalsh_tridiagonal(d, e))
    configure_faults([FaultSpec(site="plan.output", kind="nan", times=(0,),
                                lane=0, width=1)])
    res = execute_request(SolveRequest(d=d, e=e,
                                       knobs={"precision": "mixed"}))
    reset_faults()
    np.testing.assert_allclose(np.asarray(res.eigenvalues), ref, rtol=0,
                               atol=64 * np.finfo(np.float64).eps
                               * np.max(np.abs(ref)))
    frm = [ev["from"] for ev in res.diagnostics["escalations"]]
    assert "mixed" in frm


def test_sync_launch_fault_surfaces_to_caller():
    # The SYNC path has no retry budget: a launch fault is the caller's
    # to handle (the serve path is where retries live).
    d, e = _problem(32)
    configure_faults([FaultSpec(site="plan.launch", kind="error",
                                times=(0,), error="transient")])
    with pytest.raises(InjectedTransientError):
        eigvalsh_tridiagonal(d, e)
    reset_faults()


def test_poison_only_harms_the_poisoned_lane_of_a_batch():
    probs = _problems(40, 3)
    D = np.stack([p[0] for p in probs])
    E = np.stack([p[1] for p in probs])
    ref = np.asarray(eigvalsh_tridiagonal(D, E))
    configure_faults([FaultSpec(site="plan.output", kind="nan", times=(0,),
                                lane=1, width=1)])
    res = execute_request(SolveRequest(d=D, e=E, kind="batch"))
    reset_faults()
    lam = np.asarray(res.eigenvalues)
    # Untouched lanes: bit-identical.  Poisoned lane: recovered.
    np.testing.assert_array_equal(lam[0], ref[0])
    np.testing.assert_array_equal(lam[2], ref[2])
    np.testing.assert_allclose(lam[1], ref[1], rtol=0,
                               atol=1e-11 * np.max(np.abs(ref)))


# ----------------------------------------------------- serve chaos


def test_serve_flushmates_survive_a_poisoned_member():
    probs = _problems(48, 3, seed=5)
    refs = [np.asarray(eigvalsh_tridiagonal(d, e)) for d, e in probs]
    clear_plan_cache()
    configure_faults([FaultSpec(site="plan.output", kind="nan", times=(0,),
                                lane=1, width=1)])
    with EigensolverClient(max_wait_us=50000) as client:
        futs = [client.solve_async(d, e) for d, e in probs]
        res = [f.result(timeout=120) for f in futs]
        snap = client.metrics()
    reset_faults()
    poisoned = [i for i, r in enumerate(res)
                if r.diagnostics and r.diagnostics.get("escalations")]
    assert len(poisoned) == 1      # exactly one member escalated...
    for i, (r, ref) in enumerate(zip(res, refs)):
        lam = np.asarray(r.eigenvalues)
        if i in poisoned:
            np.testing.assert_allclose(lam, ref, rtol=0,
                                       atol=1e-11 * np.max(np.abs(ref)))
        else:                      # ...and the others never noticed
            np.testing.assert_array_equal(lam, ref)
    bucket = snap["buckets"]["solve/N64/float64"]
    assert bucket["degradations"] == 1
    assert bucket["degraded_lanes"] == 48
    assert bucket["fallbacks"] == 0
    assert snap["plan_cache"]["degradations"] >= 1


def test_serve_transient_launch_fault_retries_within_budget():
    probs = _problems(48, 3, seed=9)
    refs = [np.asarray(eigvalsh_tridiagonal(d, e)) for d, e in probs]
    clear_plan_cache()
    configure_faults([FaultSpec(site="serve.launch", kind="error",
                                times=(0,), error="transient")])
    with EigensolverClient(max_wait_us=50000, retries=1,
                           retry_backoff_s=0.01) as client:
        futs = [client.solve_async(d, e) for d, e in probs]
        res = [f.result(timeout=120) for f in futs]
        snap = client.metrics()
    reset_faults()
    for r, ref in zip(res, refs):
        np.testing.assert_array_equal(np.asarray(r.eigenvalues), ref)
    bucket = snap["buckets"]["solve/N64/float64"]
    assert bucket["retries"] == 1      # one relaunch fixed it
    assert bucket["fallbacks"] == 0
    assert bucket["errors"] == 0


def test_serve_deterministic_fault_skips_retry_falls_back():
    probs = _problems(48, 3, seed=13)
    refs = [np.asarray(eigvalsh_tridiagonal(d, e)) for d, e in probs]
    clear_plan_cache()
    configure_faults([FaultSpec(site="serve.launch", kind="error",
                                times=(), error="deterministic")])
    with EigensolverClient(max_wait_us=50000, retries=3,
                           retry_backoff_s=0.01) as client:
        futs = [client.solve_async(d, e) for d, e in probs]
        res = [f.result(timeout=240) for f in futs]
        snap = client.metrics()
    reset_faults()
    for r, ref in zip(res, refs):   # fallback solves each member alone
        np.testing.assert_array_equal(np.asarray(r.eigenvalues), ref)
    bucket = snap["buckets"]["solve/N64/float64"]
    assert bucket["retries"] == 0      # ValueError class: no relaunch
    assert bucket["fallbacks"] >= 1
    assert bucket["errors"] == 0       # every future still resolved OK


def test_serve_persistent_transient_fault_exhausts_budget_then_falls_back():
    probs = _problems(48, 2, seed=21)
    refs = [np.asarray(eigvalsh_tridiagonal(d, e)) for d, e in probs]
    clear_plan_cache()
    configure_faults([FaultSpec(site="serve.launch", kind="error",
                                times=(), error="transient")])
    with EigensolverClient(max_wait_us=50000, retries=2,
                           retry_backoff_s=0.01) as client:
        futs = [client.solve_async(d, e) for d, e in probs]
        res = [f.result(timeout=240) for f in futs]
        snap = client.metrics()
    reset_faults()
    for r, ref in zip(res, refs):
        np.testing.assert_array_equal(np.asarray(r.eigenvalues), ref)
    bucket = snap["buckets"]["solve/N64/float64"]
    assert bucket["retries"] == 2      # full budget consumed
    assert bucket["fallbacks"] >= 1    # then isolated per-request
    assert bucket["errors"] == 0


def test_serve_stage_delay_trips_the_straggler_monitor():
    probs = _problems(32, 12, seed=31)
    clear_plan_cache()
    configure_faults([FaultSpec(site="serve.stage", kind="delay",
                                times=(10,), delay_s=1.0)])
    with EigensolverClient(max_wait_us=100, straggler_window=16,
                           straggler_threshold=3.0) as client:
        for d, e in probs:          # closed loop: one flush per request
            client.solve(d, e)
        mon = next((m for label, m in client.engine._stragglers.items()
                    if label.startswith("solve/N32/")), None)
    reset_faults()
    stats = fault_stats()
    assert mon is not None and len(mon.events) >= 1
    ev = mon.events[0]
    assert ev["duration"] >= 1.0


def test_deadline_expires_at_flush_assembly():
    d, e = _problem(48)
    with EigensolverClient(max_wait_us=50000) as client:
        fut = client.solve_async(d, e, deadline_ms=1e-3)
        with pytest.raises(_guard.DeadlineExceeded):
            fut.result(timeout=60)
        snap = client.metrics()
    bucket = snap["buckets"]["solve/N64/float64"]
    assert bucket["deadline_expired"] == 1
    assert snap["plan_cache"]["deadline_expired"] >= 1


def test_deadline_expires_post_launch_flushmates_unharmed():
    probs = _problems(48, 2, seed=41)
    ref0 = np.asarray(eigvalsh_tridiagonal(*probs[0]))
    clear_plan_cache()
    # Staging stalls 0.4s: the 50ms-deadline member expires IN FLIGHT,
    # the unbounded member still gets its (bit-identical) answer.
    configure_faults([FaultSpec(site="serve.stage", kind="delay",
                                times=(0,), delay_s=0.4)])
    with EigensolverClient(max_wait_us=50000) as client:
        f0 = client.solve_async(*probs[0])
        f1 = client.solve_async(*probs[1], deadline_ms=50.0)
        res0 = f0.result(timeout=120)
        with pytest.raises(_guard.DeadlineExceeded):
            f1.result(timeout=120)
        snap = client.metrics()
    reset_faults()
    np.testing.assert_array_equal(np.asarray(res0.eigenvalues), ref0)
    assert snap["buckets"]["solve/N64/float64"]["deadline_expired"] == 1


def test_every_future_resolves_under_a_hostile_schedule():
    # The umbrella invariant: a mixed storm of faults across sites, a
    # burst of concurrent requests -- every single future must resolve
    # (result or error), none may hang.
    probs = _problems(48, 8, seed=77)
    clear_plan_cache()
    configure_faults([
        FaultSpec(site="serve.launch", kind="error", times=(0,),
                  error="transient"),
        FaultSpec(site="plan.output", kind="nan", times=(1, 3), lane=0,
                  width=2),
        FaultSpec(site="serve.stage", kind="delay", times=(2,),
                  delay_s=0.05),
    ])
    with EigensolverClient(max_wait_us=200, retries=1,
                           retry_backoff_s=0.01) as client:
        futs = [client.solve_async(d, e) for d, e in probs]
        done = [f.result(timeout=240) for f in futs]
    reset_faults()
    assert len(done) == len(probs)
    for r, (d, e) in zip(done, probs):
        ref = np.asarray(eigvalsh_tridiagonal(d, e))
        np.testing.assert_allclose(np.asarray(r.eigenvalues), ref, rtol=0,
                                   atol=1e-11 * np.max(np.abs(ref)))


@pytest.mark.skipif(DEVICES < 4, reason="needs >= 4 (forced host) "
                    "devices; run under XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4")
def test_dist_halo_corruption_is_caught_by_certification():
    rng = np.random.default_rng(3)
    n = 4096
    d = rng.normal(size=n)
    e = rng.normal(size=n - 1)
    ref = np.asarray(eigvalsh_tridiagonal(d, e, mesh=None))
    clear_plan_cache()
    configure_faults([FaultSpec(site="dist.halo", kind="corrupt",
                                times=(0,), lane=0, index=-1,
                                value=float("nan"))])
    res = execute_request(SolveRequest(d=d, e=e, certify=True,
                                       knobs={"mesh": 4}))
    reset_faults()
    # The corrupted halo value deflates into finite-but-WRONG lanes (NaN
    # comparisons read as deflated), which no finite screen can see --
    # only the certification sweep against the original (d, e) catches
    # it, and the ladder's bisection rung repairs the flagged lanes.
    np.testing.assert_allclose(np.asarray(res.eigenvalues), ref, rtol=0,
                               atol=1e-10 * np.max(np.abs(ref)))
    assert res.diagnostics["escalations"]
