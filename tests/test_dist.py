"""Distributed-conquer tests: sharded solves, solver-mesh routing, and
the hardened production-mesh factorization.

The single-device half (routing validation, factorization rules, halo
quantizer) runs everywhere, tier-1 included.  The multi-device matrix --
P in {2, 4} vs single-device equality, sharded serve flushes, no-retrace
-- activates when at least 4 devices are visible; CI runs this file
under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.

The load-bearing property is *bit-identity*: the sharded path reorders
no floating-point reduction of the single-device path (scatter-add
grouping in the divide step, per-root secular windows, replicated merge
head and post-pass), so every family must match exactly -- the
8 eps ||T|| acceptance tolerance is asserted too, but as a floor, not
the target.
"""

import numpy as np
import pytest

import jax

from repro.core import (FAMILIES, clear_plan_cache, eigvalsh_tridiagonal,
                        eigvalsh_tridiagonal_batch, make_family,
                        plan_cache_stats)
from repro.core import plan as _plan
from repro.dist import compression as _comp
from repro.launch.mesh import make_solver_mesh, mesh_shape_for

EPS = np.finfo(np.float64).eps
DEVICES = jax.device_count()

multi = pytest.mark.skipif(
    DEVICES < 4, reason="needs >= 4 (forced host) devices; run under "
    "XLA_FLAGS=--xla_force_host_platform_device_count=4")


def _norm(d, e):
    return np.max(np.abs(d)) + (2.0 * np.max(np.abs(e)) if len(e) else 0.0)


def _problem(n, seed=0):
    rng = np.random.default_rng(seed + n)
    return rng.normal(size=n), rng.normal(size=n - 1)


# ------------------------------------------------- mesh factorization


@pytest.mark.parametrize("devices,kw,want", [
    (1, {}, (1, 1)),
    (48, {}, (3, 16)),                      # classic: 16-way TP
    (6, {}, (1, 6)),                        # non-pow2: largest divisor
    (12, {"model_parallel": 8}, (2, 6)),
    (9, {"model_parallel": 4}, (3, 3)),     # odd: old //=2 loop missed 3
    (7, {"model_parallel": 4}, (7, 1)),     # prime: data-parallel only
    (8, {"model_parallel": 2, "pods": 2}, (2, 2, 2)),
    (8, {"model_parallel": 2, "pods": 3}, (4, 2)),   # pod doesn't divide
])
def test_mesh_shape_for_factorizations(devices, kw, want):
    shape, axes = mesh_shape_for(devices, **kw)
    assert shape == want
    assert len(axes) == len(shape)
    assert int(np.prod(shape)) == devices


@pytest.mark.parametrize("devices", range(1, 41))
def test_mesh_shape_for_always_exact(devices):
    """Every count factorizes exactly -- no dropped or invented devices."""
    for mp in (1, 3, 16):
        shape, _ = mesh_shape_for(devices, model_parallel=mp)
        assert int(np.prod(shape)) == devices
        assert all(s >= 1 for s in shape)


@pytest.mark.parametrize("devices,kw", [
    (0, {}), (-4, {}),
    (8, {"model_parallel": 0}), (8, {"pods": 0}),
])
def test_mesh_shape_for_rejects_degenerate(devices, kw):
    with pytest.raises(ValueError):
        mesh_shape_for(devices, **kw)


def test_make_solver_mesh_validates():
    with pytest.raises(ValueError, match="power of two"):
        make_solver_mesh(3)
    with pytest.raises(ValueError):
        make_solver_mesh(0)
    too_many = 1 << DEVICES.bit_length()    # smallest pow2 > DEVICES
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        make_solver_mesh(too_many)


@pytest.mark.skipif(DEVICES < 2, reason="needs >= 2 devices")
def test_make_solver_mesh_shape():
    mesh = make_solver_mesh(2)
    assert dict(mesh.shape) == {"shard": 2}


# ---------------------------------------------------------- routing


def test_auto_routing_below_floor_is_single_device():
    assert _plan.resolve_solve_route(1024).shards == 1
    assert _plan.resolve_solve_route(1024, mesh="auto").shards == 1
    assert _plan.resolve_solve_route(1024, mesh=None).shards == 1
    assert _plan.resolve_solve_route(1024, mesh=1).shards == 1


def test_auto_routing_huge_n_uses_devices():
    want = 1 << (DEVICES.bit_length() - 1)  # largest pow2 <= devices
    assert _plan.resolve_solve_route(_plan.DIST_AUTO_MIN_N).shards == want


def test_explicit_mesh_validates_hard():
    with pytest.raises(ValueError, match="power of two"):
        _plan.resolve_solve_route(16384, mesh=3)
    too_many = 1 << DEVICES.bit_length()
    with pytest.raises(ValueError, match="devices"):
        _plan.resolve_solve_route(16384, mesh=too_many)
    with pytest.raises(ValueError, match="mesh"):
        _plan.resolve_solve_route(16384, mesh="typo")


@multi
def test_explicit_mesh_needs_enough_leaves():
    # N=64 with leaf=32 has two leaves: four shards cannot each own one.
    with pytest.raises(ValueError, match="leaves"):
        _plan.resolve_solve_route(64, leaf=32, mesh=4)


def test_compress_halo_normalized_off_single_device():
    route = _plan.resolve_solve_route(1024, mesh=1, compress_halo=True)
    assert route.shards == 1 and route.compress_halo is False
    # ... so it cannot split the single-device cache bucket.
    assert route == _plan.resolve_solve_route(1024, mesh=1)


def test_run_py_mesh_flag_validates_before_jax():
    from benchmarks import run as bench_run
    with pytest.raises(SystemExit):            # non-pow2 rejected by argparse
        bench_run.main(["--mesh", "3"])
    with pytest.raises(SystemExit):            # conflicting host-devices
        bench_run.main(["--mesh", "4", "--host-devices", "2"])
    # jax is already initialized in this process: a clear error, never a
    # silent single-device fallback.
    with pytest.raises(RuntimeError, match="jax"):
        bench_run.main(["--mesh", "4"])


# ----------------------------------------------------- halo compression


def test_quantize_lanes_roundtrip_bound():
    rng = np.random.default_rng(7)
    x = rng.normal(size=(3, 2, 64)) * 10.0
    q, scale = _comp.quantize_lanes(x)
    assert np.asarray(q).dtype == np.int8
    deq = np.asarray(_comp.dequantize_lanes(q, scale, x.dtype))
    # Rounding to the int8 grid: error at most half a quantization step.
    assert np.max(np.abs(x - deq) / np.asarray(scale)) <= 0.5 + 1e-6


# ------------------------------------------------- sharded vs single


@multi
@pytest.mark.parametrize("P", [2, 4])
@pytest.mark.parametrize("family", FAMILIES)
def test_sharded_matches_single_device(family, P):
    d, e = make_family(family, 257)
    lam1 = np.asarray(eigvalsh_tridiagonal(d, e, leaf=8, mesh=1))
    lamP = np.asarray(eigvalsh_tridiagonal(d, e, leaf=8, mesh=P))
    # Acceptance bar ...
    np.testing.assert_allclose(lamP, lam1, rtol=0,
                               atol=8.0 * EPS * max(1.0, _norm(d, e)))
    # ... and the design property: nothing in the sharded path reorders
    # a floating-point reduction, so the match is exact.
    assert np.array_equal(lamP, lam1)


@multi
def test_sharded_boundary_rows_padded_batch():
    rng = np.random.default_rng(1)
    n = 700                                   # pads: track-slot plumbing
    d = rng.normal(size=(3, n))
    e = rng.normal(size=(3, n - 1))
    r1 = eigvalsh_tridiagonal_batch(d, e, return_boundary=True, mesh=1)
    r4 = eigvalsh_tridiagonal_batch(d, e, return_boundary=True, mesh=4)
    assert np.array_equal(np.asarray(r1.eigenvalues),
                          np.asarray(r4.eigenvalues))
    assert np.array_equal(np.asarray(r1.blo), np.asarray(r4.blo))
    assert np.array_equal(np.asarray(r1.bhi), np.asarray(r4.bhi))


@multi
def test_compress_halo_off_is_bit_identical_and_on_is_lossy():
    d, e = _problem(700)
    lam1 = np.asarray(eigvalsh_tridiagonal(d, e, mesh=1))
    default = np.asarray(eigvalsh_tridiagonal(d, e, mesh=4))
    explicit_off = np.asarray(
        eigvalsh_tridiagonal(d, e, mesh=4, compress_halo=False))
    assert np.array_equal(default, lam1)      # the pinned default path
    assert np.array_equal(explicit_off, lam1)
    lossy = np.asarray(
        eigvalsh_tridiagonal(d, e, mesh=4, compress_halo=True))
    # int8 rows perturb the coupling vectors: small but visible error.
    assert np.max(np.abs(lossy - lam1)) <= 0.05 * _norm(d, e)


# ------------------------------------------------- cache and serving


@multi
def test_no_retrace_on_repeated_same_mesh_traffic():
    d, e = _problem(300, seed=5)
    eigvalsh_tridiagonal(d, e, mesh=4)        # warm the (N, P) bucket
    before = _plan.EXECUTOR_TRACES.count
    for shift in (0.5, -1.0, 2.0):
        eigvalsh_tridiagonal(d + shift, e, mesh=4)
    assert _plan.EXECUTOR_TRACES.count == before


@multi
def test_mesh_buckets_in_plan_cache_stats():
    clear_plan_cache()
    p1 = _plan.make_plan(300, mesh=1)
    p2 = _plan.make_plan(300, mesh=2)
    p4 = _plan.make_plan(300, mesh=4)
    assert (p1.devices, p2.devices, p4.devices) == (1, 2, 4)
    assert plan_cache_stats()["mesh_buckets"] == {1: 1, 2: 1, 4: 1}


@multi
def test_serve_flush_lands_on_sharded_route():
    from repro.serve import EigensolverClient
    probs = [_problem(n, seed=3) for n in (257, 300, 420)]
    with EigensolverClient(max_batch=8, max_wait_us=100_000) as client:
        futs = [client.solve_async(d, e, mesh=2) for d, e in probs]
        results = [f.result(timeout=300) for f in futs]
    for (d, e), res in zip(probs, results):
        want = np.asarray(eigvalsh_tridiagonal(d, e, mesh=2))
        assert np.array_equal(np.asarray(res.eigenvalues), want)
