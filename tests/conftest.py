import jax

# The eigensolver library is validated at float64 (its accuracy claims are
# 1e-12-relative against LAPACK references); model smoke tests pin their
# own float32 dtypes explicitly so x64 does not affect them.
jax.config.update("jax_enable_x64", True)
