"""Per-architecture smoke + consistency tests (reduced configs, CPU).

The decode-consistency test is the strongest one: teacher-forced
forward(tokens) logits must match the prefill+decode_step chain position by
position, which exercises every cache path (GQA KV, MLA latent, Mamba2
conv+ssm state, zamba2 hybrid, whisper self+cross).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_smoke_config, SHAPES, shape_applicable
from repro.models import (decode_step, forward, init_cache, init_model,
                          loss_fn, param_count, prefill)

RNG = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            RNG, (B, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = init_model(RNG, cfg)
    batch = _batch(cfg)
    logits, aux = forward(params, cfg, batch["tokens"],
                          encoder_input=batch.get("frames"))
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, metrics = loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_teacher_forcing(arch):
    import dataclasses
    cfg = get_smoke_config(arch)
    if cfg.moe_num_experts:
        # Capacity drops differ between full-sequence and per-token routing
        # (inherent to capacity-based MoE); disable drops for equivalence.
        cfg = dataclasses.replace(cfg, moe_capacity_factor=16.0)
    params = init_model(RNG, cfg)
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    tokens = batch["tokens"]
    full_logits, _ = forward(params, cfg, tokens,
                             encoder_input=batch.get("frames"))

    # prefill on the first half, decode the second half token by token
    half = S // 2
    lg, caches = prefill(params, cfg, tokens[:, :half], max_seq=S,
                         encoder_input=batch.get("frames"))
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(full_logits[:, :half], np.float32),
                               atol=2e-3, rtol=2e-3)
    for i in range(half, S):
        lg, caches = decode_step(params, cfg, tokens[:, i:i + 1], caches,
                                 jnp.asarray(i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(full_logits[:, i], np.float32),
            atol=2e-3, rtol=2e-3, err_msg=f"{arch} pos {i}")


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-130m"])
def test_tiny_training_reduces_loss(arch):
    from repro.optim.optimizers import adamw
    cfg = get_smoke_config(arch)
    params = init_model(RNG, cfg)
    # SSD recurrences want a gentler LR at f32 than attention stacks.
    opt = adamw(lr=1e-3 if arch == "mamba2-130m" else 3e-3)
    state = opt.init(params)

    def step(params, state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
        params, state = opt.apply(params, grads, state)
        return params, state, loss

    step = jax.jit(step)
    batch = _batch(cfg, B=4, S=32)
    losses = []
    for _ in range(30):
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_full_configs_match_assignment():
    """The full (non-smoke) configs carry the exact assigned dimensions."""
    spec = {
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "mamba2-130m": (24, 768, 24, 24, 0, 50280),
    }
    for arch, (L, d, H, G, f, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, H, G, f, V), arch
    # family features
    assert get_config("llama4-maverick-400b-a17b").moe_num_experts == 128
    assert get_config("llama4-maverick-400b-a17b").moe_top_k == 1
    assert get_config("dbrx-132b").moe_num_experts == 16
    assert get_config("dbrx-132b").moe_top_k == 4
    assert get_config("minicpm3-4b").attention == "mla"
    assert get_config("qwen3-0.6b").qk_norm
    assert get_config("qwen2-1.5b").qkv_bias
    assert get_config("qwen2-vl-72b").rope_style == "mrope"
    assert get_config("zamba2-7b").ssm_state_dim == 64
    assert get_config("mamba2-130m").ssm_state_dim == 128
    assert get_config("mamba2-130m").attention == "none"
    assert get_config("whisper-small").encoder_layers == 12


def test_long_context_skip_rule():
    long = SHAPES["long_500k"]
    runnable = [a for a in ARCHS if shape_applicable(get_config(a), long)]
    assert sorted(runnable) == ["mamba2-130m", "zamba2-7b"]


def test_param_count_sanity():
    """Analytic count ~ matches actual leaf sizes on smoke configs."""
    for arch in ("qwen3-0.6b", "mamba2-130m"):
        cfg = get_smoke_config(arch)
        params = init_model(RNG, cfg)
        actual = param_count(params)
        analytic = cfg.num_params()
        assert 0.5 < actual / analytic < 2.0, (arch, actual, analytic)


def test_pipeline_forward_matches_plain():
    """GPipe stage-roll pipeline == plain forward (bubbles never collected)."""
    from repro.launch.pipeline import pipeline_forward
    cfg = get_smoke_config("qwen3-0.6b")
    params = init_model(RNG, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(5), (4, 16), 0,
                                cfg.vocab_size)
    ref, _ = forward(params, cfg, tokens)
    got, _ = pipeline_forward(params, cfg, tokens, n_stages=2, n_micro=2,
                              remat=False)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-3, rtol=2e-3)


def test_flash_mla_matches_dense():
    """Chunked latent-space (MLA) attention == dense path."""
    from repro.models import layers as nn
    cfg = get_smoke_config("minicpm3-4b")
    p = nn.init_mla(jax.random.PRNGKey(2), cfg)
    B, S = 2, 64
    x = jax.random.normal(RNG, (B, S, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    dense = nn.mla_forward(p, cfg, x, pos, causal=True)
    old_thr, old_chunk = nn.FLASH_THRESHOLD, nn.FLASH_KV_CHUNK
    try:
        nn.FLASH_THRESHOLD, nn.FLASH_KV_CHUNK = 1, 16
        flash = nn.mla_forward(p, cfg, x, pos, causal=True)
    finally:
        nn.FLASH_THRESHOLD, nn.FLASH_KV_CHUNK = old_thr, old_chunk
    np.testing.assert_allclose(np.asarray(flash, np.float32),
                               np.asarray(dense, np.float32),
                               atol=2e-3, rtol=2e-3)


def test_flash_attention_matches_dense():
    """The online-softmax chunked path == dense softmax attention."""
    from repro.models import layers as nn
    cfg = get_smoke_config("qwen3-0.6b")
    p = nn.init_attention(jax.random.PRNGKey(1), cfg)
    B, S = 2, 64
    x = jax.random.normal(RNG, (B, S, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    q, k, v = nn._project_qkv(p, cfg, x, pos)
    it = jnp.arange(S)
    mask = (it[None, :, None] >= it[None, None, :])[:, None, None, :, :]
    dense = nn._sdpa(q, k, v, mask, cfg)
    old_chunk = nn.FLASH_KV_CHUNK
    try:
        nn.FLASH_KV_CHUNK = 16
        flash = nn._sdpa_chunked(q, k, v, cfg, causal=True)
    finally:
        nn.FLASH_KV_CHUNK = old_chunk
    np.testing.assert_allclose(np.asarray(flash, np.float32),
                               np.asarray(dense, np.float32),
                               atol=2e-3, rtol=2e-3)
