"""Pallas kernel validation: interpret-mode vs pure-jnp oracles vs XLA path.

Sweeps shapes (incl. non-divisible-by-block), dtypes, deflation patterns
and block sizes, asserting allclose against ref.py (which deliberately
materializes the dense K x K intermediates the kernels must avoid).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import secular as sec
from repro.kernels import ref
from repro.kernels.secular_roots import secular_solve_pallas
from repro.kernels.boundary_update import boundary_rows_update_pallas
from repro.kernels.fused_update import secular_postpass_pallas
from repro.kernels.resident_merge import (resident_merge_pallas,
                                          resident_merge_pallas_batch)
from repro.kernels.zhat import zhat_reconstruct_pallas


def _problem(K, kprime, seed=0, dtype=np.float64):
    rng = np.random.default_rng(seed)
    d = np.sort(rng.standard_normal(K))
    d[kprime:] += 10.0                     # deflated values parked high
    z = rng.standard_normal(K)
    z[kprime:] = 0.0
    z /= np.linalg.norm(z)
    return (jnp.asarray(d, dtype), jnp.asarray(z, dtype), 0.7)


SHAPES = [(8, 8), (32, 17), (64, 64), (130, 101), (256, 1), (257, 256)]


@pytest.mark.parametrize("K,kprime", SHAPES)
@pytest.mark.parametrize("dtype", [np.float64, np.float32])
def test_secular_kernel_vs_oracle(K, kprime, dtype):
    d, z, rho = _problem(K, kprime, dtype=dtype)
    o_p, t_p = secular_solve_pallas(d, z * z, jnp.asarray(rho, d.dtype),
                                    jnp.asarray(kprime), niter=24,
                                    interpret=True)
    lam_p = np.sort(np.asarray(d)[np.asarray(o_p)[:kprime]]
                    + np.asarray(t_p)[:kprime])
    o_r, t_r = ref.secular_roots_ref(d, z * z, rho, kprime)
    lam_r = np.sort(np.asarray(d)[np.asarray(o_r)[:kprime]]
                    + np.asarray(t_r)[:kprime])
    tol = 1e-10 if dtype == np.float64 else 2e-3
    np.testing.assert_allclose(lam_p, lam_r, atol=tol * 10, rtol=tol)


@pytest.mark.parametrize("K,kprime", SHAPES)
def test_secular_kernel_vs_xla_path(K, kprime):
    """The Pallas kernel and the chunked XLA fallback implement the same
    algorithm -- they must agree to machine precision."""
    d, z, rho = _problem(K, kprime, seed=1)
    o_x, t_x = sec.secular_solve(d, z * z, rho, kprime, niter=16)
    o_p, t_p = secular_solve_pallas(d, z * z, jnp.asarray(rho, d.dtype),
                                    jnp.asarray(kprime), niter=16,
                                    interpret=True)
    lam_x = np.asarray(d)[np.asarray(o_x)] + np.asarray(t_x)
    lam_p = np.asarray(d)[np.asarray(o_p)] + np.asarray(t_p)
    np.testing.assert_allclose(lam_x, lam_p, atol=1e-13, rtol=0)


@pytest.mark.parametrize("root_block", [32, 128])
@pytest.mark.parametrize("pole_tile", [64, 1024])
def test_secular_kernel_tiling_invariance(root_block, pole_tile):
    """BlockSpec tiling is a perf knob, never a semantics knob."""
    d, z, rho = _problem(200, 163, seed=2)
    o_p, t_p = secular_solve_pallas(d, z * z, jnp.asarray(rho, d.dtype),
                                    jnp.asarray(163), niter=16,
                                    root_block=root_block,
                                    pole_tile=pole_tile, interpret=True)
    o_0, t_0 = secular_solve_pallas(d, z * z, jnp.asarray(rho, d.dtype),
                                    jnp.asarray(163), niter=16,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(t_p), np.asarray(t_0),
                               atol=1e-14, rtol=0)


@pytest.mark.parametrize("K,kprime", SHAPES)
@pytest.mark.parametrize("r", [1, 2, 4])
def test_boundary_update_kernel(K, kprime, r):
    rng = np.random.default_rng(3)
    d, z, rho = _problem(K, kprime, seed=3)
    origin, tau = sec.secular_solve(d, z * z, rho, kprime, niter=16)
    R = jnp.asarray(rng.standard_normal((r, K)))
    got = boundary_rows_update_pallas(R, d, z, origin, tau,
                                      jnp.asarray(kprime), interpret=True)
    want = ref.boundary_rows_update_ref(R, d, z, origin, tau, kprime)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-12, rtol=1e-12)


@pytest.mark.parametrize("K,kprime", SHAPES)
def test_zhat_kernel(K, kprime):
    d, z, rho = _problem(K, kprime, seed=4)
    origin, tau = sec.secular_solve(d, z * z, rho, kprime, niter=16)
    got = zhat_reconstruct_pallas(d, z, origin, tau, jnp.asarray(kprime),
                                  jnp.asarray(rho, d.dtype), interpret=True)
    want = ref.zhat_reconstruct_ref(d, z, origin, tau, kprime, rho)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-10, rtol=1e-8)


@pytest.mark.parametrize("K,kprime", SHAPES)
@pytest.mark.parametrize("r", [1, 2, 3])
def test_fused_postpass_kernel_vs_oracle(K, kprime, r):
    """The fused kernel's single delta sweep == dense zhat + dense row
    update (the two intermediates it exists to avoid materializing)."""
    rng = np.random.default_rng(6)
    d, z, rho = _problem(K, kprime, seed=6)
    origin, tau = sec.secular_solve(d, z * z, rho, kprime, niter=16)
    R = jnp.asarray(rng.standard_normal((r, K)))
    zh_p, rows_p = secular_postpass_pallas(
        R, d, z, origin, tau, jnp.asarray(kprime),
        jnp.asarray(rho, d.dtype), interpret=True)
    zh_o, rows_o = ref.secular_postpass_ref(R, d, z, origin, tau, kprime, rho)
    np.testing.assert_allclose(np.asarray(zh_p), np.asarray(zh_o),
                               atol=1e-10, rtol=1e-8)
    np.testing.assert_allclose(np.asarray(rows_p), np.asarray(rows_o),
                               atol=1e-10, rtol=1e-8)


@pytest.mark.parametrize("pole_block", [32, 128])
@pytest.mark.parametrize("root_tile", [64, 1024])
def test_fused_postpass_kernel_tiling_invariance(pole_block, root_tile):
    """BlockSpec tiling is a perf knob, never a semantics knob."""
    d, z, rho = _problem(200, 163, seed=7)
    origin, tau = sec.secular_solve(d, z * z, rho, 163, niter=16)
    R = jnp.asarray(np.random.default_rng(7).standard_normal((2, 200)))
    args = (R, d, z, origin, tau, jnp.asarray(163), jnp.asarray(rho, d.dtype))
    zh_t, rows_t = secular_postpass_pallas(*args, pole_block=pole_block,
                                           root_tile=root_tile,
                                           interpret=True)
    zh_0, rows_0 = secular_postpass_pallas(*args, interpret=True)
    np.testing.assert_allclose(np.asarray(zh_t), np.asarray(zh_0),
                               atol=1e-13, rtol=0)
    np.testing.assert_allclose(np.asarray(rows_t), np.asarray(rows_0),
                               atol=1e-13, rtol=0)


@pytest.mark.parametrize("K,kprime", [(64, 64), (130, 101)])
def test_fused_postpass_kernel_vs_xla_fused(K, kprime):
    """Pallas fused kernel vs the XLA fused path (same algorithm, same
    single-sweep structure) -- agreement to near machine precision."""
    d, z, rho = _problem(K, kprime, seed=8)
    origin, tau = sec.secular_solve(d, z * z, rho, kprime, niter=16)
    R = jnp.asarray(np.random.default_rng(8).standard_normal((2, K)))
    zh_p, rows_p = secular_postpass_pallas(
        R, d, z, origin, tau, jnp.asarray(kprime),
        jnp.asarray(rho, d.dtype), interpret=True)
    zh_x, rows_x = sec.secular_postpass(R, d, z, origin, tau, kprime, rho)
    np.testing.assert_allclose(np.asarray(zh_p), np.asarray(zh_x),
                               atol=1e-12, rtol=1e-10)
    np.testing.assert_allclose(np.asarray(rows_p), np.asarray(rows_x),
                               atol=1e-12, rtol=1e-10)


@pytest.mark.parametrize("K,kprime", [(32, 17), (64, 64), (130, 101)])
def test_resident_merge_kernel_vs_oracle(K, kprime):
    """The single-launch resident kernel == bisection root solve followed
    by the dense post-pass (every intermediate it keeps on-chip)."""
    rng = np.random.default_rng(9)
    d, z, rho = _problem(K, kprime, seed=9)
    R = jnp.asarray(rng.standard_normal((2, K)))
    o_p, t_p, zh_p, rows_p = resident_merge_pallas(
        d, z, R, jnp.asarray(rho, d.dtype), jnp.asarray(kprime),
        niter=24, interpret=True)
    o_r, t_r, zh_r, rows_r = ref.resident_merge_ref(d, z, R, rho, kprime)
    lam_p = np.sort(np.asarray(d)[np.asarray(o_p)[:kprime]]
                    + np.asarray(t_p)[:kprime])
    lam_r = np.sort(np.asarray(d)[np.asarray(o_r)[:kprime]]
                    + np.asarray(t_r)[:kprime])
    np.testing.assert_allclose(lam_p, lam_r, atol=1e-9, rtol=1e-10)
    np.testing.assert_allclose(np.asarray(zh_p), np.asarray(zh_r),
                               atol=1e-8, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(rows_p), np.asarray(rows_r),
                               atol=1e-8, rtol=1e-6)


@pytest.mark.parametrize("K,kprime", [(32, 17), (64, 64), (130, 101)])
def test_resident_merge_kernel_vs_xla_resident(K, kprime):
    """Pallas resident kernel vs the fused dense XLA composition (same
    algorithm end to end) -- agreement to near machine precision."""
    rng = np.random.default_rng(10)
    d, z, rho = _problem(K, kprime, seed=10)
    R = jnp.asarray(rng.standard_normal((3, K)))
    o_p, t_p, zh_p, rows_p = resident_merge_pallas(
        d, z, R, jnp.asarray(rho, d.dtype), jnp.asarray(kprime),
        interpret=True)
    o_x, t_x, zh_x, rows_x = sec.secular_merge_resident(d, z, R, rho, kprime)
    lam_p = np.asarray(d)[np.asarray(o_p)] + np.asarray(t_p)
    lam_x = np.asarray(d)[np.asarray(o_x)] + np.asarray(t_x)
    np.testing.assert_allclose(lam_p, lam_x, atol=1e-13, rtol=0)
    np.testing.assert_allclose(np.asarray(zh_p), np.asarray(zh_x),
                               atol=1e-12, rtol=1e-10)
    np.testing.assert_allclose(np.asarray(rows_p), np.asarray(rows_x),
                               atol=1e-12, rtol=1e-10)


def test_resident_merge_xla_matches_two_launch():
    """The XLA resident composition is EXACTLY the dense two-launch
    pipeline (same functions, one traced region): dispatch collapse is a
    launch-count knob, never a semantics knob."""
    d, z, rho = _problem(64, 50, seed=11)
    R = jnp.asarray(np.random.default_rng(11).standard_normal((2, 64)))
    o1, t1, zh1, rows1 = sec.secular_merge_resident(d, z, R, rho, 50)
    o2, t2 = sec.secular_solve(d, z * z, rho, 50, dense=True)
    zh2, rows2 = sec.secular_postpass(R, d, z, o2, t2, 50, rho, dense=True)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(zh1), np.asarray(zh2))
    np.testing.assert_array_equal(np.asarray(rows1), np.asarray(rows2))


def test_resident_merge_batch_kernel():
    """Batched resident kernel (problems on the grid axis) vs a loop of
    single-problem kernel calls and vs the batched oracle."""
    B, K = 3, 48
    rng = np.random.default_rng(12)
    ds, zs, kps = [], [], []
    for b in range(B):
        kp = (8, 48, 31)[b]
        d, z, _ = _problem(K, kp, seed=20 + b)
        ds.append(d); zs.append(z); kps.append(kp)
    d = jnp.stack(ds); z = jnp.stack(zs)
    kprime = jnp.asarray(kps, jnp.int32)
    rho = jnp.asarray([0.7, 1.3, 0.2], d.dtype)
    R = jnp.asarray(rng.standard_normal((B, 2, K)))

    o_b, t_b, zh_b, rows_b = resident_merge_pallas_batch(
        d, z, R, rho, kprime, interpret=True)
    for b in range(B):
        o_s, t_s, zh_s, rows_s = resident_merge_pallas(
            d[b], z[b], R[b], rho[b], kprime[b], interpret=True)
        np.testing.assert_array_equal(np.asarray(o_b[b]), np.asarray(o_s))
        np.testing.assert_array_equal(np.asarray(t_b[b]), np.asarray(t_s))
        np.testing.assert_array_equal(np.asarray(zh_b[b]), np.asarray(zh_s))
        np.testing.assert_array_equal(np.asarray(rows_b[b]),
                                      np.asarray(rows_s))
    o_r, t_r, zh_r, rows_r = ref.resident_merge_batch_ref(
        d, z, R, np.asarray(rho), np.asarray(kprime))
    for b in range(B):
        kp = kps[b]
        lam_b = np.sort(np.asarray(d[b])[np.asarray(o_b[b])[:kp]]
                        + np.asarray(t_b[b])[:kp])
        lam_r = np.sort(np.asarray(d[b])[np.asarray(o_r[b])[:kp]]
                        + np.asarray(t_r[b])[:kp])
        np.testing.assert_allclose(lam_b, lam_r, atol=1e-9, rtol=1e-9)
    np.testing.assert_allclose(np.asarray(rows_b), np.asarray(rows_r),
                               atol=1e-8, rtol=1e-6)


def test_solver_resident_threshold_is_dispatch_knob_only():
    """Full solver with every level under the residency threshold vs the
    streamed two-launch pipeline: identical spectra and boundary rows."""
    from repro.core import eigvalsh_tridiagonal_br, make_family
    d, e = make_family("normal", 200)
    r_res = eigvalsh_tridiagonal_br(d, e, leaf=8, return_boundary=True,
                                    resident_threshold=1 << 20,
                                    stream_threshold=1 << 20)
    r_two = eigvalsh_tridiagonal_br(d, e, leaf=8, return_boundary=True,
                                    resident_threshold=0,
                                    stream_threshold=1 << 20)
    np.testing.assert_allclose(np.asarray(r_res.eigenvalues),
                               np.asarray(r_two.eigenvalues),
                               rtol=0, atol=1e-12)
    np.testing.assert_allclose(np.asarray(r_res.bhi), np.asarray(r_two.bhi),
                               rtol=0, atol=1e-10)


# ---------------------------------------------------------------------------
# Sturm-count kernel (partial-spectrum front end)
# ---------------------------------------------------------------------------

@pytest.mark.partial
@pytest.mark.parametrize("B,n,S", [(1, 8, 4), (4, 64, 130), (3, 1, 5),
                                   (2, 257, 1), (8, 33, 32)])
@pytest.mark.parametrize("dtype", [np.float64, np.float32])
def test_sturm_kernel_vs_oracle(B, n, S, dtype):
    """Counts are integers: the Pallas kernel must match the scalar
    Python-loop oracle EXACTLY (and the XLA scan too) on every lane."""
    from repro.core.bisect import _pivot_floor, sturm_count_xla
    from repro.kernels.sturm_count import sturm_count_pallas_batch

    rng = np.random.default_rng(B * 1000 + n)
    d = jnp.asarray(rng.standard_normal((B, n)), dtype)
    e = rng.uniform(0.05, 0.5, (B, max(n - 1, 0)))
    e2 = jnp.asarray(e * e, dtype)
    shifts = jnp.asarray(rng.uniform(-3, 3, (B, S)), dtype)
    pivmin = _pivot_floor(e2, d.dtype)
    got = sturm_count_pallas_batch(d, e2, shifts, pivmin, shift_block=32,
                                   interpret=True)
    want = ref.sturm_count_ref(np.asarray(d), np.asarray(e2),
                               np.asarray(shifts), np.asarray(pivmin))
    xla = sturm_count_xla(d, e2, shifts, pivmin)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(xla), np.asarray(want))


@pytest.mark.partial
def test_sturm_kernel_shift_block_invariance():
    """The shift-block width is a tiling knob, never a semantics knob."""
    from repro.core.bisect import _pivot_floor
    from repro.kernels.sturm_count import sturm_count_pallas_batch

    rng = np.random.default_rng(11)
    d = jnp.asarray(rng.standard_normal((2, 100)))
    e2 = jnp.asarray(rng.uniform(0.01, 0.25, (2, 99)))
    shifts = jnp.asarray(rng.uniform(-3, 3, (2, 77)))
    pivmin = _pivot_floor(e2, d.dtype)
    outs = [np.asarray(sturm_count_pallas_batch(
        d, e2, shifts, pivmin, shift_block=sb, interpret=True))
        for sb in (8, 64, 128)]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_zhat_improves_or_matches_weights():
    """Reconstructed weights stay close to the originals for a
    well-conditioned problem (sanity on the log-product path)."""
    d, z, rho = _problem(64, 64, seed=5)
    origin, tau = sec.secular_solve(d, z * z, rho, 64, niter=24)
    zhat = zhat_reconstruct_pallas(d, z, origin, tau, jnp.asarray(64),
                                   jnp.asarray(rho, d.dtype), interpret=True)
    np.testing.assert_allclose(np.asarray(zhat), np.asarray(z),
                               atol=1e-8, rtol=1e-6)
