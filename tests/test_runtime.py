"""Direct unit tests for the ``repro.runtime`` reliability substrate:
retry, watchdog, straggler monitor.

These modules were previously exercised only through the serve engine;
the chaos harness leans on their exact semantics (which error classes
retry, how backoff grows, when a hang or straggler fires), so each
contract gets a direct pin here.
"""

import json
import os
import time

import pytest

from repro.runtime import StragglerMonitor, Watchdog, retry_transient
from repro.runtime.retry import TRANSIENT_DEFAULT


# --------------------------------------------------------------- retry


def test_retry_succeeds_after_transient_failures(monkeypatch):
    sleeps = []
    monkeypatch.setattr(time, "sleep", sleeps.append)
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("flaky interconnect")
        return "ok"

    retried = []
    out = retry_transient(flaky, retries=3, backoff_s=0.1,
                          on_retry=lambda i, exc: retried.append((i, exc)))()
    assert out == "ok"
    assert calls["n"] == 3
    assert [i for i, _ in retried] == [0, 1]
    # Exponential backoff: each retry doubles the previous delay.
    assert sleeps == [0.1, 0.2]


def test_retry_budget_exhausted_raises_last_error(monkeypatch):
    monkeypatch.setattr(time, "sleep", lambda s: None)
    calls = {"n": 0}

    def always_fails():
        calls["n"] += 1
        raise RuntimeError(f"attempt {calls['n']}")

    with pytest.raises(RuntimeError, match="attempt 3"):
        retry_transient(always_fails, retries=2, backoff_s=0.0)()
    assert calls["n"] == 3          # 1 try + 2 retries, never more


def test_retry_non_transient_propagates_immediately():
    calls = {"n": 0}

    def deterministic():
        calls["n"] += 1
        raise ValueError("bad input")

    with pytest.raises(ValueError):
        retry_transient(deterministic, retries=5, backoff_s=0.0)()
    assert calls["n"] == 1          # ValueError is not in TRANSIENT_DEFAULT


def test_retry_custom_transient_classes(monkeypatch):
    monkeypatch.setattr(time, "sleep", lambda s: None)

    class Flaky(Exception):
        pass

    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        if calls["n"] == 1:
            raise Flaky()
        return 7

    assert retry_transient(fn, retries=1, backoff_s=0.0,
                           transient=(Flaky,))() == 7
    # ...and RuntimeError is then NOT transient for this wrapper.
    with pytest.raises(RuntimeError):
        retry_transient(lambda: (_ for _ in ()).throw(RuntimeError()),
                        retries=3, backoff_s=0.0, transient=(Flaky,))()


def test_transient_default_is_os_and_runtime_errors():
    assert OSError in TRANSIENT_DEFAULT
    assert RuntimeError in TRANSIENT_DEFAULT
    assert ValueError not in TRANSIENT_DEFAULT


def test_retry_passes_arguments_through(monkeypatch):
    monkeypatch.setattr(time, "sleep", lambda s: None)
    seen = []

    def fn(a, b=0):
        seen.append((a, b))
        if len(seen) == 1:
            raise OSError()
        return a + b

    assert retry_transient(fn, retries=1, backoff_s=0.0)(2, b=3) == 5
    assert seen == [(2, 3), (2, 3)]


# ------------------------------------------------------------ watchdog


def test_watchdog_beat_writes_heartbeat_file(tmp_path):
    path = os.path.join(tmp_path, "sub", "hb.json")
    wd = Watchdog(path, timeout_s=60.0)
    wd.beat(3, bucket="solve/N64", idle=False)
    with open(path) as f:
        payload = json.load(f)
    assert payload["step"] == 3
    assert payload["bucket"] == "solve/N64"
    assert payload["time"] == pytest.approx(time.time(), abs=60)
    # Beats replace atomically (no .tmp litter).
    assert not os.path.exists(path + ".tmp")


def test_watchdog_detects_a_hang(tmp_path):
    hangs = []
    wd = Watchdog(os.path.join(tmp_path, "hb.json"), timeout_s=0.05,
                  check_every_s=0.01, on_hang=lambda s: hangs.append(s))
    with wd:
        wd.beat(0)
        deadline = time.monotonic() + 5.0
        while not hangs and time.monotonic() < deadline:
            time.sleep(0.01)
    assert hangs, "watchdog never fired on a silent worker"
    assert wd.hang_count >= 1
    assert hangs[0] > 0.05


def test_watchdog_stays_quiet_while_beating(tmp_path):
    hangs = []
    wd = Watchdog(os.path.join(tmp_path, "hb.json"), timeout_s=0.2,
                  check_every_s=0.01, on_hang=lambda s: hangs.append(s))
    with wd:
        for step in range(10):
            wd.beat(step)
            time.sleep(0.01)
    assert not hangs
    assert wd.hang_count == 0


# ----------------------------------------------------------- straggler


def test_straggler_needs_a_baseline_first():
    mon = StragglerMonitor(window=16, threshold=3.0)
    for step in range(7):
        mon.record(step, 100.0)     # huge, but no baseline yet (< 8)
    assert mon.events == []


def test_straggler_flags_outlier_against_median_mad():
    mon = StragglerMonitor(window=64, threshold=3.0)
    for step in range(10):
        mon.record(step, 0.010 + 1e-4 * (step % 3))
    mon.record(10, 0.500)           # 50x the median
    assert len(mon.events) == 1
    ev = mon.events[0]
    assert ev["step"] == 10
    assert ev["duration"] == 0.5
    assert ev["median"] == pytest.approx(0.010, rel=0.2)
    assert ev["duration"] > ev["limit"]


def test_straggler_tolerates_normal_jitter():
    mon = StragglerMonitor(window=64, threshold=3.0)
    for step in range(50):
        mon.record(step, 0.010 + 1e-4 * (step % 5))
    assert mon.events == []


def test_straggler_on_straggler_hook_and_report():
    fired = []
    mon = StragglerMonitor(window=32, threshold=2.0,
                           on_straggler=fired.append)
    # Host 2 lags every step; hosts 0/1 anchor the overall median.
    for step in range(12):
        mon.record(step, 0.010,
                   per_host={0: 0.010, 1: 0.009, 2: 0.050})
    mon.record(12, 1.0, per_host={0: 0.010, 1: 0.009, 2: 1.0})
    assert fired and fired[0]["step"] == 12
    assert fired[0]["slow_hosts"] == [2]
    rep = mon.report()
    assert rep["events"] == 1
    assert rep["steps_tracked"] == 13
    assert rep["median_s"] == pytest.approx(0.010, rel=0.2)
