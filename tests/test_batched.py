"""Batch-first solver core coverage.

  * batched-vs-looped eigenvalue equality across families (uniform,
    clustered, glued_wilkinson) at <= 8 * eps * ||T||;
  * mixed-n bucket padding: different original sizes that pad into the
    same (N, bucket) class share ONE SolvePlan and still solve exactly;
  * return_boundary=True on a padded batched problem (per-problem tracked
    row through the tree);
  * plan cache: a second same-bucket call performs zero executor retraces;
  * batched kernel dispatchers (XLA vmap + Pallas batch grid, interpret
    mode) against loops of single solves and the dense batch oracles;
  * one-device-solve instrumentation (SOLVE_COUNTER) for batches and for
    the whole-batch SLQ pipeline, whose nodes/weights must match the
    pre-refactor per-probe loop;
  * SpectralEstimate.density vectorization pinned against the loop form.
"""

import numpy as np
import pytest
import scipy.linalg as sla

import jax
import jax.numpy as jnp

from repro.core import (SOLVE_COUNTER, eigvalsh_tridiagonal,
                        eigvalsh_tridiagonal_batch, eigvalsh_tridiagonal_br,
                        make_family_batch, make_plan)
from repro.core import plan as plan_mod
from repro.core import secular as sec
from repro.core.instrument import SolveCounter
from repro.kernels import ops, ref
from repro.kernels.fused_update import secular_postpass_pallas_batch
from repro.kernels.secular_roots import secular_solve_pallas_batch


_family_batch = make_family_batch


# ---------------------------------------------------------------------------
# batched == looped, across families
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["uniform", "clustered", "glued_wilkinson"])
@pytest.mark.parametrize("n,leaf", [(96, 8), (130, 16)])
def test_batched_matches_looped_singles(family, n, leaf):
    B = 4
    ds, es = _family_batch(family, n, B)
    res = eigvalsh_tridiagonal_batch(ds, es, leaf=leaf)
    assert res.eigenvalues.shape == (B, n)
    eps = np.finfo(np.float64).eps
    for b in range(B):
        single = eigvalsh_tridiagonal_br(ds[b], es[b], leaf=leaf)
        tnorm = max(np.max(np.abs(np.asarray(single.eigenvalues))), 1.0)
        err = np.max(np.abs(np.asarray(res.eigenvalues[b])
                            - np.asarray(single.eigenvalues)))
        assert err <= 8.0 * eps * tnorm, f"{family} b={b}: {err}"
        # and both agree with LAPACK (cluster-width scale for glued)
        lam_ref = sla.eigh_tridiagonal(ds[b], es[b], eigvals_only=True)
        tol = 1e-7 if family == "glued_wilkinson" else 1e-11
        assert np.max(np.abs(np.asarray(res.eigenvalues[b]) - lam_ref)) \
            / tnorm < tol


def test_api_routes_2d_inputs():
    ds, es = _family_batch("normal", 64, 3)
    lam = eigvalsh_tridiagonal(ds, es, leaf=16)   # native batched "br"
    lam_eigh = eigvalsh_tridiagonal(ds, es, method="eigh")  # looped baseline
    np.testing.assert_allclose(np.asarray(lam), np.asarray(lam_eigh),
                               rtol=0, atol=1e-11)


# ---------------------------------------------------------------------------
# mixed-n bucket padding + plan cache
# ---------------------------------------------------------------------------

def test_mixed_n_same_bucket_shares_plan():
    """n=100 and n=120 both pad to N=128 at leaf=32; with the same batch
    bucket they must resolve to the SAME cached plan and solve exactly."""
    p1 = make_plan(100, 3, leaf=32)
    p2 = make_plan(120, 4, leaf=32)
    assert p1 is p2
    assert p1.padded_n == 128 and p1.batch_bucket_size == 4

    for n in (100, 120):
        ds, es = _family_batch("uniform", n, 3, seed0=7)
        res = p1.execute(ds, es)
        assert res.eigenvalues.shape == (3, n)
        for b in range(3):
            lam_ref = sla.eigh_tridiagonal(ds[b], es[b], eigvals_only=True)
            np.testing.assert_allclose(np.asarray(res.eigenvalues[b]),
                                       lam_ref, rtol=0, atol=1e-10)


def test_same_bucket_second_call_no_retrace():
    ds5, es5 = _family_batch("normal", 100, 5, seed0=1)
    eigvalsh_tridiagonal_batch(ds5, es5, leaf=32)      # bucket 8, may trace
    before = plan_mod.EXECUTOR_TRACES.count
    ds7, es7 = _family_batch("normal", 120, 7, seed0=9)  # same N=128, bucket 8
    eigvalsh_tridiagonal_batch(ds7, es7, leaf=32)
    assert plan_mod.EXECUTOR_TRACES.count == before, \
        "second same-bucket call retraced the executor"


def test_batch_bucket_rounding():
    assert [plan_mod.batch_bucket(b) for b in (1, 2, 3, 5, 8, 9, 256)] == \
        [1, 2, 4, 8, 8, 16, 256]
    with pytest.raises(ValueError):
        plan_mod.batch_bucket(0)


# ---------------------------------------------------------------------------
# boundary rows on a padded batched problem
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,leaf", [(100, 8), (130, 32)])
def test_batched_return_boundary_padded(n, leaf):
    B = 3
    ds, es = _family_batch("uniform", n, B, seed0=3)
    with SOLVE_COUNTER.measure() as window:
        res = eigvalsh_tridiagonal_batch(ds, es, leaf=leaf,
                                         return_boundary=True)
    assert window.count == 1, "batched boundary solve must be ONE launch"
    for b in range(B):
        A = np.diag(ds[b]) + np.diag(es[b], 1) + np.diag(es[b], -1)
        w, V = np.linalg.eigh(A)
        np.testing.assert_allclose(np.asarray(res.eigenvalues[b]), w,
                                   atol=1e-10)
        assert np.max(np.abs(np.abs(np.asarray(res.blo[b]))
                             - np.abs(V[0]))) < 1e-9
        assert np.max(np.abs(np.abs(np.asarray(res.bhi[b]))
                             - np.abs(V[-1]))) < 1e-9
        assert abs(np.linalg.norm(np.asarray(res.bhi[b])) - 1.0) < 1e-9


# ---------------------------------------------------------------------------
# batched kernel dispatchers
# ---------------------------------------------------------------------------

def _secular_batch(B, K, kprimes, seed=0):
    rng = np.random.default_rng(seed)
    ds, zs = [], []
    for kp in kprimes:
        d = np.sort(rng.standard_normal(K))
        d[kp:] += 10.0
        z = rng.standard_normal(K)
        z[kp:] = 0.0
        z /= max(np.linalg.norm(z), 1e-30)
        ds.append(d)
        zs.append(z)
    rho = 0.4 + 0.1 * np.arange(B)
    return (jnp.asarray(np.stack(ds)), jnp.asarray(np.stack(zs)),
            jnp.asarray(rho), jnp.asarray(kprimes, jnp.int32))


def test_batched_secular_solve_matches_loop_and_pallas():
    B, K = 4, 96
    d, z, rho, kprime = _secular_batch(B, K, [96, 50, 1, 77])
    o_b, t_b = ops.secular_solve_batched(d, z * z, rho, kprime, niter=24)
    for b in range(B):
        o_s, t_s = sec.secular_solve(d[b], (z * z)[b], rho[b], kprime[b],
                                     niter=24)
        assert np.array_equal(np.asarray(o_b[b]), np.asarray(o_s))
        np.testing.assert_array_equal(np.asarray(t_b[b]), np.asarray(t_s))

    o_p, t_p = secular_solve_pallas_batch(d, z * z, rho, kprime, niter=24,
                                          interpret=True)
    lam_b = np.take_along_axis(np.asarray(d), np.asarray(o_b), 1) \
        + np.asarray(t_b)
    lam_p = np.take_along_axis(np.asarray(d), np.asarray(o_p), 1) \
        + np.asarray(t_p)
    np.testing.assert_allclose(lam_p, lam_b, rtol=0, atol=1e-13)


def test_batched_postpass_matches_oracle_and_pallas():
    B, K = 3, 64
    d, z, rho, kprime = _secular_batch(B, K, [64, 40, 17], seed=5)
    origin, tau = sec.secular_solve_batched(d, z * z, rho, kprime, niter=24)
    R = jnp.asarray(np.random.default_rng(6).standard_normal((B, 2, K)))

    zh_x, rows_x = ops.secular_postpass_batched(R, d, z, origin, tau,
                                                kprime, rho)
    zh_o, rows_o = ref.secular_postpass_batch_ref(R, d, z, origin, tau,
                                                  kprime, rho)
    np.testing.assert_allclose(np.asarray(zh_x), np.asarray(zh_o),
                               rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(np.asarray(rows_x), np.asarray(rows_o),
                               rtol=1e-10, atol=1e-12)

    zh_p, rows_p = secular_postpass_pallas_batch(R, d, z, origin, tau,
                                                 kprime, rho, interpret=True)
    np.testing.assert_allclose(np.asarray(zh_p), np.asarray(zh_x),
                               rtol=0, atol=1e-13)
    np.testing.assert_allclose(np.asarray(rows_p), np.asarray(rows_x),
                               rtol=0, atol=1e-13)


# ---------------------------------------------------------------------------
# instrumentation + SLQ single-solve pipeline
# ---------------------------------------------------------------------------

def test_solve_counter_semantics():
    c = SolveCounter("t")
    with c.measure() as w:
        c.increment()
        c.increment(2)
        assert w.count == 3
    # windows are views, not resets: global tally unaffected by exit
    assert c.count == 3
    c.reset()
    assert c.count == 0


def test_batch_is_one_device_solve():
    ds, es = _family_batch("normal", 80, 6, seed0=11)
    with SOLVE_COUNTER.measure() as window:
        eigvalsh_tridiagonal_batch(ds, es, leaf=16)
    assert window.count == 1


def _sym_matvec(A):
    def mv(v):
        return {"x": A @ v["x"]}
    return mv


@pytest.mark.parametrize("num_probes", [1, 5])
def test_slq_single_device_solve_matches_loop(num_probes):
    """The batched SLQ pipeline is ONE device solve for any num_probes and
    reproduces the pre-refactor per-probe loop's nodes/weights."""
    from repro.spectral import slq_spectrum
    from repro.spectral.lanczos import lanczos_tridiag
    from repro.spectral.slq import _rademacher_like

    rng = np.random.default_rng(2)
    M = rng.standard_normal((30, 30))
    A = jnp.asarray(M @ M.T / 30 + np.eye(30))
    params = {"x": jnp.zeros(30)}
    key = jax.random.PRNGKey(7)
    num_steps = 16

    with SOLVE_COUNTER.measure() as window:
        est = slq_spectrum(_sym_matvec(A), params, key,
                           num_probes=num_probes, num_steps=num_steps)
    assert window.count == 1, \
        f"SLQ must be one device solve, saw {window.count}"

    # pre-refactor reference: per-probe Lanczos + per-probe single solves
    nodes_ref, weights_ref = [], []
    for k in range(num_probes):
        probe = _rademacher_like(jax.random.fold_in(key, k), params)
        alpha, beta = lanczos_tridiag(_sym_matvec(A), probe, num_steps)
        res = eigvalsh_tridiagonal_br(
            np.asarray(alpha, np.float64), np.asarray(beta, np.float64),
            leaf=8, return_boundary=True)
        nodes_ref.append(np.asarray(res.eigenvalues))
        weights_ref.append(np.asarray(res.blo) ** 2)
    np.testing.assert_allclose(est.nodes, np.stack(nodes_ref),
                               rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(est.weights, np.stack(weights_ref),
                               rtol=1e-5, atol=1e-8)


def test_density_vectorized_matches_loop():
    from repro.spectral import SpectralEstimate
    rng = np.random.default_rng(4)
    nodes = np.sort(rng.uniform(0.0, 5.0, size=(3, 12)), axis=1)
    weights = rng.uniform(0.0, 1.0, size=(3, 12))
    est = SpectralEstimate(nodes=nodes, weights=weights, lam_max=5.0,
                           lam_min=0.0, trace_est=0.0)
    grid = np.linspace(-1.0, 6.0, 157)
    dens = est.density(grid)

    sigma = max((np.max(nodes) - np.min(nodes)) / 100.0, 1e-12)
    expect = np.zeros_like(grid)
    for k in range(nodes.shape[0]):
        for lam, w in zip(nodes[k], weights[k]):
            expect += w * np.exp(-0.5 * ((grid - lam) / sigma) ** 2)
    expect /= (nodes.shape[0] * np.sqrt(2 * np.pi) * sigma)
    np.testing.assert_allclose(dens, expect, rtol=1e-13, atol=1e-15)
