"""Substrate layers: data, optimizers, spectral, checkpoint, runtime, dist."""

import os
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_synthetic_determinism_and_shards():
    from repro.data import SyntheticTokens
    src = SyntheticTokens(vocab_size=1000, seq_len=64, seed=7)
    a = src.batch(step=3, shard=0, batch_size=4)
    b = src.batch(step=3, shard=0, batch_size=4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src.batch(step=3, shard=1, batch_size=4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    d = src.batch(step=4, shard=0, batch_size=4)
    assert not np.array_equal(a["tokens"], d["tokens"])
    assert a["tokens"].max() < 1000 and a["tokens"].min() >= 0


def test_pipeline_prefetch_and_resume():
    from repro.data import DataPipeline, SyntheticTokens
    src = SyntheticTokens(vocab_size=100, seq_len=16, seed=1)
    p1 = DataPipeline(src, global_batch=4, prefetch=2)
    it1 = iter(p1)
    first = [next(it1)["tokens"] for _ in range(5)]
    p1.stop()
    # resume at step 3 reproduces the tail exactly
    p2 = DataPipeline(src, global_batch=4, prefetch=2, start_step=3)
    it2 = iter(p2)
    resumed = [next(it2)["tokens"] for _ in range(2)]
    p2.stop()
    np.testing.assert_array_equal(first[3], resumed[0])
    np.testing.assert_array_equal(first[4], resumed[1])


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["sgd", "adamw", "adafactor"])
def test_optimizers_minimize_quadratic(name):
    from repro.optim.optimizers import get_optimizer
    opt = get_optimizer(name, lr=0.1)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3), "m": jnp.zeros((4, 5))}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["m"] ** 2)

    state = opt.init(params)
    for _ in range(150):
        grads = jax.grad(loss)(params)
        params, state = opt.apply(params, grads, state)
    assert float(loss(params)) < 0.05, (name, float(loss(params)))


def test_adafactor_state_is_factored():
    from repro.optim.optimizers import adafactor
    opt = adafactor()
    params = {"w": jnp.zeros((64, 128))}
    state = opt.init(params)
    v = state["v"]["w"]
    assert v["vr"].shape == (64,) and v["vc"].shape == (128,)
    total = sum(x.size for x in jax.tree.leaves(state))
    assert total < 64 * 128 / 10       # O(m+n), not O(mn)


def test_lr_scale_hook():
    from repro.optim.optimizers import sgd
    opt = sgd(lr=1.0, momentum=0.0)
    params = {"w": jnp.ones(2)}
    grads = {"w": jnp.ones(2)}
    state = opt.init(params)
    p1, _ = opt.apply(params, grads, state, lr_scale=1.0)
    p2, _ = opt.apply(params, grads, state, lr_scale=0.5)
    assert float(p1["w"][0]) == pytest.approx(0.0)
    assert float(p2["w"][0]) == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# spectral (Lanczos + SLQ on BR)
# ---------------------------------------------------------------------------

def _sym_matvec(A):
    def mv(v):
        return {"x": A @ v["x"]}
    return mv


def test_lanczos_ritz_values_converge():
    from repro.spectral import lanczos_tridiag
    from repro.core import eigvalsh_tridiagonal
    rng = np.random.default_rng(0)
    Q, _ = np.linalg.qr(rng.standard_normal((60, 60)))
    lam_true = np.linspace(1.0, 10.0, 60)
    A = jnp.asarray(Q @ np.diag(lam_true) @ Q.T)
    probe = {"x": jnp.asarray(rng.standard_normal(60))}
    # m < dim avoids Krylov breakdown (beta -> 0 at full dimension);
    # extremal Ritz values converge long before that.
    alpha, beta = lanczos_tridiag(_sym_matvec(A), probe, 45)
    ritz = np.asarray(eigvalsh_tridiagonal(np.asarray(alpha),
                                           np.asarray(beta), leaf=8))
    assert abs(ritz[-1] - 10.0) < 1e-6
    assert abs(ritz[0] - 1.0) < 1e-6


def test_slq_trace_estimate():
    from repro.spectral import slq_spectrum
    rng = np.random.default_rng(1)
    M = rng.standard_normal((40, 40))
    A = jnp.asarray(M @ M.T / 40 + np.eye(40))
    true_trace = float(jnp.trace(A))
    est = slq_spectrum(_sym_matvec(A), {"x": jnp.zeros(40)},
                       jax.random.PRNGKey(0), num_probes=12, num_steps=20)
    assert abs(est.trace_est - true_trace) / true_trace < 0.25
    true_lmax = float(np.linalg.eigvalsh(np.asarray(A))[-1])
    assert abs(est.lam_max - true_lmax) / true_lmax < 0.05


@pytest.mark.partial
def test_spectral_edges_matches_dense_extremes():
    """The sliced extremal-edge path (no full spectrum, no boundary rows)
    agrees with the dense operator's edge eigenvalues."""
    from repro.spectral import sharpness, spectral_edges
    rng = np.random.default_rng(2)
    Q, _ = np.linalg.qr(rng.standard_normal((50, 50)))
    lam_true = np.linspace(0.5, 8.0, 50)
    A = jnp.asarray(Q @ np.diag(lam_true) @ Q.T)
    lo, hi = spectral_edges(_sym_matvec(A), {"x": jnp.zeros(50)},
                            jax.random.PRNGKey(3), num_probes=2,
                            num_steps=30, k=2)
    assert lo.shape == (2, 2) and hi.shape == (2, 2)
    assert abs(float(np.max(hi)) - 8.0) / 8.0 < 0.02
    assert abs(float(np.min(lo)) - 0.5) / 0.5 < 0.2
    s = sharpness(_sym_matvec(A), {"x": jnp.zeros(50)},
                  jax.random.PRNGKey(4), num_steps=30)
    assert abs(s - 8.0) / 8.0 < 0.02


@pytest.mark.partial
def test_governor_probe_uses_sliced_path():
    from repro.optim.spectral_adapt import SpectralGovernor
    rng = np.random.default_rng(5)
    M = rng.standard_normal((30, 30))
    A = jnp.asarray(M @ M.T / 30 + np.eye(30))
    gov = SpectralGovernor(target_sharpness=1.0, ema=0.0)
    scale = gov.probe(_sym_matvec(A), {"x": jnp.zeros(30)},
                      jax.random.PRNGKey(6), num_steps=20)
    true_lmax = float(np.linalg.eigvalsh(np.asarray(A))[-1])
    assert gov.lam_max == pytest.approx(true_lmax, rel=0.05)
    assert scale == pytest.approx(max(gov.min_scale,
                                      min(1.0, 1.0 / gov.lam_max)))


def test_hvp_on_quadratic():
    from repro.spectral import make_hvp
    A = jnp.asarray([[2.0, 1.0], [1.0, 3.0]])

    def loss(p):
        return 0.5 * p["x"] @ A @ p["x"]

    hvp = make_hvp(loss, {"x": jnp.asarray([1.0, 1.0])})
    out = hvp({"x": jnp.asarray([1.0, 0.0])})
    np.testing.assert_allclose(np.asarray(out["x"]), np.asarray(A[:, 0]),
                               atol=1e-12)


def test_spectral_governor():
    from repro.optim.spectral_adapt import SpectralGovernor
    gov = SpectralGovernor(target_sharpness=10.0, ema=0.0)
    assert gov.update(5.0) == 1.0          # flat: full LR
    assert gov.update(100.0) == pytest.approx(0.1)
    assert gov.update(1e6) == pytest.approx(gov.min_scale)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_resume(tmp_path):
    from repro.checkpoint import CheckpointManager, restore_tree, save_tree
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones(4)}}
    d = str(tmp_path / "ckpt")
    save_tree(d, 10, tree, meta={"loss": 1.5})
    save_tree(d, 20, tree, meta={"loss": 1.0})
    got, meta = restore_tree(d, 20, tree)
    assert meta["loss"] == 1.0
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))

    mgr = CheckpointManager(d, period=5)
    restored, meta, step = mgr.resume(tree)
    assert step == 20 and meta["loss"] == 1.0


def test_checkpoint_keep_n(tmp_path):
    from repro.checkpoint.manager import all_steps, save_tree
    d = str(tmp_path / "ckpt")
    tree = {"a": jnp.zeros(2)}
    for s in range(6):
        save_tree(d, s, tree, keep=3)
    assert all_steps(d) == [3, 4, 5]


def test_checkpoint_corruption_detected(tmp_path):
    from repro.checkpoint import restore_tree, save_tree
    d = str(tmp_path / "ckpt")
    tree = {"a": jnp.arange(8, dtype=jnp.float32)}
    path = save_tree(d, 1, tree)
    fn = [f for f in os.listdir(path) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(path, fn))
    arr[0] += 1
    np.save(os.path.join(path, fn), arr)
    with pytest.raises(IOError, match="checksum"):
        restore_tree(d, 1, tree)


def test_torn_checkpoint_skipped(tmp_path):
    from repro.checkpoint.manager import latest_step, save_tree
    import json
    d = str(tmp_path / "ckpt")
    tree = {"a": jnp.zeros(2)}
    save_tree(d, 1, tree)
    save_tree(d, 2, tree)
    # tear the newest manifest
    with open(os.path.join(d, "step_00000002", "manifest.json"), "w") as f:
        f.write("{ torn")
    assert latest_step(d) == 1


# ---------------------------------------------------------------------------
# runtime
# ---------------------------------------------------------------------------

def test_watchdog_detects_hang(tmp_path):
    from repro.runtime import Watchdog
    events = []
    wd = Watchdog(str(tmp_path / "hb.json"), timeout_s=0.2,
                  check_every_s=0.05, on_hang=lambda s: events.append(s))
    with wd:
        wd.beat(0)
        time.sleep(0.6)
    assert wd.hang_count >= 1 and events


def test_straggler_monitor_flags_outlier():
    from repro.runtime import StragglerMonitor
    mon = StragglerMonitor(window=32, threshold=2.0)
    for s in range(20):
        mon.record(s, 1.0 + 0.01 * (s % 3))
    mon.record(20, 5.0)
    assert mon.events and mon.events[-1]["step"] == 20
    rep = mon.report()
    assert rep["median_s"] == pytest.approx(1.0, abs=0.1)


def test_retry_transient():
    from repro.runtime import retry_transient
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    assert retry_transient(flaky, retries=5, backoff_s=0.01)() == "ok"
    assert len(calls) == 3

    def fatal():
        raise ValueError("no")

    with pytest.raises(ValueError):
        retry_transient(fatal, retries=2, backoff_s=0.01)()


# ---------------------------------------------------------------------------
# dist: sharding rules + compression
# ---------------------------------------------------------------------------

def test_sharding_rules_divisibility_pruning():
    from repro.dist.sharding import logical_param_specs
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # fake a mesh with extents 16/16 by building specs against shapes
    mesh16 = jax.make_mesh((1, 1), ("data", "model"))
    params = {
        "layers": {"attn": {
            "wq": jax.ShapeDtypeStruct((2, 64, 16, 8), jnp.float32),
            "wk": jax.ShapeDtypeStruct((2, 64, 3, 8), jnp.float32),
        }},
        "embed": jax.ShapeDtypeStruct((100, 64), jnp.float32),
    }
    specs = jax.tree.map(
        lambda x: x, logical_param_specs(params, mesh16),
        is_leaf=lambda x: isinstance(x, P))
    # with extents 1 everything divides; structure must match
    assert specs["layers"]["attn"]["wq"] == P(None, "data", "model", None)
    assert specs["embed"] == P("model", "data")


def test_sharding_prunes_nondivisible():
    from types import SimpleNamespace
    from repro.dist.sharding import _prune
    mesh = SimpleNamespace(shape={"model": 4})  # _prune reads .shape only
    assert _prune(("model",), (8,), mesh) == ("model",)
    assert _prune(("model",), (6,), mesh) == (None,)


def test_int8_compression_error_feedback():
    from repro.compat import shard_map
    from repro.dist.compression import (CompressionState,
                                        compressed_cross_pod_mean,
                                        init_compression_state)
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("pod",))
    grads = {"w": jnp.asarray(np.random.default_rng(0)
                              .standard_normal(128).astype(np.float32))}
    state = init_compression_state(grads)

    def f(g, err):
        return compressed_cross_pod_mean(g, CompressionState(err), "pod")

    out, new_state = shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check_vma=False)(grads, state.error)
    # single-pod mean == dequantized self; error feedback bounds the bias
    err1 = np.abs(np.asarray(out["w"]) - np.asarray(grads["w"]))
    scale = np.max(np.abs(np.asarray(grads["w"]))) / 127
    assert np.max(err1) <= scale * 1.01
    # residual carries exactly the quantization error
    total = np.asarray(out["w"]) + np.asarray(new_state.error["w"])
    np.testing.assert_allclose(total, np.asarray(grads["w"]), atol=1e-6)
