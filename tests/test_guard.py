"""Guarded-front-door tests: input validation taxonomy, overflow-safe
equilibration, and certification as a product knob.

The load-bearing properties:

  * rejection is STRUCTURED -- ``InvalidInputError`` names the offending
    field/lane/index so a service operator can filter the poisoned lane
    without parsing messages (and it subclasses ValueError, so existing
    caller contracts keep holding);
  * the guarded path is FREE when not needed -- in-range inputs pass
    through ``equilibrate`` untouched (same objects, scale 1.0) and a
    guarded solve is bit-identical to the unguarded seed behavior;
  * pathological scalings are handled EXACTLY -- power-of-two scaling
    means eigenvalues of the scaled problem are exactly ``scale * lam``,
    so 2^±600 problems solve to the same relative accuracy as O(1) ones;
  * ``certify=True`` works on every method and reports its tally in
    ``SolveResult.diagnostics``.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (CertificationError, InvalidInputError, SolveRequest,
                        certify_spectrum, clear_plan_cache, equilibrate,
                        eigvalsh_tridiagonal, eigvalsh_tridiagonal_range,
                        execute_request, plan_cache_stats, sturm_count,
                        validate_problem)
from repro.core import guard as _guard


def _problem(n, seed=0):
    rng = np.random.default_rng(seed + n)
    return rng.normal(size=n), rng.normal(size=n - 1)


# ---------------------------------------------------------- validation


def test_nan_rejection_names_lane_and_index():
    d = np.ones((4, 8))
    e = np.ones((4, 7))
    d[2, 5] = np.nan
    with pytest.raises(InvalidInputError, match="NaN") as ei:
        validate_problem(d, e)
    assert ei.value.field == "d"
    assert ei.value.lane == 2
    assert ei.value.index == 5


def test_inf_rejection_1d_names_index():
    d, e = _problem(8)
    e = e.copy()
    e[3] = np.inf
    with pytest.raises(InvalidInputError, match="Inf") as ei:
        validate_problem(d, e)
    assert ei.value.field == "e"
    assert ei.value.lane is None
    assert ei.value.index == 3


def test_invalid_input_is_a_value_error():
    assert issubclass(InvalidInputError, ValueError)
    with pytest.raises(ValueError):
        validate_problem(np.ones((2, 8)), np.ones((2, 3)))


@pytest.mark.parametrize("d,e", [
    (np.ones((2, 3, 4)), np.ones((2, 3, 3))),   # bad rank
    (np.ones((0,)), np.ones((0,))),             # empty
    (np.ones(8), np.ones(5)),                   # wrong e length
    (np.ones((3, 8)), np.ones((2, 7))),         # batch mismatch
    (np.ones(8), np.ones((2, 7))),              # rank mismatch
    (np.arange(8), np.ones(7)),                 # integer dtype
])
def test_malformed_shapes_rejected(d, e):
    with pytest.raises(InvalidInputError):
        validate_problem(d, e)


def test_valid_input_returned_untouched():
    d, e = _problem(16)
    d2, e2 = validate_problem(d, e)
    assert d2 is d and e2 is e


def test_route_time_rejection_before_any_launch():
    d, e = _problem(16)
    d = d.copy()
    d[7] = np.nan
    with pytest.raises(InvalidInputError) as ei:
        execute_request(SolveRequest(d=d, e=e))
    assert ei.value.index == 7


def test_public_utilities_share_the_taxonomy():
    d, e = _problem(12)
    bad = d.copy()
    bad[0] = np.inf
    with pytest.raises(InvalidInputError):
        sturm_count(bad, e, 0.0)
    with pytest.raises(InvalidInputError):
        sturm_count(np.ones((2, 12)), np.ones((2, 11)), 0.0)
    with pytest.raises(InvalidInputError):
        certify_spectrum(bad, e, np.zeros(12))
    with pytest.raises(InvalidInputError):    # lam shape mismatch
        certify_spectrum(d, e, np.zeros(5))
    with pytest.raises(InvalidInputError):    # non-positive tolerance
        certify_spectrum(d, e, np.zeros(12), tol=0.0)


def test_deadline_ms_validation():
    d, e = _problem(8)
    for bad in (-1.0, 0.0, np.nan, np.inf):
        with pytest.raises(InvalidInputError) as ei:
            execute_request(SolveRequest(d=d, e=e, deadline_ms=bad))
        assert ei.value.field == "deadline_ms"


# ------------------------------------------------------- equilibration


def test_equilibrate_passthrough_is_bit_free():
    d, e = _problem(32)
    d2, e2, scale = equilibrate(d, e)
    assert scale == 1.0
    assert d2 is d and e2 is e      # same objects: zero-copy fast path


@pytest.mark.parametrize("exp", [600, -600])
def test_equilibrate_extreme_scales_are_exact_powers_of_two(exp):
    d, e = _problem(32)
    ds, es, scale = equilibrate(d * 2.0 ** exp, e * 2.0 ** exp)
    frac, _ = np.frexp(scale)
    assert frac == 0.5              # scale is an exact power of two
    # Power-of-two scaling is exact: scaled arrays equal the originals
    # times the combined factor, bit for bit.
    np.testing.assert_array_equal(ds, d * (2.0 ** exp * scale))
    np.testing.assert_array_equal(es, e * (2.0 ** exp * scale))


@pytest.mark.parametrize("exp", [600, -600])
def test_extreme_scale_solve_matches_unit_scale(exp):
    d, e = _problem(48)
    ref = np.asarray(eigvalsh_tridiagonal(d, e))
    res = execute_request(SolveRequest(d=d * 2.0 ** exp, e=e * 2.0 ** exp))
    lam = np.asarray(res.eigenvalues) * 2.0 ** -exp
    np.testing.assert_allclose(lam, ref, rtol=0, atol=1e-12 * np.max(
        np.abs(ref)))
    assert res.diagnostics["equilibration_scale"] != 1.0


def test_f32_safe_range_is_narrower():
    d, e = _problem(16)
    _, _, s64 = equilibrate(d * 2.0 ** 100, e * 2.0 ** 100)
    _, _, s32 = equilibrate((d * 2.0 ** 100).astype(np.float32),
                            (e * 2.0 ** 100).astype(np.float32))
    assert s64 == 1.0               # 2^100 is fine for f64 (e^2 < 2^1024)
    assert s32 != 1.0               # but overflows f32's e^2 range


# ------------------------------------------------------- certification


def test_certify_spectrum_passes_true_eigenvalues():
    d, e = _problem(64)
    lam = np.asarray(eigvalsh_tridiagonal(d, e))
    cert = certify_spectrum(d, e, lam)
    assert cert.all_certified
    assert bool(np.all(cert.lo <= lam) and np.all(lam <= cert.hi))


def test_certify_spectrum_flags_a_wrong_value():
    d, e = _problem(64)
    lam = np.asarray(eigvalsh_tridiagonal(d, e)).copy()
    lam[10] += 0.1 * (np.max(lam) - np.min(lam))
    cert = certify_spectrum(d, e, lam)
    assert not bool(cert.certified[10])
    assert not cert.all_certified


def test_certify_spectrum_batched():
    d0, e0 = _problem(32, seed=1)
    d1, e1 = _problem(32, seed=2)
    D, E = np.stack([d0, d1]), np.stack([e0, e1])
    lam = np.stack([np.asarray(eigvalsh_tridiagonal(d0, e0)),
                    np.asarray(eigvalsh_tridiagonal(d1, e1))])
    cert = certify_spectrum(D, E, lam)
    assert cert.certified.shape == (2, 32)
    assert cert.all_certified


@pytest.mark.parametrize("method", ["br", "sterf", "bisect"])
def test_certify_knob_works_on_every_method(method):
    d, e = _problem(48)
    clear_plan_cache()
    req = SolveRequest(d=d, e=e, method=method, certify=True)
    res = execute_request(req)
    assert res.diagnostics["certified"] == 48
    assert res.diagnostics["lanes"] == 48
    ref = np.asarray(eigvalsh_tridiagonal(d, e, method=method))
    np.testing.assert_array_equal(np.asarray(res.eigenvalues), ref)


def test_certify_does_not_split_the_compiled_tree():
    clear_plan_cache()
    d, e = _problem(48)
    eigvalsh_tridiagonal(d, e)
    traces = plan_cache_stats()["executor_traces"]
    eigvalsh_tridiagonal(d, e, certify=True)
    # Certified and uncertified routes share ONE tree executable: the
    # certify sweep is a separate jit, not a retrace of the solver.
    assert plan_cache_stats()["executor_traces"] == traces


def test_certified_mixed_precision_solve():
    d, e = _problem(96)
    lam = eigvalsh_tridiagonal(d, e, precision="mixed", certify=True)
    ref = np.asarray(eigvalsh_tridiagonal(d, e))
    scale = np.max(np.abs(ref))
    np.testing.assert_allclose(np.asarray(lam), ref, rtol=0,
                               atol=64 * np.finfo(np.float64).eps * scale)


def test_certified_range_is_free():
    d, e = _problem(64)
    clear_plan_cache()
    req = SolveRequest(d=d, e=e, kind="range", il=0, iu=7, certify=True)
    res = execute_request(req)
    # Bisection encloses every value with exact counts -- certified by
    # construction, tallied without an extra sweep.
    assert res.diagnostics["certified"] == 8
    ref = np.asarray(eigvalsh_tridiagonal_range(d, e, il=0, iu=7))
    np.testing.assert_array_equal(np.asarray(res.eigenvalues), ref)


# ------------------------------------------------------------ counters


def test_robustness_counters_in_plan_cache_stats_and_reset():
    clear_plan_cache()
    stats = plan_cache_stats()
    assert stats["degradations"] == 0
    assert stats["deadline_expired"] == 0
    _guard.DEGRADATIONS.increment()
    _guard.DEADLINES.increment()
    assert plan_cache_stats()["degradations"] == 1
    assert plan_cache_stats()["deadline_expired"] == 1
    clear_plan_cache()
    stats = plan_cache_stats()
    assert stats["degradations"] == 0
    assert stats["deadline_expired"] == 0


def test_certification_error_class_hierarchy():
    assert issubclass(CertificationError, RuntimeError)
    assert issubclass(_guard.DeadlineExceeded, TimeoutError)
