"""Degenerate-input contracts for every public eigensolver entry point.

Covers n = 1, n = 2, all-zero off-diagonal (diagonal input), the all-zero
matrix, and duplicate-eigenvalue clusters across:

  * ``eigvalsh_tridiagonal``        (every method)
  * ``eigvalsh_tridiagonal_br``     (incl. return_boundary)
  * ``eigvalsh_tridiagonal_batch``  (the batched front door)
  * ``eigvalsh_tridiagonal_range``  (the sliced front door)

Exactness contract: with e == 0 the D&C paths deflate every merge
completely and the leaf eigendecompositions are diagonal, so the result
is the *exactly* sorted diagonal (bit-for-bit); sterf converges at step
zero and is exact too.  The bisection paths converge to within their
bracket tolerance instead (~2 eps * ||T||) -- a root polished between
two adjacent floats has no reason to land on the input bit pattern -- so
the sliced/bisect assertions carry that small allowance.
"""

import numpy as np
import pytest
import scipy.linalg as sla

from repro.core import (METHODS, eigvalsh_tridiagonal,
                        eigvalsh_tridiagonal_batch, eigvalsh_tridiagonal_br,
                        eigvalsh_tridiagonal_range)

_KW = {"br": {"leaf": 8}, "lazy": {"leaf": 8}, "full": {"leaf": 8},
       "sterf": {}, "eigh": {}, "bisect": {}}
EPS = np.finfo(np.float64).eps


@pytest.mark.parametrize("method", METHODS)
def test_n1(method):
    got = np.asarray(eigvalsh_tridiagonal(np.array([2.5]), np.zeros(0),
                                          method=method, **_KW[method]))
    np.testing.assert_array_equal(got, [2.5])


@pytest.mark.parametrize("method", METHODS)
def test_n2(method):
    d = np.array([1.0, -1.0])
    e = np.array([0.5])
    got = np.asarray(eigvalsh_tridiagonal(d, e, method=method,
                                          **_KW[method]))
    want = np.array([-np.sqrt(1.25), np.sqrt(1.25)])
    np.testing.assert_allclose(got, want, rtol=0, atol=16 * EPS)


def test_n1_n2_other_entry_points():
    res = eigvalsh_tridiagonal_br(np.array([3.0]), np.zeros(0),
                                  return_boundary=True)
    np.testing.assert_array_equal(np.asarray(res.eigenvalues), [3.0])
    np.testing.assert_array_equal(np.asarray(res.blo), [1.0])

    res = eigvalsh_tridiagonal_batch(np.array([[1.0], [2.0]]),
                                     np.zeros((2, 0)))
    np.testing.assert_array_equal(np.asarray(res.eigenvalues),
                                  [[1.0], [2.0]])

    got = eigvalsh_tridiagonal_range(np.array([1.0, -1.0]), np.array([0.5]),
                                     select="i", il=1, iu=1)
    np.testing.assert_allclose(np.asarray(got), [np.sqrt(1.25)],
                               rtol=0, atol=16 * EPS)

    res = eigvalsh_tridiagonal_br(np.array([4.0, 1.0]), np.array([0.0]),
                                  return_boundary=True)
    np.testing.assert_array_equal(np.asarray(res.eigenvalues), [1.0, 4.0])


@pytest.mark.parametrize("method", ["br", "sterf", "lazy", "full"])
def test_diagonal_input_exact(method):
    """e == 0: every merge deflates completely; the result IS sorted d."""
    rng = np.random.default_rng(7)
    d = rng.standard_normal(37)
    got = np.asarray(eigvalsh_tridiagonal(d, np.zeros(36), method=method,
                                          **_KW[method]))
    np.testing.assert_array_equal(got, np.sort(d))


def test_diagonal_input_exact_batched():
    rng = np.random.default_rng(8)
    D = rng.standard_normal((3, 41))
    res = eigvalsh_tridiagonal_batch(D, np.zeros((3, 40)), leaf=8)
    np.testing.assert_array_equal(np.asarray(res.eigenvalues),
                                  np.sort(D, axis=1))


def test_diagonal_input_exact_boundary_rows():
    """Padded diagonal input with boundary rows: still exact, unit rows."""
    rng = np.random.default_rng(9)
    d = rng.standard_normal(19)
    res = eigvalsh_tridiagonal_br(d, np.zeros(18), leaf=8,
                                  return_boundary=True)
    np.testing.assert_array_equal(np.asarray(res.eigenvalues), np.sort(d))
    assert abs(np.linalg.norm(np.asarray(res.blo)) - 1.0) < 1e-12
    assert abs(np.linalg.norm(np.asarray(res.bhi)) - 1.0) < 1e-12


def test_diagonal_input_range_near_exact():
    """The bisection paths converge to the bracket tolerance, not the
    input bit pattern -- allow ~2 eps * ||T||."""
    rng = np.random.default_rng(10)
    d = rng.standard_normal(37)
    want = np.sort(d)
    tol = 4 * EPS * np.max(np.abs(d))
    got = np.asarray(eigvalsh_tridiagonal_range(d, np.zeros(36),
                                                select="i", il=0, iu=36))
    np.testing.assert_allclose(got, want, rtol=0, atol=tol)
    top = np.asarray(eigvalsh_tridiagonal_range(d, np.zeros(36),
                                                select="i", il=30, iu=36))
    np.testing.assert_allclose(top, want[30:], rtol=0, atol=tol)


@pytest.mark.parametrize("method", METHODS)
def test_all_zero_matrix(method):
    got = np.asarray(eigvalsh_tridiagonal(np.zeros(16), np.zeros(15),
                                          method=method, **_KW[method]))
    np.testing.assert_array_equal(got, np.zeros(16))


def test_all_zero_matrix_other_entry_points():
    res = eigvalsh_tridiagonal_batch(np.zeros((2, 16)), np.zeros((2, 15)),
                                     leaf=8)
    np.testing.assert_array_equal(np.asarray(res.eigenvalues),
                                  np.zeros((2, 16)))
    got = eigvalsh_tridiagonal_range(np.zeros(16), np.zeros(15),
                                     select="i", il=4, iu=11)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(8))


@pytest.mark.parametrize("method", METHODS)
def test_duplicate_eigenvalue_cluster(method):
    """Weakly coupled constant diagonal: a cluster of near-identical
    eigenvalues around 1 (heavy deflation in the D&C paths, near-double
    roots in the bisection path)."""
    d = np.ones(48)
    e = np.full(47, 1e-3)
    got = np.asarray(eigvalsh_tridiagonal(d, e, method=method,
                                          **_KW[method]))
    ref = sla.eigh_tridiagonal(d, e, eigvals_only=True)
    nrm = np.max(np.abs(d)) + 2 * np.max(np.abs(e))
    np.testing.assert_allclose(got, ref, rtol=0, atol=64 * EPS * nrm)


def test_duplicate_cluster_batched_and_range():
    d = np.ones(48)
    e = np.full(47, 1e-3)
    ref = sla.eigh_tridiagonal(d, e, eigvals_only=True)
    nrm = np.max(np.abs(d)) + 2 * np.max(np.abs(e))
    res = eigvalsh_tridiagonal_batch(np.stack([d, d]), np.stack([e, e]),
                                     leaf=8)
    for b in range(2):
        np.testing.assert_allclose(np.asarray(res.eigenvalues[b]), ref,
                                   rtol=0, atol=64 * EPS * nrm)
    got = np.asarray(eigvalsh_tridiagonal_range(d, e, select="i",
                                                il=40, iu=47))
    np.testing.assert_allclose(got, ref[40:], rtol=0, atol=64 * EPS * nrm)


def test_zero_offdiagonal_segment_splits():
    """Interior exact zeros decouple the problem exactly (rho == 0
    merges deflate completely) -- every entry point agrees with scipy."""
    rng = np.random.default_rng(3)
    d = rng.standard_normal(64)
    e = rng.uniform(0.1, 0.3, 63)
    e[13] = 0.0
    e[40] = 0.0
    ref = sla.eigh_tridiagonal(d, e, eigvals_only=True)
    nrm = np.max(np.abs(d)) + 2 * np.max(np.abs(e))
    for method in METHODS:
        got = np.asarray(eigvalsh_tridiagonal(d, e, method=method,
                                              **_KW[method]))
        np.testing.assert_allclose(got, ref, rtol=0, atol=64 * EPS * nrm,
                                   err_msg=method)
    got = np.asarray(eigvalsh_tridiagonal_range(d, e, select="i",
                                                il=10, iu=20))
    np.testing.assert_allclose(got, ref[10:21], rtol=0, atol=64 * EPS * nrm)
