"""Fused conquer-phase coverage.

  * fused single-pass post-pass (XLA dense + streamed) vs the legacy
    two-pass reference and vs the deliberately-dense ref.py oracle,
    including deflation-heavy secular problems (zero weights, duplicate
    poles);
  * full-solver equivalence fused vs legacy on deflation-heavy matrices
    (constant diagonal, glued-Wilkinson);
  * size-adaptive dispatch: stream_threshold is a speed knob, never a
    semantics knob;
  * regression: return_boundary=True on a padded size performs exactly ONE
    D&C solve (the pre-fusion code re-solved the reversed problem).
"""

import numpy as np
import pytest
import scipy.linalg as sla

import jax.numpy as jnp

from repro.core import br_dc
from repro.core import secular as sec
from repro.core import (dense_from_tridiag, eigvalsh_tridiagonal,
                        eigvalsh_tridiagonal_br, make_family)
from repro.kernels import ref


def _secular_problem(K, kprime, seed=0, duplicates=False):
    rng = np.random.default_rng(seed)
    d = np.sort(rng.standard_normal(K))
    if duplicates:
        # Near-coincident active poles: the regime zhat reconstruction and
        # the pole-side guards exist for.
        d[1::4] = d[0::4][: d[1::4].shape[0]] + 1e-13
        d = np.sort(d)
    d[kprime:] += 10.0
    z = rng.standard_normal(K)
    z[kprime:] = 0.0
    nz = np.linalg.norm(z)
    z = z / (nz if nz > 0 else 1.0)
    return jnp.asarray(d), jnp.asarray(z), 0.7


@pytest.mark.parametrize("K,kprime", [(16, 16), (64, 40), (130, 101),
                                      (256, 1), (257, 256)])
@pytest.mark.parametrize("duplicates", [False, True])
def test_fused_postpass_matches_two_pass(K, kprime, duplicates):
    """The fused single-pass == zhat_reconstruct followed by
    boundary_rows_update, for every dispatch mode."""
    d, z, rho = _secular_problem(K, kprime, duplicates=duplicates)
    origin, tau = sec.secular_solve(d, z * z, rho, kprime, niter=24)
    R = jnp.asarray(np.random.default_rng(1).standard_normal((2, K)))

    zh_ref = sec.zhat_reconstruct(d, z, origin, tau, kprime, rho)
    rows_ref = sec.boundary_rows_update(R, d, zh_ref, origin, tau, kprime)

    for dense in (True, False):
        for chunk in (32, 300):
            zh, rows = sec.secular_postpass(R, d, z, origin, tau, kprime,
                                            rho, chunk=chunk, dense=dense)
            np.testing.assert_allclose(np.asarray(zh), np.asarray(zh_ref),
                                       rtol=1e-12, atol=1e-13)
            np.testing.assert_allclose(np.asarray(rows), np.asarray(rows_ref),
                                       rtol=1e-11, atol=1e-12)


@pytest.mark.parametrize("K,kprime", [(32, 17), (130, 101)])
def test_fused_postpass_matches_dense_oracle(K, kprime):
    d, z, rho = _secular_problem(K, kprime, seed=3)
    origin, tau = sec.secular_solve(d, z * z, rho, kprime, niter=24)
    R = jnp.asarray(np.random.default_rng(4).standard_normal((3, K)))
    zh_o, rows_o = ref.secular_postpass_ref(R, d, z, origin, tau, kprime, rho)
    zh, rows = sec.secular_postpass(R, d, z, origin, tau, kprime, rho)
    np.testing.assert_allclose(np.asarray(zh), np.asarray(zh_o),
                               rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(np.asarray(rows), np.asarray(rows_o),
                               rtol=1e-10, atol=1e-12)


def test_fused_postpass_use_zhat_false():
    d, z, rho = _secular_problem(64, 50)
    origin, tau = sec.secular_solve(d, z * z, rho, 50, niter=24)
    R = jnp.asarray(np.random.default_rng(5).standard_normal((2, 64)))
    rows_ref = sec.boundary_rows_update(R, d, z, origin, tau, 50)
    zh, rows = sec.secular_postpass(R, d, z, origin, tau, 50, rho,
                                    use_zhat=False)
    np.testing.assert_allclose(np.asarray(zh), np.asarray(z), atol=0)
    np.testing.assert_allclose(np.asarray(rows), np.asarray(rows_ref),
                               rtol=1e-12, atol=1e-14)


def _glued_wilkinson(n):
    return make_family("glued_wilkinson", n)


@pytest.mark.parametrize("mat", ["toeplitz", "glued_wilkinson"])
@pytest.mark.parametrize("n", [96, 200])
def test_solver_fused_matches_legacy_on_deflation_heavy(mat, n):
    """Constant diagonal + glued-Wilkinson deflate nearly everything; the
    fused conquer must agree with the legacy two-pass pipeline AND with
    LAPACK through the whole tree."""
    d, e = make_family(mat, n)
    ref_lam = sla.eigh_tridiagonal(d, e, eigvals_only=True)
    got_f = eigvalsh_tridiagonal(d, e, leaf=8, fused=True)
    got_l = eigvalsh_tridiagonal(d, e, leaf=8, fused=False)
    scale = max(1.0, np.max(np.abs(ref_lam)))
    # Glued-Wilkinson carries 1e-8-separated eigenvalue clusters (glue^2);
    # any D&C resolves them to cluster width, so compare to LAPACK at that
    # scale -- the fused-vs-legacy agreement below stays at rounding level.
    lapack_tol = 5e-13 if mat == "toeplitz" else 1e-7
    assert np.max(np.abs(np.asarray(got_f) - ref_lam)) / scale < lapack_tol
    assert np.max(np.abs(np.asarray(got_f) - np.asarray(got_l))) / scale < 5e-13


@pytest.mark.parametrize("n", [100, 200])
def test_stream_threshold_is_speed_knob_only(n):
    """Dense vs streamed dispatch at every level agree to rounding."""
    d, e = make_family("normal", n)
    res_all_dense = eigvalsh_tridiagonal_br(
        d, e, leaf=8, stream_threshold=1 << 20, return_boundary=True)
    res_all_stream = eigvalsh_tridiagonal_br(
        d, e, leaf=8, stream_threshold=0, return_boundary=True)
    np.testing.assert_allclose(np.asarray(res_all_dense.eigenvalues),
                               np.asarray(res_all_stream.eigenvalues),
                               rtol=0, atol=1e-12)
    np.testing.assert_allclose(np.asarray(res_all_dense.bhi),
                               np.asarray(res_all_stream.bhi),
                               rtol=0, atol=1e-10)


@pytest.mark.parametrize("n,leaf", [(100, 8), (5, 32), (130, 32)])
def test_return_boundary_padded_is_single_solve(n, leaf):
    """Padding appends sentinel rows below row n-1; the tracked selected
    row must recover the true last row of Q without a second solve."""
    d, e = make_family("uniform", n)
    N, _ = br_dc._tree_shape(n, leaf)
    assert N != n, "test must exercise the padded path"

    with br_dc.SOLVE_COUNTER.measure() as window:
        res = eigvalsh_tridiagonal_br(d, e, leaf=leaf, return_boundary=True)
    assert window.count == 1, \
        "padded return_boundary ran more than one D&C solve"

    A = np.asarray(dense_from_tridiag(d, e))
    w, V = np.linalg.eigh(A)
    np.testing.assert_allclose(np.asarray(res.eigenvalues), w, atol=1e-10)
    assert np.max(np.abs(np.abs(np.asarray(res.blo)) - np.abs(V[0]))) < 1e-9
    assert np.max(np.abs(np.abs(np.asarray(res.bhi)) - np.abs(V[-1]))) < 1e-9
    assert abs(np.linalg.norm(res.bhi) - 1.0) < 1e-9
