"""Serving-layer tests: the coalescing service must be indistinguishable
from the sync API except for throughput.

The load-bearing assertions:

  * bit-for-bit -- every request kind answered by the service equals the
    sync API's answer exactly (same route -> same executable -> same
    bits; mixed-n flushes ride the host-pad + tracked-row machinery);
  * isolation -- a poisoned request fails alone, flushmates complete;
  * backpressure -- the bounded queue's high-water mark never exceeds
    queue_depth;
  * coalescing -- concurrent same-bucket traffic shares device launches.
"""

import threading
import time
from concurrent.futures import Future

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SOLVE_COUNTER, SolveRequest, clear_plan_cache,
                        eigvalsh_tridiagonal, eigvalsh_tridiagonal_batch,
                        eigvalsh_tridiagonal_range, plan_cache_stats,
                        prewarm)
from repro.core import br_dc as _br
from repro.core import plan as _plan
from repro.core.request import execute_request, route_request
from repro.serve import (CoalescingScheduler, EigensolverClient, QueueFull,
                         ServeConfig)
from repro.serve.engine import _host_pad


def _problem(n, seed=0):
    rng = np.random.default_rng(seed + n)
    return rng.normal(size=n), rng.normal(size=n - 1)


# ---------------------------------------------------------------- routing


def test_route_key_equality_is_the_coalescing_invariant():
    d40, e40 = _problem(40)
    d64, e64 = _problem(64)
    r40 = route_request(SolveRequest(d=d40, e=e40))
    r64 = route_request(SolveRequest(d=d64, e=e64))
    # Same padded bucket -> same route -> coalescable...
    assert r40.route == r64.route
    assert r40.route.batch_bucket == 0  # batch axis left to the flush
    # ...while knob or shape changes split the route.
    r_rows = route_request(SolveRequest(d=d64, e=e64, return_boundary=True))
    assert r_rows.route != r64.route
    d100, e100 = _problem(100)
    assert route_request(SolveRequest(d=d100, e=e100)).route != r64.route


def test_route_request_is_pure_wrt_plan_cache():
    clear_plan_cache()
    d, e = _problem(48)
    route_request(SolveRequest(d=d, e=e))
    route_request(SolveRequest(d=d, e=e, kind="range", il=0, iu=3))
    stats = plan_cache_stats()
    assert stats["size"] == 0 and stats["range_size"] == 0


def test_sync_api_goes_through_request_core():
    d, e = _problem(48)
    req = SolveRequest(d=d, e=e)
    got = execute_request(req).eigenvalues
    ref = eigvalsh_tridiagonal(d, e)
    assert jnp.array_equal(got, ref)


# ------------------------------------------------------------- host pad


def test_host_pad_bitwise_matches_pad_problem():
    for n in (3, 40, 57):
        d, e = _problem(n)
        d2 = np.stack([d, d * 0.5])
        e2 = np.stack([e, e * 2.0])
        N, _ = _br._tree_shape(n, 32)
        dp, ep = _host_pad(d2, e2, N)
        dref, eref, N2, _ = _br._pad_problem(jnp.asarray(d2),
                                             jnp.asarray(e2), 32)
        assert N2 == N
        assert np.array_equal(dp, np.asarray(dref))
        # _pad_problem returns e padded to length N for uniform split
        # indexing; the host form stops at the executor's N-1 input width.
        assert np.array_equal(ep, np.asarray(eref)[:, : N - 1])


# -------------------------------------------------------- service == sync


def test_threaded_mixed_requests_bitwise_equal_sync():
    """N threads x mixed-n/mixed-kind traffic == sequential sync results,
    bit for bit -- the acceptance criterion of the serving layer."""
    sizes = (40, 64, 100)
    cases = []
    for n in sizes:
        d, e = _problem(n)
        cases.append(("full", d, e, {}))
        cases.append(("range", d, e, {"il": 0, "iu": 5}))
        cases.append(("range", d, e, {"il": n - 4, "iu": n - 1}))
    db, eb = _problem(64, seed=7)
    DB = np.stack([db, 2.0 * db, db - 1.0])
    EB = np.stack([eb, eb, 0.5 * eb])
    refs = []
    for kind, d, e, kw in cases:
        if kind == "full":
            refs.append(eigvalsh_tridiagonal(d, e))
        else:
            refs.append(eigvalsh_tridiagonal_range(d, e, select="i", **kw))
    ref_batch = eigvalsh_tridiagonal_batch(DB, EB, return_boundary=True)

    with EigensolverClient(max_batch=8, max_wait_us=20_000) as client:
        futs = [None] * len(cases)

        def submit(lo, hi):
            for i in range(lo, hi):
                kind, d, e, kw = cases[i]
                if kind == "full":
                    futs[i] = client.solve_async(d, e)
                else:
                    futs[i] = client.solve_range_async(d, e, select="i",
                                                       **kw)
        threads = [threading.Thread(target=submit, args=(i, i + 3))
                   for i in range(0, len(cases), 3)]
        fb = client.solve_batch_async(DB, EB, return_boundary=True)
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for i, ref in enumerate(refs):
            got = futs[i].result(timeout=600).eigenvalues
            assert jnp.array_equal(got, ref), f"case {i} diverged"
        res = fb.result(timeout=600)
        assert jnp.array_equal(res.eigenvalues, ref_batch.eigenvalues)
        assert jnp.array_equal(res.blo, ref_batch.blo)
        assert jnp.array_equal(res.bhi, ref_batch.bhi)
        snap = client.metrics()
        assert sum(b["errors"] for b in snap["buckets"].values()) == 0


def test_coalescing_shares_device_launches():
    d64, e64 = _problem(64)
    eigvalsh_tridiagonal(d64, e64)  # warm the bucket's executable
    with EigensolverClient(max_batch=16, max_wait_us=300_000) as client:
        with SOLVE_COUNTER.measure() as window:
            futs = [client.solve_async(*_problem(64, seed=s))
                    for s in range(8)]
            refs = [eigvalsh_tridiagonal(*_problem(64, seed=s))
                    for s in range(8)]
            for f, ref in zip(futs, refs):
                assert jnp.array_equal(f.result(timeout=600).eigenvalues,
                                       ref)
        snap = client.metrics()
    bucket = snap["buckets"]["solve/N64/float64"]
    assert bucket["coalesce_factor"] > 1.0
    assert bucket["flushes"] < bucket["requests"]
    # The sync refs cost one launch each; the 8 service solves must have
    # coalesced into fewer launches than requests (8 refs + < 8 flushes).
    assert window.count < 16


def test_slq_through_service_bitwise_equal_direct():
    from repro.spectral.slq import slq_spectrum
    A = jnp.asarray(np.random.default_rng(3).normal(size=(24, 24)))
    A = (A + A.T) / 2

    def matvec(v):
        return A @ v

    params_like = jnp.zeros((24,))
    rng = jax.random.PRNGKey(0)
    direct = slq_spectrum(matvec, params_like, rng, num_probes=3,
                          num_steps=8)
    with EigensolverClient(max_wait_us=1000) as client:
        served = slq_spectrum(matvec, params_like, rng, num_probes=3,
                              num_steps=8, client=client)
    assert np.array_equal(direct.nodes, served.nodes)
    assert np.array_equal(direct.weights, served.weights)
    assert direct.trace_est == served.trace_est


def test_empty_value_window_resolves_at_submit():
    d, e = _problem(32)
    lo = float(np.min(np.asarray(d)) - np.sum(np.abs(e)) - 10.0)
    with EigensolverClient() as client:
        lam = client.solve_range(d, e, select="v", vl=lo - 5.0, vu=lo)
    assert lam.shape == (0,)


# ------------------------------------------------------------- isolation


def test_poisoned_request_fails_alone():
    good1 = _problem(64, seed=1)
    good2 = _problem(64, seed=2)
    with EigensolverClient(max_batch=8, max_wait_us=50_000) as client:
        f1 = client.solve_async(*good1)
        bad = client.solve_async(np.zeros(64), np.zeros(10))  # wrong e width
        f_bad_method = client.submit(SolveRequest(
            d=good1[0], e=good1[1], method="nope"))
        f2 = client.solve_async(*good2)
        with pytest.raises(ValueError, match="batched solve expects"):
            bad.result(timeout=600)
        with pytest.raises(ValueError, match="unknown method"):
            f_bad_method.result(timeout=600)
        assert jnp.array_equal(f1.result(timeout=600).eigenvalues,
                               eigvalsh_tridiagonal(*good1))
        assert jnp.array_equal(f2.result(timeout=600).eigenvalues,
                               eigvalsh_tridiagonal(*good2))


def test_flush_failure_falls_back_to_singles(monkeypatch):
    """A whole-flush error must demote to per-request solves so only the
    genuinely poisoned member fails."""
    real_execute = _plan.SolvePlan.execute

    def explode_on_batches(self, d, e, orig_n=None):
        if d.shape[0] > 1:
            raise RuntimeError("injected device fault")
        return real_execute(self, d, e, orig_n=orig_n)

    monkeypatch.setattr(_plan.SolvePlan, "execute", explode_on_batches)
    p1, p2 = _problem(64, seed=11), _problem(64, seed=12)
    with EigensolverClient(max_batch=8, max_wait_us=100_000,
                           retries=0) as client:
        f1 = client.solve_async(*p1)
        f2 = client.solve_async(*p2)
        r1 = f1.result(timeout=600).eigenvalues
        r2 = f2.result(timeout=600).eigenvalues
        snap = client.metrics()
    monkeypatch.undo()
    assert jnp.array_equal(r1, eigvalsh_tridiagonal(*p1))
    assert jnp.array_equal(r2, eigvalsh_tridiagonal(*p2))
    assert any(b["fallbacks"] >= 1 for b in snap["buckets"].values())
    assert all(b["errors"] == 0 for b in snap["buckets"].values())


# ----------------------------------------------------------- backpressure


def test_backpressure_bound_honored(monkeypatch):
    monkeypatch.setattr(
        _plan.SolvePlan, "execute",
        lambda self, d, e, orig_n=None: (time.sleep(0.02), _slow_result(d))[1])
    depth = 4
    with EigensolverClient(max_batch=2, max_wait_us=500,
                           queue_depth=depth) as client:
        futs = []

        def flood():
            for s in range(8):
                futs.append(client.solve_async(*_problem(64, seed=s)))

        threads = [threading.Thread(target=flood) for _ in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for f in futs:
            f.result(timeout=600)
        peak = client.scheduler.peak_pending
    assert peak <= depth, f"peak pending {peak} exceeded depth {depth}"


def _slow_result(d):
    B, n = d.shape
    return _br.BRBatchResult(jnp.zeros((B, n), d.dtype), None, None, ())


def test_queue_full_times_out_without_engine():
    cfg = ServeConfig(queue_depth=1, submit_timeout_s=0.05)
    sched = CoalescingScheduler(cfg)
    d, e = _problem(64)
    f1 = sched.submit(SolveRequest(d=d, e=e))
    assert isinstance(f1, Future) and not f1.done()
    f2 = sched.submit(SolveRequest(d=d, e=e))  # no engine: queue stays full
    with pytest.raises(QueueFull):
        f2.result(timeout=1)
    sched.close()


# ------------------------------------------------- cache/stats satellites


def test_clear_plan_cache_resets_trace_counters():
    d, e = _problem(48, seed=21)
    eigvalsh_tridiagonal(d, e)
    eigvalsh_tridiagonal_range(d, e, select="i", il=0, iu=3)
    assert _plan.EXECUTOR_TRACES.count >= 0
    clear_plan_cache()
    stats = plan_cache_stats()
    assert stats["executor_traces"] == 0
    assert stats["range_executor_traces"] == 0
    assert stats["size"] == 0 and stats["range_size"] == 0
    assert stats["hits"] == stats["misses"] == 0


def test_state_bytes_reported_for_both_plan_kinds():
    clear_plan_cache()
    d, e = _problem(64, seed=22)
    eigvalsh_tridiagonal(d, e)
    eigvalsh_tridiagonal_range(d, e, select="i", il=0, iu=7)
    stats = plan_cache_stats()
    assert stats["state_bytes"] > 0
    assert stats["range_state_bytes"] > 0
    # The models, spelled out: (3 + r) * N * bucket * 8 bytes and
    # bucket * (2n + 4k) * 8 bytes.
    assert stats["state_bytes"] == (3 + 2) * 64 * 1 * 8
    assert stats["range_state_bytes"] == 1 * (2 * 64 + 4 * 8) * 8


def test_prewarm_makes_cold_start_free():
    clear_plan_cache()
    out = prewarm([{"kind": "solve", "n": 64, "batch": 4},
                   {"kind": "range", "n": 64, "k": 8, "batch": 1}])
    assert out["plans"] == 2
    t0 = plan_cache_stats()
    d, e = _problem(60, seed=23)   # same buckets: N=64, k->8
    D = np.stack([np.asarray(d)] * 3)
    E = np.stack([np.asarray(e)] * 3)
    eigvalsh_tridiagonal_batch(D, E)
    eigvalsh_tridiagonal_range(np.pad(d, (0, 4)), np.pad(e, (0, 4)),
                               select="i", il=10, iu=15)
    t1 = plan_cache_stats()
    assert t1["executor_traces"] == t0["executor_traces"]
    assert t1["range_executor_traces"] == t0["range_executor_traces"]


def test_cancelled_future_does_not_kill_engine():
    """A caller cancelling (or abandoning) its future must not crash the
    worker thread -- later requests still resolve."""
    p1, p2 = _problem(64, seed=41), _problem(64, seed=42)
    with EigensolverClient(max_batch=4, max_wait_us=50_000) as client:
        f1 = client.solve_async(*p1)
        f1.cancel()   # queued futures are never marked running: cancellable
        f2 = client.solve_async(*p2)
        got = f2.result(timeout=600).eigenvalues
    assert jnp.array_equal(got, eigvalsh_tridiagonal(*p2))


def test_prewarm_slq_matches_service_flush_executable():
    """prewarm kind="slq" must compile the boundary+track executable the
    serve flush actually runs, so the first real SLQ request is trace-free."""
    clear_plan_cache()
    prewarm([{"kind": "slq", "n": 16, "batch": 4, "leaf": 8}])
    t0 = plan_cache_stats()["executor_traces"]
    D = np.random.default_rng(5).normal(size=(3, 16))
    E = np.random.default_rng(6).normal(size=(3, 15))
    with EigensolverClient(max_wait_us=1000) as client:
        res = client.submit(SolveRequest(d=D, e=E, kind="slq",
                                         knobs={"leaf": 8})).result(
                                             timeout=600)
    assert res.blo is not None
    assert plan_cache_stats()["executor_traces"] == t0


def test_engine_survives_heartbeat_write_failure():
    """An unwritable heartbeat path degrades monitoring, never serving."""
    p1, p2 = _problem(48, seed=51), _problem(48, seed=52)
    with EigensolverClient(heartbeat_path="/proc/nope/hb.json",
                           max_wait_us=1000) as client:
        r1 = client.solve(*p1)
        r2 = client.solve(*p2)   # the worker thread must still be alive
    assert jnp.array_equal(r1, eigvalsh_tridiagonal(*p1))
    assert jnp.array_equal(r2, eigvalsh_tridiagonal(*p2))


def test_prewarm_full_kind_covers_leaf_sized_requests():
    """kind='full' prewarm entries must ride the same routing rules as
    real single-problem requests (incl. the L==0 boundary-rows rule)."""
    clear_plan_cache()
    prewarm([{"kind": "full", "n": 16, "batch": 1}])
    t0 = plan_cache_stats()
    execute_request(SolveRequest(d=np.ones(16), e=np.zeros(15)))
    t1 = plan_cache_stats()
    assert t1["executor_traces"] == t0["executor_traces"]
    assert t1["misses"] == t0["misses"]


def test_return_boundary_requires_br():
    with pytest.raises(TypeError, match="require method='br'"):
        route_request(SolveRequest(d=np.ones(8), e=np.zeros(7),
                                   method="bisect", return_boundary=True))
    with pytest.raises(TypeError, match="require method='br'"):
        route_request(SolveRequest(d=np.ones((2, 8)), e=np.zeros((2, 7)),
                                   kind="slq", method="sterf"))


def test_mixed_n_flush_via_orig_n_bitwise():
    """The tracked-row mixed-size hook: host-padded problems inside one
    launch return the same boundary rows as their sync solves."""
    p40, p64 = _problem(40, seed=31), _problem(64, seed=32)
    s40 = _br.eigvalsh_tridiagonal_br(*p40, return_boundary=True)
    s64 = _br.eigvalsh_tridiagonal_br(*p64, return_boundary=True)
    d40, e40 = _host_pad(np.asarray(p40[0])[None], np.asarray(p40[1])[None],
                         64)
    D = np.concatenate([d40, np.asarray(p64[0])[None]], axis=0)
    E = np.concatenate([e40, np.asarray(p64[1])[None]], axis=0)
    plan = _plan.make_plan(64, 2, return_boundary=True)
    res = plan.execute(D, E, orig_n=np.asarray([40, 64], np.int32))
    assert jnp.array_equal(res.eigenvalues[0, :40], s40.eigenvalues)
    assert jnp.array_equal(res.blo[0, :40], s40.blo)
    assert jnp.array_equal(res.bhi[0, :40], s40.bhi)
    assert jnp.array_equal(res.eigenvalues[1], s64.eigenvalues)
    assert jnp.array_equal(res.blo[1], s64.blo)
    assert jnp.array_equal(res.bhi[1], s64.bhi)
