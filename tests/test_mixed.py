"""Mixed-precision pipeline: f32 D&C tree + Sturm-certified f64 refinement.

Four contracts pinned here:

  * soundness -- certification is an integer predicate on f64 Sturm
    counts: every NON-polished eigenvalue already meets the tolerance,
    and every returned eigenvalue (polished or not) is certified by an
    independent count check.  The vectorized certify sweep must agree
    exactly with the scalar reference oracle (``kernels.ref.certify_ref``).
  * dtype hygiene -- the f32 tree must stay f32 end to end (no silent
    weak-typing promotions in host staging, halo compression, or the
    pivot floor), while the mixed OUTPUT is float64.
  * isolation -- the default f64 path stays bit-identical with mixed
    traffic interleaved (precision/refine_tol split the route key, so a
    mixed solve can never retrace or perturb a native executable).
  * observability -- the refinement gauge mirrors the deflation gauge:
    per-solve (targets, polished, iterations, rounds) land in
    ``measure(refinement=True)`` windows, and mixed routes
    prewarm/coalesce like any other.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import FAMILIES, eigvalsh_tridiagonal, make_family
from repro.core import bisect as bis
from repro.core import plan as plan_mod
from repro.core.br_dc import (SOLVE_COUNTER, _pad_problem,
                              eigvalsh_tridiagonal_batch,
                              eigvalsh_tridiagonal_br)
from repro.core.bisect import (DEFAULT_REFINE_TOL, _pivot_floor,
                               refine_clusters, sturm_count_xla)
from repro.dist.compression import dequantize_lanes, quantize_lanes
from repro.kernels.ref import certify_ref
from repro.serve.engine import _host_pad

EPS = np.finfo(np.float64).eps


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_executables():
    # This module compiles many one-off executables (f32 tree plans,
    # certify sweeps, pow2-bucketed refine launches, serve/prewarm
    # traffic).  XLA:CPU keeps every loaded executable's memory
    # mappings for the life of the process, and the kernel's
    # vm.max_map_count budget is shared with all later test modules --
    # drop the plan cache and jit caches on the way out so the suite's
    # mapping high-water stays near its pre-mixed level.
    yield
    plan_mod.clear_plan_cache()
    jax.clear_caches()

pytestmark = pytest.mark.mixed


def _f32_tree_estimates(d, e, leaf=8):
    """The mixed pipeline's first stage in isolation: an f32 tree solve
    of the f64 problem, upcast -- exactly what refine_clusters receives."""
    res = eigvalsh_tridiagonal_br(np.asarray(d, np.float32),
                                  np.asarray(e, np.float32), leaf=leaf)
    return np.asarray(res.eigenvalues, np.float64)[None, :]


def _count_certified(d, e, lam, tol):
    """Independent soundness check: (B, n) bool, True where f64 Sturm
    counts prove |lam[b, j] - true lam_j| <= tol[b]."""
    return np.asarray(certify_ref(d, e, lam, tol))


# ---------------------------------------------------------------- soundness


@pytest.mark.parametrize("family", FAMILIES)
def test_refinement_soundness(family):
    """Every non-polished lane is returned bit-identical AND certified:
    the freeze heuristics inside the polish loop cannot produce an
    uncertified eigenvalue, because only certification (sound by
    construction) decides what refine_clusters leaves alone."""
    n = 257
    d, e = make_family(family, n)
    lam0 = _f32_tree_estimates(d, e)
    lam, info = refine_clusters(d[None, :], e[None, :], lam0, sort=False)
    lam = np.asarray(lam)

    untouched = ~info["polished_mask"]
    assert np.array_equal(lam[untouched], lam0[untouched])

    tol = DEFAULT_REFINE_TOL * EPS * max(
        1.0, np.abs(d).max() + 2.0 * np.abs(e).max())
    cert = _count_certified(d[None, :], e[None, :], lam, np.array([tol]))
    assert cert.all(), f"{(~cert).sum()} uncertified lanes"
    # ... including the ones the polish never touched: stage-1 output
    # already met tolerance there, which is the soundness property.
    cert0 = _count_certified(d[None, :], e[None, :], lam0, np.array([tol]))
    assert cert0[untouched].all()


def test_certify_sweep_matches_scalar_oracle():
    """The 2N-lane vectorized certify sweep agrees exactly with the
    scalar-loop reference -- certification is integer-valued, so any
    mismatch is a vectorization bug, not roundoff."""
    rng = np.random.default_rng(7)
    B, n = 3, 64
    d = rng.standard_normal((B, n))
    e = rng.standard_normal((B, n - 1))
    lam = np.sort(np.stack([
        np.linalg.eigvalsh(np.diag(d[b]) + np.diag(e[b], 1)
                           + np.diag(e[b], -1)) for b in range(B)]), axis=1)
    # Perturb some lanes past tolerance so both outcomes appear.
    lam_pert = lam.copy()
    lam_pert[:, ::5] += 1e-7
    cert, _, _, tol = bis._certify_executor(
        jnp.asarray(d), jnp.asarray(e * e), jnp.asarray(lam_pert),
        jnp.full((B,), n, jnp.int32), jnp.asarray(DEFAULT_REFINE_TOL))
    want = _count_certified(d, e, lam_pert, np.asarray(tol))
    assert np.array_equal(np.asarray(cert), want)
    assert not np.asarray(cert).all()      # the perturbation was detected
    assert np.asarray(cert).any()


def test_certified_brackets_enclose():
    """The tightest-bracket extraction stays an enclosure: every true
    eigenvalue lies in its lane's [lo, hi]."""
    rng = np.random.default_rng(11)
    n = 48
    d = rng.standard_normal((1, n))
    e = rng.standard_normal((1, n - 1))
    truth = np.linalg.eigvalsh(np.diag(d[0]) + np.diag(e[0], 1)
                               + np.diag(e[0], -1))
    lam = truth[None, :] + rng.uniform(-1e-8, 1e-8, (1, n))
    _, lo, hi, _ = bis._certify_executor(
        jnp.asarray(d), jnp.asarray(e * e), jnp.asarray(lam),
        jnp.full((1,), n, jnp.int32), jnp.asarray(DEFAULT_REFINE_TOL))
    lo, hi = np.asarray(lo)[0], np.asarray(hi)[0]
    assert (lo <= truth).all() and (truth <= hi).all()


def test_mixed_padded_and_batched_soundness():
    """End-to-end mixed solves certify: padded sizes (sentinel lanes in
    the tree), batches, and boundary-row output all go through the same
    refine stage.  Post-sort lanes may swap within tolerance, so the
    end-to-end check allows 2 * tol."""
    rng = np.random.default_rng(3)
    B, n = 4, 100                      # pads to 128 at leaf=8
    d = rng.standard_normal((B, n))
    e = rng.standard_normal((B, n - 1))
    res = eigvalsh_tridiagonal_batch(d, e, leaf=8, precision="mixed",
                                     return_boundary=True)
    lam = np.asarray(res.eigenvalues)
    assert lam.shape == (B, n) and lam.dtype == np.float64
    assert res.blo.dtype == jnp.float64 and res.bhi.dtype == jnp.float64
    assert (np.diff(lam, axis=1) >= 0.0).all()
    tol = DEFAULT_REFINE_TOL * EPS * np.maximum(
        1.0, np.abs(d).max(axis=1) + 2.0 * np.abs(e).max(axis=1))
    assert _count_certified(d, e, lam, 2.0 * tol).all()


# ------------------------------------------------------------ dtype hygiene


def test_f32_native_solve_stays_f32():
    d, e = make_family("normal", 130)
    res = eigvalsh_tridiagonal_br(np.asarray(d, np.float32),
                                  np.asarray(e, np.float32), leaf=8)
    assert res.eigenvalues.dtype == jnp.float32


def test_host_pad_no_promotion_and_bitwise_match():
    """serve's numpy staging must mirror the device padding bitwise AND
    keep f32 batches f32 (NumPy 1.x value-based promotion would silently
    lift `2.0 * f32` to f64 without the typed constants)."""
    rng = np.random.default_rng(5)
    for dt in (np.float32, np.float64):
        d = rng.standard_normal((3, 20)).astype(dt)
        e = rng.standard_normal((3, 19)).astype(dt)
        d_host, e_host = _host_pad(d, e, 32)
        assert d_host.dtype == dt and e_host.dtype == dt
        d_dev, e_dev, N, _ = _pad_problem(jnp.asarray(d), jnp.asarray(e), 32)
        assert np.array_equal(d_host, np.asarray(d_dev))
        assert np.array_equal(e_host, np.asarray(e_dev)[:, :N - 1])


def test_halo_compression_roundtrip_dtype():
    x = jnp.asarray(np.random.default_rng(9).standard_normal((2, 3, 16)),
                    jnp.float32)
    q, scale = quantize_lanes(x)
    assert q.dtype == jnp.int8 and scale.dtype == jnp.float32
    out = dequantize_lanes(q, scale, x.dtype)
    assert out.dtype == jnp.float32


def test_pivot_floor_dtype():
    e2 = jnp.asarray([[1.0, 4.0]], jnp.float32)
    assert _pivot_floor(e2, jnp.float32).dtype == jnp.float32
    assert _pivot_floor(e2.astype(jnp.float64),
                        jnp.float64).dtype == jnp.float64


def test_refine_requires_x64_inputs_upcast():
    """refine_clusters always certifies in f64 regardless of input dtype."""
    d, e = make_family("uniform", 33)
    lam0 = _f32_tree_estimates(d, e)
    lam, _ = refine_clusters(np.asarray(d, np.float32)[None, :],
                             np.asarray(e, np.float32)[None, :],
                             np.asarray(lam0, np.float32))
    assert lam.dtype == jnp.float64


# ----------------------------------------------------------- f64 isolation


def test_native_f64_bit_identical_around_mixed_traffic():
    """Interleaving mixed solves must not perturb the native f64 answer
    by a single bit -- precision splits the route key, so native traffic
    keeps its own executable."""
    d, e = make_family("clustered", 257)
    before = np.asarray(eigvalsh_tridiagonal(d, e, leaf=8))
    eigvalsh_tridiagonal(d, e, leaf=8, precision="mixed")
    after = np.asarray(eigvalsh_tridiagonal(d, e, leaf=8))
    assert np.array_equal(before, after)


# ------------------------------------------------------- routing / serving


def test_route_key_split_and_coalesce():
    native = plan_mod.resolve_solve_route(257, leaf=8)
    mixed1 = plan_mod.resolve_solve_route(257, leaf=8, precision="mixed")
    mixed2 = plan_mod.resolve_solve_route(257, leaf=8, precision="mixed")
    assert mixed1 == mixed2                       # coalesces with itself
    assert mixed1 != native                       # never with native
    assert native.precision == "native" and native.refine_tol == 0.0
    assert mixed1.precision == "mixed"
    assert mixed1.refine_tol == DEFAULT_REFINE_TOL
    assert mixed1.dtype == "float64"              # OUTPUT dtype stays f64
    # An explicit tolerance is its own route (coalescing invariant: equal
    # keys <=> shared executable + shared refine stage).
    loose = plan_mod.resolve_solve_route(257, leaf=8, precision="mixed",
                                         refine_tol=64.0)
    assert loose != mixed1 and loose.refine_tol == 64.0


def test_route_validation_errors():
    with pytest.raises(ValueError, match="refine_tol only applies"):
        plan_mod.resolve_solve_route(64, refine_tol=16.0)
    with pytest.raises(ValueError, match="refine_tol must be positive"):
        plan_mod.resolve_solve_route(64, precision="mixed", refine_tol=0.0)
    with pytest.raises(ValueError, match="float64 or None"):
        plan_mod.resolve_solve_route(64, precision="mixed",
                                     dtype=jnp.float32)
    with pytest.raises(ValueError, match="precision must be"):
        plan_mod.resolve_solve_route(64, precision="half")


def test_prewarm_mixed_compiles_both_executors():
    """A mixed prewarm spec compiles the f32 tree AND the certify sweep:
    the follow-up same-shape mixed solve performs zero new traces."""
    plan_mod.clear_plan_cache()
    report = plan_mod.prewarm([{"kind": "solve", "n": 200, "batch": 4,
                                "leaf": 8, "precision": "mixed"}])
    assert report["plans"] == 1
    stats = plan_mod.plan_cache_stats()
    assert stats["refine_executor_traces"] >= 1   # certify sweep compiled
    t0 = plan_mod.EXECUTOR_TRACES.count
    rng = np.random.default_rng(1)
    eigvalsh_tridiagonal_batch(rng.standard_normal((4, 200)),
                               rng.standard_normal((4, 199)),
                               leaf=8, precision="mixed")
    assert plan_mod.EXECUTOR_TRACES.count == t0   # tree executor reused


def test_serve_mixed_request_roundtrip():
    """Mixed rides the service like any route: the served answer equals
    the sync answer bitwise (same plan, same refine stage)."""
    from repro.serve import EigensolverClient
    d, e = make_family("normal", 64)
    want = np.asarray(eigvalsh_tridiagonal(d, e, leaf=8, precision="mixed"))
    with EigensolverClient(max_batch=4, max_wait_us=1000) as client:
        got = np.asarray(client.solve(d, e, leaf=8, precision="mixed"))
    assert np.array_equal(got, want)


# ----------------------------------------------------------- observability


def test_refinement_gauge():
    d, e = make_family("clustered", 257)
    with SOLVE_COUNTER.measure(refinement=True) as window:
        eigvalsh_tridiagonal(d, e, leaf=8, precision="mixed")
    stats = window.refinement_stats
    assert stats["solves"] == 1
    assert stats["targets"] == 257
    assert 0 <= stats["polished"] <= stats["targets"]
    assert stats["polish_fraction"] == stats["polished"] / stats["targets"]
    assert stats["max_rounds"] <= bis.DEFAULT_REFINE_ROUNDS
    # Outside a refinement window the gauge is off (steady state records
    # nothing), matching the deflation gauge's gating contract.
    with SOLVE_COUNTER.measure() as cold:
        eigvalsh_tridiagonal(d, e, leaf=8, precision="mixed")
    assert cold.refinement_stats["solves"] == 0


def test_refinement_counts_misses_not_n():
    """The pipeline's cost model: polish work is proportional to the miss
    set.  A well-separated spectrum certifies (almost) everywhere on
    round one; polished lanes stay a strict subset of targets."""
    d, e = make_family("wilkinson", 257)      # close pairs -> some misses
    lam0 = _f32_tree_estimates(d, e)
    _, info = refine_clusters(d[None, :], e[None, :], lam0)
    assert info["targets"] == 257
    assert info["polished"] < 257             # never polish-everything
    if info["polished"] == 0:
        assert info["iterations"] == 0
