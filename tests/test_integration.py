"""End-to-end integration: train loop (with resume), serve loop, dry-run.

These drive the public entry points exactly as a user would.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def test_train_smoke_loss_decreases(tmp_path):
    from repro.launch.train import main
    losses = main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "25",
                   "--batch", "4", "--seq", "64", "--lr", "3e-3",
                   "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "10",
                   "--log-every", "100"])
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_train_resume_from_checkpoint(tmp_path):
    from repro.launch.train import main
    ck = str(tmp_path / "ck")
    l1 = main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "10",
               "--batch", "2", "--seq", "32", "--ckpt-dir", ck,
               "--ckpt-every", "5", "--log-every", "100"])
    # "crash" after step 10; relaunch continues from the last checkpoint
    l2 = main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "14",
               "--batch", "2", "--seq", "32", "--ckpt-dir", ck,
               "--ckpt-every", "5", "--log-every", "100"])
    assert len(l2) == 4          # steps 10..13 only: resumed, not restarted


def test_train_with_spectral_governor(tmp_path):
    from repro.launch.train import main
    losses = main(["--arch", "qwen3-0.6b", "--smoke", "--steps", "12",
                   "--batch", "2", "--seq", "32",
                   "--spectral-every", "5",
                   "--ckpt-dir", str(tmp_path / "ck"),
                   "--log-every", "100"])
    assert np.isfinite(losses).all()


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-130m",
                                  "whisper-small"])
def test_serve_smoke(arch):
    from repro.launch.serve import main
    gen = main(["--arch", arch, "--smoke", "--batch", "2",
                "--prompt-len", "16", "--gen", "6"])
    assert gen.shape == (2, 6)
    assert np.isfinite(gen).all()


@pytest.mark.slow
def test_dryrun_subprocess_cell():
    """A fresh process (so XLA_FLAGS applies) compiles one fast cell on the
    512-device production mesh."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-130m", "--shape", "long_500k", "--mesh", "both"],
        env=ENV, capture_output=True, text=True, timeout=900)
    assert "ALL CELLS PASSED" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]
