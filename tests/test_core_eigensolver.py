"""BR boundary-row D&C: correctness against LAPACK references."""

import numpy as np
import pytest
import scipy.linalg as sla

from repro.core import (eigvalsh_tridiagonal, eigvalsh_tridiagonal_br,
                        dense_from_tridiag, make_family, FAMILIES)


def _ref(d, e):
    return sla.eigh_tridiagonal(d, e, eigvals_only=True)


def _efwd(got, ref):
    return np.max(np.abs(np.asarray(got) - ref)) / max(1.0, np.max(np.abs(ref)))


# dtype sweep tolerances (relative, forward error vs the float64 LAPACK
# reference): the float32 bar is eps_f32-relative with the same ~2000x
# headroom the float64 bar carries.  The sweep exists to catch silent
# dtype promotion (bare Python constants are weakly typed under jax, so
# a strongly-typed f64 scalar sneaking into the merge would *pass* at
# f64 and only show as an unexpected output dtype here).
_DTYPE_TOL = {np.float64: 5e-13, np.float32: 5e-4}


@pytest.mark.parametrize("dtype", [np.float64, np.float32])
@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("n", [5, 16, 33, 64, 100, 257, 512])
def test_br_matches_lapack(family, n, dtype):
    d, e = make_family(family, n, dtype=dtype)
    got = eigvalsh_tridiagonal(d, e, leaf=8)
    assert got.dtype == dtype
    assert _efwd(got, _ref(d.astype(np.float64),
                           e.astype(np.float64))) < _DTYPE_TOL[dtype]


@pytest.mark.parametrize("dtype", [np.float64, np.float32])
@pytest.mark.parametrize("leaf", [4, 8, 16, 32])
def test_leaf_size_invariance(leaf, dtype):
    d, e = make_family("uniform", 200, dtype=dtype)
    got = eigvalsh_tridiagonal(d, e, leaf=leaf)
    assert got.dtype == dtype
    assert _efwd(got, _ref(d.astype(np.float64),
                           e.astype(np.float64))) < _DTYPE_TOL[dtype]


@pytest.mark.parametrize("chunk", [16, 64, 333])
def test_chunk_invariance(chunk):
    """The streaming chunk size is a memory knob only -- results identical."""
    d, e = make_family("normal", 150)
    a = eigvalsh_tridiagonal(d, e, leaf=8, chunk=chunk)
    b = eigvalsh_tridiagonal(d, e, leaf=8, chunk=150)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=1e-13)


def test_zero_offdiagonal_splits():
    """e == 0 decouples exactly (handled by total deflation, rho = 0)."""
    rng = np.random.default_rng(3)
    d = rng.standard_normal(64)
    e = rng.uniform(0.1, 0.3, 63)
    e[13] = 0.0
    e[40] = 0.0
    got = eigvalsh_tridiagonal(d, e, leaf=8)
    assert _efwd(got, _ref(d, e)) < 5e-13


def test_duplicate_diagonal_entries():
    d = np.ones(48)
    e = np.full(47, 1e-3)
    got = eigvalsh_tridiagonal(d, e, leaf=8)
    assert _efwd(got, _ref(d, e)) < 5e-13


def test_tiny_matrices():
    for n in (1, 2, 3):
        rng = np.random.default_rng(n)
        d = rng.standard_normal(n)
        e = np.abs(rng.standard_normal(max(n - 1, 0))) + 0.1
        got = np.asarray(eigvalsh_tridiagonal(d, e, leaf=8))
        ref = np.linalg.eigvalsh(np.asarray(dense_from_tridiag(d, e)))
        np.testing.assert_allclose(got, ref, atol=1e-13)


def test_boundary_rows_match_dense_eigh():
    """blo/bhi(Q) agree with dense eigenvectors up to column sign, including
    padded sizes (the flip-identity path)."""
    for n in (64, 100):
        d, e = make_family("uniform", n)
        A = np.asarray(dense_from_tridiag(d, e))
        w, V = np.linalg.eigh(A)
        res = eigvalsh_tridiagonal_br(d, e, leaf=8, return_boundary=True)
        assert np.max(np.abs(np.abs(np.asarray(res.blo)) - np.abs(V[0]))) < 1e-10
        assert np.max(np.abs(np.abs(np.asarray(res.bhi)) - np.abs(V[-1]))) < 1e-10
        # rows of an orthogonal matrix have unit norm
        assert abs(np.linalg.norm(res.blo) - 1.0) < 1e-10
        assert abs(np.linalg.norm(res.bhi) - 1.0) < 1e-10


def test_float32_path():
    d, e = make_family("uniform", 256, dtype=np.float32)
    got = eigvalsh_tridiagonal(d, e, leaf=8, dtype=np.float32)
    assert got.dtype == np.float32
    assert _efwd(got, _ref(d.astype(np.float64), e.astype(np.float64))) < 5e-4


def test_gershgorin_padding_sentinels_dropped():
    """n that forces padding: no sentinel leaks into the spectrum."""
    d, e = make_family("normal", 77)
    got = np.asarray(eigvalsh_tridiagonal(d, e, leaf=8))
    assert got.shape == (77,)
    ref = _ref(d, e)
    assert _efwd(got, ref) < 5e-13
    assert np.all(np.diff(got) >= -1e-12)   # ascending


def test_workspace_model_linear():
    from repro.core import workspace_model, workspace_model_lazy
    w1 = workspace_model(1 << 12)["persistent_bytes"]
    w2 = workspace_model(1 << 13)["persistent_bytes"]
    assert w2 / w1 == pytest.approx(2.0, rel=0.01)       # O(n)
    l1 = workspace_model_lazy(1 << 12)["persistent_bytes"]
    l2 = workspace_model_lazy(1 << 13)["persistent_bytes"]
    assert l2 / l1 == pytest.approx(4.0, rel=0.05)       # O(n^2)
