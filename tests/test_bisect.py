"""Partial-spectrum slicing: Sturm counts, bisection front end, range plans.

Covers the accuracy contract (the sliced solve matches the corresponding
slice of the full BR solve to <= 8 * eps * ||T|| on every family), the
select-by-index / select-by-value semantics against scipy, the batched
front door, and the (k, select)-aware range-plan compile cache.
"""

import numpy as np
import pytest
import scipy.linalg as sla

from repro.core import (FAMILIES, eigvalsh_tridiagonal_br,
                        eigvalsh_tridiagonal_range, make_family,
                        make_family_batch, sturm_count)
from repro.core.plan import RANGE_EXECUTOR_TRACES, make_range_plan

pytestmark = pytest.mark.partial


def _tnorm(d, e):
    """Cheap ||T|| upper bound (infinity norm) for eps-relative tolerances."""
    return float(np.max(np.abs(d)) + (2.0 * np.max(np.abs(e)) if len(e) else 0.0))


# Internal-consistency bar (the acceptance criterion): sliced vs full BR.
SLICE_TOL_EPS = 8.0
# External bar vs scipy/LAPACK: both sides carry their own rounding, so
# the cross-library tolerance is the conformance suite's documented
# 64 * eps * ||T|| (see tests/test_conformance.py).
EXTERNAL_TOL_EPS = 64.0


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("window", [(0, 7), (120, 135), (249, 256)])
def test_range_matches_full_br_slice(family, window):
    """Acceptance bar: sliced solve == the full BR solve's slice to
    8 * eps * ||T|| on every family."""
    n = 257
    il, iu = window
    d, e = make_family(family, n)
    full = np.asarray(eigvalsh_tridiagonal_br(d, e, leaf=8).eigenvalues)
    got = np.asarray(eigvalsh_tridiagonal_range(d, e, select="i",
                                                il=il, iu=iu))
    tol = SLICE_TOL_EPS * np.finfo(np.float64).eps * max(1.0, _tnorm(d, e))
    assert got.shape == (iu - il + 1,)
    np.testing.assert_allclose(got, full[il:iu + 1], rtol=0, atol=tol)


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("n", [17, 64, 257])
def test_range_matches_scipy(family, n):
    d, e = make_family(family, n)
    ref = sla.eigh_tridiagonal(d, e, eigvals_only=True)
    k = min(8, n)
    got = np.asarray(eigvalsh_tridiagonal_range(d, e, select="i",
                                                il=n - k, iu=n - 1))
    tol = EXTERNAL_TOL_EPS * np.finfo(np.float64).eps * max(1.0, _tnorm(d, e))
    np.testing.assert_allclose(got, ref[n - k:], rtol=0, atol=tol)


@pytest.mark.parametrize("family", ["uniform", "toeplitz", "normal"])
def test_select_by_value_matches_scipy(family):
    n = 128
    d, e = make_family(family, n)
    ref = sla.eigh_tridiagonal(d, e, eigvals_only=True)
    # Window edges placed strictly between well-separated eigenvalues so
    # the half-open (vl, vu] boundary cannot flip a count at rounding
    # level (degenerate-gap edges are covered below).
    vl = 0.5 * (ref[20] + ref[21])
    vu = 0.5 * (ref[90] + ref[91])
    got = np.asarray(eigvalsh_tridiagonal_range(d, e, select="v",
                                                vl=vl, vu=vu))
    tol = EXTERNAL_TOL_EPS * np.finfo(np.float64).eps * max(1.0, _tnorm(d, e))
    assert got.shape == (70,)
    np.testing.assert_allclose(got, ref[21:91], rtol=0, atol=tol)


def test_select_by_value_degenerate_edges():
    """Wilkinson W^+ has pairs split by ~eps: a window edge inside such a
    pair legitimately lands on either side, so the contract is count
    within the cluster multiplicity and values matching the scipy slice
    the returned count implies."""
    n = 128
    d, e = make_family("wilkinson", n)
    ref = sla.eigh_tridiagonal(d, e, eigvals_only=True)
    vl = 0.5 * (ref[20] + ref[21])       # gap here is O(1e-13)
    vu = 0.5 * (ref[90] + ref[91])       # gap here is O(1e-14)
    got = np.asarray(eigvalsh_tridiagonal_range(d, e, select="v",
                                                vl=vl, vu=vu))
    assert abs(got.shape[0] - 70) <= 2
    tol = EXTERNAL_TOL_EPS * np.finfo(np.float64).eps * max(1.0, _tnorm(d, e))
    start = int(np.asarray(sturm_count(d, e, np.asarray(vl))))
    np.testing.assert_allclose(got, ref[start:start + got.shape[0]],
                               rtol=0, atol=tol + 1e-12)


def test_select_by_value_empty_window():
    d, e = make_family("uniform", 64)
    ref = sla.eigh_tridiagonal(d, e, eigvals_only=True)
    got = eigvalsh_tridiagonal_range(d, e, select="v",
                                     vl=float(ref[-1]) + 1.0,
                                     vu=float(ref[-1]) + 2.0)
    assert got.shape == (0,)


def test_range_batched_matches_loop():
    D, E = make_family_batch("normal", 100, 5)
    got = np.asarray(eigvalsh_tridiagonal_range(D, E, select="i",
                                                il=90, iu=99))
    assert got.shape == (5, 10)
    for b in range(D.shape[0]):
        single = np.asarray(eigvalsh_tridiagonal_range(
            D[b], E[b], select="i", il=90, iu=99))
        np.testing.assert_array_equal(got[b], single)


@pytest.mark.parametrize("dtype,tol", [(np.float64, 1e-13),
                                       (np.float32, 5e-5)])
def test_range_dtypes(dtype, tol):
    d, e = make_family("uniform", 128, dtype=dtype)
    got = np.asarray(eigvalsh_tridiagonal_range(d, e, select="i",
                                                il=120, iu=127))
    assert got.dtype == dtype
    ref = sla.eigh_tridiagonal(d.astype(np.float64), e.astype(np.float64),
                               eigvals_only=True)
    np.testing.assert_allclose(got.astype(np.float64), ref[120:],
                               rtol=0, atol=tol * max(1.0, _tnorm(d, e)))


def test_range_window_shift_hits_cache():
    """Any (il, iu) window of the same bucketed width shares one
    executable: the target indices are traced, never static."""
    d, e = make_family("uniform", 200)
    _ = eigvalsh_tridiagonal_range(d, e, select="i", il=0, iu=5)
    with RANGE_EXECUTOR_TRACES.measure() as w:
        _ = eigvalsh_tridiagonal_range(d, e, select="i", il=100, iu=105)
        _ = eigvalsh_tridiagonal_range(d, e, select="i", il=194, iu=199)
        _ = eigvalsh_tridiagonal_range(d, e, select="i", il=0, iu=7)
    assert w.count == 0, "same-bucket window traffic must not retrace"


def test_range_plan_bucketing():
    p1 = make_range_plan(333, 5)
    p2 = make_range_plan(333, 8)
    assert p1 is p2                      # k=5 rounds up into the k=8 bucket
    assert p1.key.k_bucket == 8
    p3 = make_range_plan(333, 9)
    assert p3.key.k_bucket == 16
    assert make_range_plan(333, 5, batch=3).key.batch_bucket == 4


def test_sturm_count_matches_spectrum():
    d, e = make_family("normal", 96)
    ref = sla.eigh_tridiagonal(d, e, eigvals_only=True)
    mids = 0.5 * (ref[:-1] + ref[1:])    # strictly between eigenvalues
    cnt = np.asarray(sturm_count(d, e, mids))
    np.testing.assert_array_equal(cnt, np.arange(1, 96))
    assert int(sturm_count(d, e, ref[-1] + 1.0)) == 96
    assert int(sturm_count(d, e, ref[0] - 1.0)) == 0


def test_sturm_count_zero_offdiagonal():
    d = np.array([3.0, -1.0, 2.0, -1.0])
    e = np.zeros(3)
    cnt = np.asarray(sturm_count(d, e, np.array([-2.0, 0.0, 2.5, 10.0])))
    np.testing.assert_array_equal(cnt, [0, 2, 3, 4])


def test_range_n1():
    got = eigvalsh_tridiagonal_range(np.array([4.5]), np.zeros(0),
                                     select="i", il=0, iu=0)
    np.testing.assert_array_equal(np.asarray(got), [4.5])


def test_range_validation():
    d, e = make_family("uniform", 32)
    with pytest.raises(ValueError, match="index range"):
        eigvalsh_tridiagonal_range(d, e, select="i", il=5, iu=3)
    with pytest.raises(ValueError, match="index range"):
        eigvalsh_tridiagonal_range(d, e, select="i", il=0, iu=32)
    with pytest.raises(ValueError, match="requires il and iu"):
        eigvalsh_tridiagonal_range(d, e, select="i")
    with pytest.raises(ValueError, match="vl < vu"):
        eigvalsh_tridiagonal_range(d, e, select="v", vl=1.0, vu=1.0)
    with pytest.raises(ValueError, match="single problems"):
        eigvalsh_tridiagonal_range(np.stack([d, d]), np.stack([e, e]),
                                   select="v", vl=0.0, vu=1.0)
    with pytest.raises(ValueError, match="select"):
        eigvalsh_tridiagonal_range(d, e, select="x", il=0, iu=1)


def test_range_clustered_duplicates():
    """Tight clusters (the bisection worst case: brackets shrink onto
    near-coincident roots) still match scipy at the shared tolerance."""
    d = np.ones(64)
    e = np.full(63, 1e-3)
    ref = sla.eigh_tridiagonal(d, e, eigvals_only=True)
    got = np.asarray(eigvalsh_tridiagonal_range(d, e, select="i",
                                                il=0, iu=63))
    tol = EXTERNAL_TOL_EPS * np.finfo(np.float64).eps * max(1.0, _tnorm(d, e))
    np.testing.assert_allclose(got, ref, rtol=0, atol=tol)
